"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention,
                                            paged_decode_attention)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _mk_qkv(key, B, Sq, Sk, H, KV, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, D), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,D,window,cap,qb,kb",
    [
        (2, 128, 4, 4, 64, 0, 0.0, 64, 64),     # MHA
        (1, 256, 8, 2, 64, 0, 0.0, 128, 64),    # GQA, uneven blocks
        (2, 96, 4, 2, 32, 0, 0.0, 64, 64),      # padding path (96 % 64 != 0)
        (1, 256, 4, 4, 64, 64, 0.0, 64, 64),    # sliding window
        (1, 128, 4, 2, 64, 0, 50.0, 64, 64),    # softcap (gemma2)
        (1, 128, 4, 2, 128, 48, 30.0, 32, 32),  # window + cap + D=128
    ],
)
def test_flash_attention_matches_oracle(B, S, H, KV, D, window, cap, qb, kb, dtype):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, S, S, H, KV, D, dtype)
    scale = 1.0 / np.sqrt(D)
    out = flash_attention(q, k, v, scale=scale, window=window, cap=cap,
                          q_block=qb, kv_block=kb, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=scale, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,D,window,kb",
    [
        (2, 256, 4, 4, 64, 0, 64),
        (3, 300, 8, 2, 64, 0, 128),   # padding + GQA
        (2, 256, 4, 2, 128, 96, 64),  # sliding window
    ],
)
def test_decode_attention_matches_oracle(B, S, H, KV, D, window, kb, dtype):
    key = jax.random.PRNGKey(1)
    q, k, v = _mk_qkv(key, B, 1, S, H, KV, D, dtype)
    q = q[:, :, 0]  # (B, H, D)
    pos = jax.random.randint(jax.random.fold_in(key, 7), (B,), 1, S)
    scale = 1.0 / np.sqrt(D)
    out = decode_attention(q, k, v, pos, scale=scale, window=window,
                           kv_block=kb, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, scale=scale, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def _paged_from_linear(k, v, bs, *, key, extra=3):
    """Scatter linear (B, KV, S, D) caches into shuffled block pools:
    returns (k_pool, v_pool, block_table) with pools (N, KV, bs, D) and a
    non-contiguous, non-monotonic table (B, S // bs)."""
    B, KV, S, D = k.shape
    nb = S // bs
    n_pool = B * nb + extra
    table = np.asarray(jax.random.permutation(key, n_pool)[:B * nb],
                       np.int32).reshape(B, nb)
    k_pool = np.asarray(
        jax.random.normal(jax.random.fold_in(key, 1),
                          (n_pool, KV, bs, D), jnp.float32), np.float32)
    v_pool = k_pool[::-1].copy()  # poison unused blocks: gathers must skip
    k_pool, v_pool = k_pool.astype(k.dtype), v_pool.astype(k.dtype)
    kn, vn = np.asarray(k), np.asarray(v)
    for b in range(B):
        for i in range(nb):
            k_pool[table[b, i]] = kn[b, :, i * bs:(i + 1) * bs]
            v_pool[table[b, i]] = vn[b, :, i * bs:(i + 1) * bs]
    return jnp.asarray(k_pool), jnp.asarray(v_pool), jnp.asarray(table)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,D,window,bs",
    [
        (2, 256, 4, 4, 64, 0, 64),
        (3, 256, 8, 2, 64, 0, 32),    # GQA, non-contiguous table
        (2, 256, 4, 2, 128, 96, 64),  # sliding window over block seams
        (1, 128, 4, 4, 64, 0, 16),    # many small blocks
    ],
)
def test_paged_decode_bitwise_matches_linear(B, S, H, KV, D, window, bs,
                                             dtype):
    """With matched blocking (linear kv_block == paged block size) the two
    kernels share the accumulation order, so the paged gather must be
    BIT-identical to the linear cache — the invariant that lets the paged
    serving path claim the linear engine's numbers."""
    key = jax.random.PRNGKey(3)
    q, k, v = _mk_qkv(key, B, 1, S, H, KV, D, dtype)
    q = q[:, :, 0]
    pos = jax.random.randint(jax.random.fold_in(key, 11), (B,), 1, S)
    k_pool, v_pool, table = _paged_from_linear(k, v, bs, key=key)
    scale = 1.0 / np.sqrt(D)
    lin = decode_attention(q, k, v, pos, scale=scale, window=window,
                           kv_block=bs, interpret=True)
    paged = paged_decode_attention(q, k_pool, v_pool, table, pos,
                                   scale=scale, window=window,
                                   interpret=True)
    assert np.array_equal(np.asarray(lin), np.asarray(paged)), \
        f"max diff {np.abs(np.asarray(lin, np.float32) - np.asarray(paged, np.float32)).max()}"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_oracle_ragged(dtype):
    """Paged kernel vs the pure-jnp oracle under ragged positions (every
    row at a different fill level, including block-boundary edges)."""
    B, S, H, KV, D, bs = 4, 128, 4, 2, 64, 32
    key = jax.random.PRNGKey(5)
    q, k, v = _mk_qkv(key, B, 1, S, H, KV, D, dtype)
    q = q[:, :, 0]
    pos = jnp.asarray([1, bs - 1, bs, S - 1], jnp.int32)  # edges + interior
    k_pool, v_pool, table = _paged_from_linear(k, v, bs, key=key)
    scale = 1.0 / np.sqrt(D)
    out = paged_decode_attention(q, k_pool, v_pool, table, pos, scale=scale,
                                 interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_ops_wrapper_matches_gathered_reference():
    """The model-layout ops wrapper: paged attention over shuffled pools
    equals the reference run on gather_kv_blocks'd linear caches."""
    from repro.kernels import ops

    B, S, H, KV, D, bs = 2, 64, 4, 2, 32, 16
    key = jax.random.PRNGKey(9)
    q, k, v = _mk_qkv(key, B, 1, S, H, KV, D, jnp.float32)
    q = q[:, :, 0]
    pos = jnp.asarray([S - 1, bs + 3], jnp.int32)
    k_pool, v_pool, table = _paged_from_linear(k, v, bs, key=key)
    scale = 1.0 / np.sqrt(D)
    # model layout: q (B,1,H,D), pools (N, bs, KV, D)
    out = ops.paged_decode_attention(
        q[:, None], k_pool.transpose(0, 2, 1, 3),
        v_pool.transpose(0, 2, 1, 3), table, pos, scale=scale)
    k_lin = ops.gather_kv_blocks(k_pool.transpose(0, 2, 1, 3), table)
    v_lin = ops.gather_kv_blocks(v_pool.transpose(0, 2, 1, 3), table)
    want = ref.decode_attention_ref(q, k_lin.transpose(0, 2, 1, 3),
                                    v_lin.transpose(0, 2, 1, 3), pos,
                                    scale=scale)
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(want, np.float32),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,L,P,N,chunk",
    [
        (2, 4, 128, 64, 32, 32),
        (1, 8, 256, 32, 64, 64),
        (2, 3, 64, 64, 128, 16),  # odd head count, many chunks
    ],
)
def test_ssd_scan_matches_oracle(B, H, L, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, H, L, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, L), jnp.float32))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    b = jax.random.normal(ks[3], (B, L, N), jnp.float32).astype(dtype)
    c = jax.random.normal(ks[4], (B, L, N), jnp.float32).astype(dtype)
    dt = dt.astype(dtype)

    y, h = ssd_scan(x, dt, a_neg, b, c, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, dt, a_neg, b, c, chunk=chunk)
    # bf16: oracle computes intra-chunk einsums in bf16, kernel accumulates
    # in f32 — tolerance covers the representation gap, not an algorithmic one
    tol = dict(rtol=5e-2, atol=1e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32), **tol)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, H, L, P, N = 1, 2, 128, 32, 16
    x = jax.random.normal(ks[0], (B, H, L, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, L)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    b = jax.random.normal(ks[3], (B, L, N))
    c = jax.random.normal(ks[4], (B, L, N))
    y16, h16 = ssd_scan(x, dt, a_neg, b, c, chunk=16, interpret=True)
    y64, h64 = ssd_scan(x, dt, a_neg, b, c, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64), rtol=1e-4, atol=1e-4)


def test_flash_matches_chunked_model_path():
    """Kernel == the model's chunked (XLA flash) path, not just dense."""
    from repro.models.attention import chunked_attention
    B, S, H, KV, D = 1, 192, 4, 2, 64
    q, k, v = _mk_qkv(jax.random.PRNGKey(4), B, S, S, H, KV, D, jnp.float32)
    scale = 1.0 / np.sqrt(D)
    out = flash_attention(q, k, v, scale=scale, q_block=64, kv_block=64,
                          interpret=True)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos, scale=scale, kv_block=64,
        q_block=64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
