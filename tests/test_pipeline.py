"""Property battery for 1F1B pipeline parallelism + the unified planner.

Four groups, matching the acceptance criteria:

1. **Schedule properties** (pure, no jax): the event-driven simulator's
   bubble equals the analytic ``(p-1)/(m+p-1)`` exactly for uniform stage
   times; under op times inflated above a uniform floor ``(f0, b0)`` the
   makespan obeys the perturbation lower bound ``(m+p-1)(f0+b0)`` — note
   the *naive* claim "measured bubble >= model bubble" is FALSE (e.g.
   p=2, m=2, f=[3.393, 1.0], b=[2.372, 2.0] gives 0.279 < 1/3), so the
   test pins the true effective-bubble form; the serial reference
   schedule is always worse than 1F1B.
2. **Planner optimality**: branch-and-bound over the unified auto-parallel
   grid equals exhaustive enumeration (config, time, feasibility) for
   3 archs x 2 topologies; when nothing fits, both return the
   memory-frugal pick with ``feasible=False`` after pricing the full
   grid; enlarging a candidate set never worsens the optimum.
3. **Measured bubble** (8 forced host devices): a real pipe=4 run's traced
   per-(stage, microbatch) spans, replayed through the simulator, land
   within 20% of the analytic model and beat the serial schedule.
4. **Bit-identity**: after K steps on the same token stream, the 1F1B
   trainer's parameters are bit-identical (``np.array_equal``, not
   allclose) to the single-stage data-parallel trainer's for
   pipe in {1, 2, 4} x every sync strategy.  Two load-bearing choices:
   ``dtype="float32"`` (bf16 rounds the tied-embedding cotangent sum
   differently across the stage split) and **>= 2 cycles per stage** (a
   single-cycle stage lowers a trip-count-1 ``lax.scan`` that XLA inlines
   and re-fuses, drifting ~1e-7 relative vs the baseline's intact loop).
"""
import math

import numpy as np
import pytest

from repro.core.ilp import Dim, search_bnb, search_exhaustive
from repro.core.pipeline import (
    balanced_stage_cut,
    pipeline_bubble,
    schedule_1f1b,
    simulate_1f1b,
    simulate_serial,
    stage_sequence_1f1b,
)

# ---------------------------------------------------------------------------
# 1. Schedule properties (pure)
# ---------------------------------------------------------------------------


def _uniform(p, m, f, b):
    return ([[f] * m for _ in range(p)], [[b] * m for _ in range(p)])


@pytest.mark.parametrize("p", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("m", [1, 2, 3, 8])
def test_uniform_bubble_matches_model_exactly(p, m):
    if m < p:
        m = p + m  # 1F1B needs a full fill; still sweeps m > p and m == p+k
    f, b = 2.0, 3.0
    fwd, bwd = _uniform(p, m, f, b)
    sim = simulate_1f1b(fwd, bwd)
    assert sim.makespan == (m + p - 1) * (f + b)
    assert sim.bubble_fraction == pytest.approx(pipeline_bubble(p, m),
                                                abs=1e-12)
    # every stage is busy exactly m ops of each kind
    assert sim.stage_busy == tuple([m * (f + b)] * p)


def test_bubble_is_scale_invariant():
    p, m = 4, 6
    for scale in (0.25, 1.0, 1e3):
        fwd, bwd = _uniform(p, m, 2.0 * scale, 3.0 * scale)
        sim = simulate_1f1b(fwd, bwd)
        assert sim.bubble_fraction == pytest.approx(
            pipeline_bubble(p, m), abs=1e-12)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("p,m", [(2, 2), (2, 8), (3, 5), (4, 4), (4, 12)])
def test_makespan_lower_bound_under_inflated_times(p, m, seed):
    """The TRUE perturbation theorem: with every op time >= a uniform
    floor (f0, b0), the makespan is >= (m+p-1)(f0+b0), hence the
    *effective* bubble 1 - m(f0+b0)/makespan is >= the analytic model.
    (The naive "simulated bubble >= model" does NOT hold — inflating a
    stage's ops raises busy time faster than makespan.)"""
    rng = np.random.default_rng(seed)
    f0, b0 = 1.0, 1.5
    fwd = (f0 * (1.0 + rng.random((p, m)))).tolist()
    bwd = (b0 * (1.0 + rng.random((p, m)))).tolist()
    sim = simulate_1f1b(fwd, bwd)
    floor = (m + p - 1) * (f0 + b0)
    assert sim.makespan >= floor - 1e-12
    eff_bubble = 1.0 - m * (f0 + b0) / sim.makespan
    assert eff_bubble >= pipeline_bubble(p, m) - 1e-12


def test_naive_bubble_bound_counterexample():
    """Documents WHY the lower-bound test above is phrased in makespan
    terms: a concrete perturbation whose simulated bubble_fraction drops
    *below* the uniform model."""
    fwd = [[3.393, 3.393], [1.0, 1.0]]
    bwd = [[2.372, 2.372], [2.0, 2.0]]
    sim = simulate_1f1b(fwd, bwd)
    assert sim.bubble_fraction < pipeline_bubble(2, 2)


@pytest.mark.parametrize("p,m", [(2, 4), (4, 4), (4, 16)])
def test_serial_schedule_is_strictly_worse(p, m):
    fwd, bwd = _uniform(p, m, 2.0, 3.0)
    pipe, serial = simulate_1f1b(fwd, bwd), simulate_serial(fwd, bwd)
    assert pipe.makespan < serial.makespan
    assert pipe.bubble_fraction < serial.bubble_fraction
    # serial does every op one at a time: bubble is exactly 1 - 1/p
    assert serial.bubble_fraction == pytest.approx(1.0 - 1.0 / p, abs=1e-12)


@pytest.mark.parametrize("p,m", [(1, 1), (2, 2), (3, 7), (4, 4), (4, 9)])
def test_schedule_respects_1f1b_structure(p, m):
    """The serialized order is a valid topological order of the 1F1B DAG,
    each stage's own sequence has the right warmup depth, and backwards
    complete in microbatch order on every stage."""
    for s in range(p):
        seq = stage_sequence_1f1b(p, m, s)
        assert len(seq) == 2 * m
        w = min(p - 1 - s, m)
        assert all(kind == "fwd" for kind, _ in seq[:w])  # warmup depth
        if w < m:  # steady state strictly alternates fwd/bwd
            steady = seq[w:w + 2 * (m - w)]
            assert [kind for kind, _ in steady] == \
                ["fwd", "bwd"] * (m - w)
        assert [j for kind, j in seq if kind == "fwd"] == list(range(m))
        assert [j for kind, j in seq if kind == "bwd"] == list(range(m))
    done = set()
    order = schedule_1f1b(p, m)
    assert len(order) == len(set(order)) == 2 * p * m
    for (s, kind, j) in order:
        if kind == "fwd":
            assert s == 0 or (s - 1, "fwd", j) in done
        else:
            assert (s, "fwd", j) in done
            assert s == p - 1 or (s + 1, "bwd", j) in done
        done.add((s, kind, j))


def test_balanced_stage_cut_properties():
    for cycles in (4, 7, 8, 13):
        for p in (1, 2, 4):
            if p > cycles:
                continue
            cut = balanced_stage_cut(cycles, p)
            assert len(cut) == p + 1
            assert cut[0] == 0 and cut[-1] == cycles
            widths = [b - a for a, b in zip(cut, cut[1:])]
            assert max(widths) - min(widths) <= 1
            assert sorted(widths, reverse=True) == widths  # remainder first
    with pytest.raises(ValueError):
        balanced_stage_cut(3, 4)


# ---------------------------------------------------------------------------
# 2. Planner optimality: branch-and-bound == exhaustive enumeration
# ---------------------------------------------------------------------------

PLANNER_ARCHS = ("granite-3-2b", "mamba2-780m", "musicgen-large")


def _meshes():
    from repro.core.hardware import CLUSTERS, MeshSpec

    return {
        "flat4": MeshSpec(chips=4, dp=4, tp=1),
        "2x4": MeshSpec(chips=8, dp=8, tp=1, topology=CLUSTERS["2x4"]),
    }


def _grid_size(dims):
    return math.prod(len(d.values) for d in dims)


@pytest.mark.parametrize("arch", PLANNER_ARCHS)
@pytest.mark.parametrize("mesh_name", ["flat4", "2x4"])
def test_bnb_matches_exhaustive(arch, mesh_name):
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import train_search_space

    dims, evaluate, lb = train_search_space(
        get_config(arch), get_shape("train_4k"), _meshes()[mesh_name],
        fsdp=False, opt_kind="adamw")
    assert _grid_size(dims) <= 250  # keep the oracle enumerable
    got = search_bnb(dims, evaluate, lower_bound=lb)
    want = search_exhaustive(dims, evaluate)
    assert got.config == want.config
    assert got.time == want.time
    assert got.feasible == want.feasible
    # pruning may only ever REMOVE work relative to the oracle
    assert got.n_evaluated <= want.n_evaluated == _grid_size(dims)


def test_bnb_matches_exhaustive_with_forced_pipe():
    """The golden-plan shape: the CLI-clamped (pipe, m) grid must agree
    with brute force too (the clamp changes the candidate set, not the
    search contract)."""
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import train_search_space

    dims, evaluate, lb = train_search_space(
        get_config("granite-3-2b"), get_shape("train_4k"),
        _meshes()["2x4"], fsdp=False, opt_kind="adamw",
        pipe=2, n_microbatch=64)
    got = search_bnb(dims, evaluate, lower_bound=lb)
    want = search_exhaustive(dims, evaluate)
    assert (got.config, got.time, got.feasible) == \
           (want.config, want.time, want.feasible)
    assert got.config["pipe_m"] == (2, 64)


def test_bnb_infeasible_everywhere_is_memory_frugal():
    """On a chip too small for any cell, no incumbent ever forms: the full
    grid is priced (zero pruning even with a bound) and both searches hand
    back the same minimum-memory config flagged infeasible."""
    import dataclasses

    from repro.configs.base import get_config, get_shape
    from repro.core.hardware import TPU_V5E, MeshSpec
    from repro.core.planner import train_search_space

    tiny = dataclasses.replace(TPU_V5E, hbm_bytes=2 ** 30, name="tiny-hbm")
    mesh = MeshSpec(chips=8, dp=8, tp=1, chip=tiny)
    dims, evaluate, lb = train_search_space(
        get_config("granite-3-2b"), get_shape("train_4k"), mesh,
        fsdp=False, opt_kind="adamw")
    got = search_bnb(dims, evaluate, lower_bound=lb)
    want = search_exhaustive(dims, evaluate)
    assert not got.feasible and not want.feasible
    assert got.config == want.config
    assert got.n_pruned == 0
    assert got.n_evaluated == _grid_size(dims)
    # frugal means frugal: no priced cell uses less memory
    mems = []

    def collect(cfg):
        t, mem, ok = evaluate(cfg)
        mems.append(mem)
        return t, mem, ok

    search_exhaustive(dims, collect)
    assert got.memory == min(mems)


def _synthetic_eval(config):
    # deterministic, collision-free pricing: no feasibility wrinkles, so
    # the optimum over a value-set prefix is a pure min — the monotone case
    t = 100.0 - 3.1 * config["a"] + 0.7 * ((config["b"] * 37) % 11)
    return t, float(config["a"] + config["b"]), True


def test_bnb_optimum_is_monotone_in_candidate_sets():
    """Enlarging any dimension's candidate list never worsens the found
    optimum (more choices can only help), and each prefix's pick still
    matches exhaustive."""
    a_vals = tuple(range(6))
    b_vals = tuple(range(8))
    prev = float("inf")
    for k in range(1, len(b_vals) + 1):
        dims = [Dim("a", a_vals), Dim("b", b_vals[:k])]
        got = search_bnb(dims, _synthetic_eval,
                         lower_bound=lambda partial: 0.0)
        want = search_exhaustive(dims, _synthetic_eval)
        assert got.config == want.config and got.time == want.time
        assert got.time <= prev + 1e-12
        prev = got.time


# ---------------------------------------------------------------------------
# 3 + 4. Executable 1F1B: measured bubble + bit-identity (8 host devices)
# ---------------------------------------------------------------------------

BATCH, SEQ, STEPS, MICRO = 32, 32, 2, 4


def _tiny_cfg():
    """float32 and >= 2 cycles per stage at pipe=4 — see module docstring
    for why both are load-bearing for bit-identity."""
    from repro.configs.base import get_config

    cfg = get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, dtype="float32")
    return cfg.replace(num_layers=cfg.first_k_dense + 8 * len(cfg.pattern))


def _token_batches(cfg, steps):
    rng = np.random.default_rng(0)
    out = []
    for _ in range(steps):
        toks = rng.integers(0, cfg.vocab_size, (BATCH, SEQ)).astype(np.int32)
        out.append({"tokens": toks, "labels": toks})
    return out


def _run_baseline(cfg, strategy, pipe, devices):
    """The single-stage trainer on the pipeline's data shards, microbatched
    to the same per-pass rows the 1F1B schedule uses."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.distributed import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    dp = len(devices) // pipe
    tr = DataParallelTrainer(
        cfg, RunConfig(attn_impl="dense", remat="none",
                       microbatch=BATCH // dp // MICRO),
        OptConfig(lr=1e-3, warmup_steps=0, total_steps=8),
        strategy=strategy, devices=devices[:dp])
    params, state = tr.init(0)
    step = tr.step_fn()
    for b in _token_batches(cfg, STEPS):
        db = {k: jax.device_put(jnp.asarray(v),
                                NamedSharding(tr.mesh, tr._data_spec))
              for k, v in b.items()}
        params, state, _ = step(params, state, db)
    return params


def _run_pipeline(cfg, strategy, pipe, devices):
    from repro.distributed.pipeline import PipelineTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    tr = PipelineTrainer(
        cfg, RunConfig(attn_impl="dense", remat="none"),
        OptConfig(lr=1e-3, warmup_steps=0, total_steps=8),
        pipe=pipe, n_microbatch=MICRO, strategy=strategy, devices=devices)
    params, state = tr.init(0)
    step = tr.step_fn()
    for b in _token_batches(cfg, STEPS):
        params, state, _ = step(params, state, b)
    return params


BIT_MATCH_GRID = [(1, "all_reduce")] + [
    (pipe, strat) for pipe in (2, 4)
    for strat in ("all_reduce", "reduce_scatter_all_gather",
                  "parameter_server", "hier_all_reduce")]


@pytest.mark.parametrize("pipe,strategy", BIT_MATCH_GRID)
def test_pipeline_params_bit_identical_to_single_stage(pipe, strategy,
                                                       multi_device):
    """The acceptance criterion: after STEPS optimizer steps on the same
    token stream, every parameter leaf matches the single-stage trainer
    bit for bit — per sync strategy, not just under all_reduce."""
    import jax

    cfg = _tiny_cfg()
    base = _run_baseline(cfg, strategy, pipe, multi_device)
    pipe_params = _run_pipeline(cfg, strategy, pipe, multi_device)
    base_leaves, base_tree = jax.tree_util.tree_flatten(base)
    pipe_leaves, pipe_tree = jax.tree_util.tree_flatten(pipe_params)
    assert base_tree == pipe_tree
    for a, b in zip(base_leaves, pipe_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_measured_bubble_reconciles_with_model(multi_device):
    """A real pipe=4 run: replaying the traced per-(stage, microbatch) span
    durations through the 1F1B DAG must land within 20% of the analytic
    ``(p-1)/(m+p-1)`` and beat the no-overlap serial schedule."""
    from repro.distributed.pipeline import PipelineTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    pipe = 4
    tr = PipelineTrainer(
        _tiny_cfg(), RunConfig(attn_impl="dense", remat="none"),
        OptConfig(lr=1e-3, warmup_steps=0, total_steps=8),
        pipe=pipe, n_microbatch=MICRO, strategy="all_reduce",
        devices=multi_device)
    tr.train(batch=BATCH, seq=SEQ, steps=4, log_every=100)
    rep = tr.pipeline_report()
    assert rep.pipe == pipe and rep.n_microbatch == MICRO
    assert rep.bubble_model == pytest.approx(pipeline_bubble(pipe, MICRO))
    assert abs(rep.bubble_measured - rep.bubble_model) <= \
        0.20 * rep.bubble_model
    assert rep.bubble_measured < rep.bubble_serial
    assert rep.makespan_s > 0
    assert len(rep.fwd_times_s) == pipe
    assert all(len(row) == MICRO for row in rep.fwd_times_s)
