"""Structural VMEM checks for the Pallas kernels: the per-grid-step working
set implied by the BlockSpecs must fit TPU v5e VMEM (with headroom for
double buffering), and block dims must be MXU/lane aligned where the
architecture's head_dim permits."""
import pytest

from repro.core.hardware import TPU_V5E
from repro.configs.base import ARCH_IDS, get_config

VMEM = TPU_V5E.vmem_bytes  # 128 MiB
BUDGET = VMEM / 2  # double-buffering headroom


def flash_working_set(tq, tk, d, dv=None, bytes_in=2):
    dv = dv or d
    qkv = (tq * d + tk * d + tk * dv) * bytes_in
    logits = tq * tk * 4
    scratch = (tq * dv + 2 * tq) * 4
    return qkv + logits + scratch


def decode_working_set(tk, d, bytes_in=2):
    return (d + 2 * tk * d) * bytes_in + tk * 4 + (d + 2) * 4


def ssd_working_set(q, p, n, bytes_in=2):
    blocks = (q * p + 2 * q * n + q) * bytes_in
    qq = q * q * 4
    scratch = n * p * 4
    return blocks + qq + scratch + q * p * 4


@pytest.mark.parametrize("d", [64, 80, 96, 128])
def test_flash_attention_blocks_fit_vmem(d):
    assert flash_working_set(512, 512, d) < BUDGET


@pytest.mark.parametrize("d", [64, 96, 128, 576])  # 576 = MLA qk dim
def test_decode_attention_blocks_fit_vmem(d):
    assert decode_working_set(512, d) < BUDGET


@pytest.mark.parametrize("q,p,n", [(256, 64, 128), (256, 32, 256)])
def test_ssd_blocks_fit_vmem(q, p, n):
    assert ssd_working_set(q, p, n) < BUDGET


def test_arch_head_dims_mxu_alignment():
    """Record which archs have lane-aligned (multiple of 128) head dims; the
    others (head_dim 64/80/96) still satisfy the 8-sublane constraint."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.head_dim:
            assert cfg.head_dim % 8 == 0, (arch, cfg.head_dim)
        if cfg.ssm_state:
            assert cfg.ssm_head_dim % 8 == 0


def test_flash_grid_covers_any_seq():
    """Padding logic: grid x block must cover ragged sequence lengths."""
    for s in (1, 7, 127, 513, 4096):
        tq = min(512, max(s, 8))
        nq = -(-s // tq)
        assert nq * tq >= s
