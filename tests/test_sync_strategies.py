"""repro.distributed tests: strategy zoo numerics vs the single-device
baseline, compression tolerances, and the Lemma 3.2 measured-vs-predicted
report. Fast multi-device numerics run *in-process* on the 8 forced host
devices (conftest pins XLA_FLAGS before jax loads — the `multi_device`
fixture asserts the axis exists instead of silently running dp=1); only
the heavyweight trainer runs re-exec via conftest.run_sub (slow-marked)."""
import pytest

from conftest import run_sub

# ---------------------------------------------------------------------------
# In-process unit tests (no devices needed)
# ---------------------------------------------------------------------------


def test_flatten_roundtrip():
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.collectives import flatten_tree, unflatten_tree

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.full((2, 2, 2), -1.5, jnp.float32)}}
    flat, meta = flatten_tree(tree)
    assert flat.shape == (6 + 4 + 8,) and flat.dtype == jnp.float32
    back = unflatten_tree(flat, meta)
    assert back["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(back["a"]))
    np.testing.assert_array_equal(
        np.asarray(tree["b"]["d"]), np.asarray(back["b"]["d"]))


def test_wire_bytes_and_lemma_predictions():
    from repro.core import ps
    from repro.distributed.collectives import STRATEGIES, get_strategy

    s_p, dp, bw = 1e9, 8, 1e9
    ar = get_strategy("all_reduce")
    rs = get_strategy("reduce_scatter_all_gather")
    # ring all-reduce and RS+AG move identical wire bytes
    assert ar.wire_bytes(s_p, dp) == rs.wire_bytes(s_p, dp) \
        == 2.0 * s_p * (dp - 1) / dp
    assert ar.predicted_comm_time(s_p, dp, bw) == ps.predicted_comm_time(
        "all_reduce", s_p, dp, bw)

    # PS: worker pushes+pulls everything; server-side time follows Eq. 7 and
    # is monotone decreasing in the server count
    prev = float("inf")
    for n in (1, 2, 4, 8, 16):
        t = get_strategy("parameter_server",
                         n_servers=n).predicted_comm_time(s_p, dp, bw)
        assert t == ps.io_time(s_p, dp, n, bw)
        assert t < prev
        prev = t
    assert get_strategy("parameter_server").wire_bytes(s_p, dp) == 2.0 * s_p

    # dp=1 edge: nothing crosses the wire for ANY schedule — including the
    # parameter server, whose old form charged 2*S_p with no second worker
    for name in STRATEGIES:
        strat = get_strategy(name)
        assert strat.name == name
        assert strat.wire_bytes(s_p, 1) == 0.0
        assert strat.predicted_comm_time(s_p, 1, bw) == 0.0


def test_parameter_server_rejects_explicit_zero_servers():
    """n_servers=None defers to the dynamic N_ps = dp default; an explicit
    0 (or negative) must raise instead of silently falling back."""
    from repro.distributed.collectives import get_strategy

    assert get_strategy("parameter_server").n_servers is None
    assert get_strategy("parameter_server", n_servers=None).n_servers is None
    with pytest.raises(ValueError):
        get_strategy("parameter_server", n_servers=0)
    with pytest.raises(ValueError):
        get_strategy("parameter_server", n_servers=-2)


def test_hier_wire_bytes_by_tier():
    """Per-tier accounting of the reduction tree: the full payload moves
    in-node, only the 1/d_inner shard crosses nodes, and the total beats a
    flat ring's bottleneck-tier traffic."""
    from repro.core import ps
    from repro.distributed.collectives import get_strategy

    s_p = 1e9
    hier = get_strategy("hier_all_reduce", tiers=(4, 2))
    flat = get_strategy("all_reduce")
    by_tier = hier.wire_bytes_by_tier(s_p, 8)
    # tier 0 (in-node, 4 chips): RS + AG of the full payload
    assert by_tier[0] == pytest.approx(2.0 * s_p * 3 / 4)
    # tier 1 (cross-node, 2 nodes): only the 1/4 shard is exchanged
    assert by_tier[1] == pytest.approx(2.0 * (s_p / 4) * 1 / 2)
    assert sum(by_tier) == pytest.approx(hier.wire_bytes(s_p, 8))
    assert by_tier == ps.hier_wire_bytes(s_p, (4, 2))
    # the flat ring pushes its whole wire volume across every spanning tier
    flat_by_tier = get_strategy("all_reduce").wire_bytes_by_tier(s_p, 8)
    assert flat_by_tier == (flat.wire_bytes(s_p, 8),)
    # cross-node bytes: hier moves strictly less than flat
    assert by_tier[1] < flat.wire_bytes(s_p, 8)
    # dp=1: nothing anywhere
    assert all(w == 0.0 for w in hier.wire_bytes_by_tier(s_p, 1))
    # per-tier pricing: slow outer link dominates a uniform-bw pricing
    t_uniform = hier.predicted_comm_time(s_p, 8, 1e9)
    t_tiered = hier.predicted_comm_time(s_p, 8, 1e9, tier_bws=(1e9, 1e7))
    assert t_tiered > t_uniform


def test_compressor_registry_and_ratios():
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.compression import COMPRESSORS, get_compressor

    g = {"w": jnp.asarray(np.linspace(-1.0, 1.0, 64, dtype=np.float32))}
    for name in COMPRESSORS:
        comp = get_compressor(name)
        out, ef = comp.apply(g, None)
        assert out["w"].shape == g["w"].shape
        assert comp.wire_bytes(4.0 * 64) <= 4.0 * 64 + 1e-9
        if comp.stateful:
            assert ef is not None
            # error feedback exactly accounts for what compression dropped
            np.testing.assert_allclose(
                np.asarray(out["w"] + ef["w"]), np.asarray(g["w"]),
                rtol=1e-6, atol=1e-7)
        else:
            assert ef is None
    # bf16 rounding error bounded by ulp
    bf = get_compressor("bf16").apply(g, None)[0]["w"]
    assert float(jnp.max(jnp.abs(bf - g["w"]))) < 2 ** -8


def test_plan_resolves_to_runnable_strategy():
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import plan_train
    from repro.distributed.collectives import SyncStrategy

    p = plan_train(get_config("granite-3-2b"), get_shape("train_4k"))
    strat = p.resolve_sync()
    assert isinstance(strat, SyncStrategy)
    assert strat.name == p.sync_schedule
    assert p.grad_bytes > 0


# ---------------------------------------------------------------------------
# Multi-device numerics (8 simulated host devices, subprocess)
# ---------------------------------------------------------------------------

STRATEGY_BODY = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.distributed import DataParallelTrainer
from repro.launch.steps import build_train_step
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.optim.adamw import OptConfig, init_state

cfg = get_config("granite-3-2b").reduced().replace(
    vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128)
opt = OptConfig(lr=1e-3, warmup_steps=0)
run = RunConfig(attn_impl="dense", remat="none")

params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
state = init_state(opt, params)
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, (16, 32)).astype(np.int32)
batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
p1, s1, m1 = jax.jit(build_train_step(cfg, run, opt))(params, state, batch)

for strat in ("all_reduce", "reduce_scatter_all_gather", "parameter_server"):
    tr = DataParallelTrainer(cfg, run, opt, strategy=strat)
    p0, st0 = tr.init(0)
    b = {k: jax.device_put(v, NamedSharding(tr.mesh, P("data")))
         for k, v in batch.items()}
    p2, s2, m2 = tr.step_fn()(p0, st0, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5, atol=1e-6)
    # Adam normalizes by sqrt(v): near-zero grads amplify cross-shard
    # reduction-order noise; same window as test_distributed's sharded step
    for a, b_ in zip(jax.tree_util.tree_leaves(p1),
                     jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-3, atol=3e-3)
    print(strat, "matches baseline OK")
"""


def test_strategy_sync_means_match_global_mean(multi_device):
    """Fast tier-1 numerics, in-process on the 8 forced host devices:
    every strategy's sync, run under shard_map, returns exactly the
    data-axis mean of a random gradient pytree (the property that makes
    the trainer equivalent to the single-device baseline)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.distributed.collectives import STRATEGIES, get_strategy

    dp = 8
    mesh = Mesh(np.array(multi_device), ("data",))
    rng = np.random.default_rng(0)
    # per-device gradient stacks with awkward (non-divisible) leaf sizes
    gstack = {
        "w": jnp.asarray(rng.standard_normal((dp, 5, 7)), jnp.float32),
        "b": {"x": jnp.asarray(rng.standard_normal((dp, 13)), jnp.float32),
              "y": jnp.asarray(rng.standard_normal((dp, 3, 2, 2)),
                               jnp.float32)},
    }
    want = jax.tree_util.tree_map(lambda g: np.asarray(g).mean(0), gstack)

    # every strategy with defaults, plus PS with an explicit (non-dp,
    # non-divisible) server count; the bare parameter_server entry covers
    # the dynamic N_ps = dp default path
    combos = [(name, None) for name in STRATEGIES] + [("parameter_server", 3)]
    for name, n_servers in combos:
        strat = get_strategy(name, n_servers=n_servers)

        def sync_one(stack):
            local = jax.tree_util.tree_map(lambda x: x[0], stack)
            return strat.sync(local, "data", dp)

        got = jax.jit(shard_map(
            sync_one, mesh=mesh, in_specs=(P("data"),), out_specs=P()))(gstack)
        for w, g in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_allclose(w, np.asarray(g), rtol=1e-6, atol=1e-7)


def test_hier_all_reduce_mean_on_2x4_topology(multi_device):
    """The hierarchical strategy, run in-process over nested (nodes, data)
    shard_map axes on a simulated 2-node x 4-chip topology, returns exactly
    the global mean — same tolerance as the flat zoo — for both the
    topology-derived and an awkward adapted tier split."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.distributed.collectives import get_strategy

    dp = 8
    rng = np.random.default_rng(0)
    gstack = {
        "w": jnp.asarray(rng.standard_normal((dp, 5, 7)), jnp.float32),
        "b": {"x": jnp.asarray(rng.standard_normal((dp, 13)), jnp.float32),
              "y": jnp.asarray(rng.standard_normal((dp, 3, 2, 2)),
                               jnp.float32)},
    }
    want = jax.tree_util.tree_map(lambda g: np.asarray(g).mean(0), gstack)

    for tiers in ((4, 2), (2, 4)):  # 2 nodes x 4 chips, and the transpose
        strat = get_strategy("hier_all_reduce", tiers=tiers)
        inner = tiers[0]
        mesh = Mesh(np.array(multi_device).reshape(dp // inner, inner),
                    ("nodes", "data"))

        def sync_one(stack):
            local = jax.tree_util.tree_map(lambda x: x[0], stack)
            return strat.sync(local, ("nodes", "data"), dp)

        got = jax.jit(shard_map(
            sync_one, mesh=mesh, in_specs=(P(("nodes", "data")),),
            out_specs=P()))(gstack)
        for w, g in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_allclose(w, np.asarray(g), rtol=1e-6, atol=1e-7)


def test_trainer_hier_topology_in_process(multi_device):
    """End to end in-process: DataParallelTrainer builds the nested mesh
    from the named 2x4 cluster and reports the per-tier wire split."""
    from repro.configs.base import get_config
    from repro.core.hardware import get_cluster
    from repro.distributed import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    cfg = get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128)
    tr = DataParallelTrainer(cfg, RunConfig(attn_impl="dense", remat="none"),
                             OptConfig(lr=1e-3, warmup_steps=0),
                             strategy="hier_all_reduce",
                             devices=multi_device,
                             topology=get_cluster("2x4"))
    assert dict(tr.mesh.shape) == {"nodes": 2, "data": 4}
    assert tr.strategy.tiers == (4, 2)
    tr.train(batch=16, seq=32, steps=3, log_every=0)
    rep = tr.report()
    assert rep.tiers == (4, 2)
    assert len(rep.wire_bytes_by_tier) == 2
    assert abs(sum(rep.wire_bytes_by_tier) - rep.wire_bytes) < 1e-6
    assert rep.wire_bytes_by_tier[1] < rep.wire_bytes_by_tier[0]


@pytest.mark.slow
def test_all_strategies_match_single_device_baseline():
    out = run_sub(STRATEGY_BODY, devices=8)
    assert out.count("matches baseline OK") == 3


@pytest.mark.slow
def test_compression_variants_close_to_baseline():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.distributed import DataParallelTrainer
    from repro.launch.steps import build_train_step
    from repro.models import model as M
    from repro.models.blocks import RunConfig
    from repro.models.common import materialize
    from repro.optim.adamw import OptConfig, init_state

    cfg = get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128)
    opt = OptConfig(lr=1e-3, warmup_steps=0)
    run = RunConfig(attn_impl="dense", remat="none")
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    state = init_state(opt, params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (16, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    p1, _, m1 = jax.jit(build_train_step(cfg, run, opt))(params, state, batch)

    # documented looser tolerances: quantization error is bounded and fed back
    tols = {"bf16": 2e-2, "int8": 5e-2, "topk": 2e-1}
    for comp, atol in tols.items():
        tr = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                                 compression=comp)
        p0, st0 = tr.init(0)
        if tr.compressor.stateful:
            assert "ef" in st0
        b = {k: jax.device_put(v, NamedSharding(tr.mesh, P("data")))
             for k, v in batch.items()}
        p2, s2, m2 = tr.step_fn()(p0, st0, b)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5, atol=1e-6)
        for a, b_ in zip(jax.tree_util.tree_leaves(p1),
                         jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=atol, rtol=1e-1)
        if tr.compressor.stateful:
            ef_mag = max(float(jnp.max(jnp.abs(e)))
                         for e in jax.tree_util.tree_leaves(s2["ef"]))
            assert ef_mag > 0, "error feedback never engaged"
        print(comp, "OK")
    """, devices=8)


@pytest.mark.slow
def test_trainer_report_measured_vs_lemma():
    out = run_sub("""
    import json
    import jax
    from repro.configs.base import get_config
    from repro.distributed import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    cfg = get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128)
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=4)
    run = RunConfig(attn_impl="dense", remat="none")
    tr = DataParallelTrainer(cfg, run, opt, strategy="reduce_scatter_all_gather")
    res = tr.train(batch=16, seq=32, steps=4, log_every=0)
    rep = tr.report()
    assert rep.dp == 8 and rep.grad_bytes > 0
    assert rep.measured_comm_s > 0 and rep.predicted_comm_s > 0
    assert rep.measured_compute_s > 0
    # StepTimes carried the split phases
    assert all(t.dist_update > 0 for t in res.step_times)
    assert all(t.param_update > 0 for t in res.step_times)
    print("REPORT", json.dumps(rep.as_dict(), default=str))
    """, devices=8)
    assert "REPORT" in out
