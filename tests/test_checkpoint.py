"""Crash-safe elastic checkpointing (repro.checkpoint) + bounded-staleness
async PS (repro.distributed.async_ps).

Everything runs in-process on the 8 forced host devices (conftest pins
XLA_FLAGS before jax loads).  The io-level tests exercise the atomicity
protocol directly — torn steps, stale manifests, async races — and the
trainer-level tests check the two contracts the subsystem ships:

- staleness=0 is BIT-identical to the synchronous ``parameter_server``
  strategy (np.array_equal on every param leaf after K steps), and
- a killed run resumed from its checkpoint onto a *different* ``(dp,
  pipe)`` grid reproduces the uninterrupted loss trajectory to 1e-6.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, MANIFEST_SCHEMA_ID,
                              latest_step, restore, save, validate_manifest)
from repro.checkpoint import io as ckpt_io


def tiny_cfg():
    from repro.configs.base import get_config

    return get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, dtype="float32")


def run_opt(lr=1e-3):
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    return RunConfig(attn_impl="dense", remat="none"), \
        OptConfig(lr=lr, warmup_steps=0)


def leaves_equal(a, b):
    import jax

    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    return [np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(fa, fb)]


# ---------------------------------------------------------------------------
# io primitives: dtypes, atomicity, manifest
# ---------------------------------------------------------------------------


def test_dtype_roundtrip_fp32_bf16_int(tmp_path):
    import jax.numpy as jnp
    import ml_dtypes

    tree = {
        "w": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        "b": np.asarray(jnp.asarray([1.5, -2.25, 3e-2], jnp.bfloat16)),
        "step": np.asarray([7], np.int64),
        "mask": np.asarray([1, 0, 1], np.int32),
    }
    assert tree["b"].dtype == ml_dtypes.bfloat16  # the non-native case
    save(tree, str(tmp_path), step=3)

    template = {k: np.zeros_like(v) for k, v in tree.items()}
    out, step = restore(template, str(tmp_path))
    assert step == 3
    for k in tree:
        got = np.asarray(out[k])
        assert got.dtype == tree[k].dtype, k
        # bit-exact, not allclose: bf16 goes through the uint16 view
        assert np.array_equal(got.view(np.uint8), tree[k].view(np.uint8)), k

    # the step meta records the true dtype next to the stored bit-pattern
    meta = json.loads((tmp_path / "step_00000003.meta.json").read_text())
    validate_manifest(meta)
    assert meta["layout"]["b"]["dtype"] == "bfloat16"
    assert meta["layout"]["b"]["stored_dtype"] == "uint16"
    assert meta["layout"]["w"]["dtype"] == "float32"
    assert meta["layout"]["w"]["stored_dtype"] == "float32"


def test_manifest_validates_and_rejects_drift(tmp_path):
    save({"x": np.ones(2, np.float32)}, str(tmp_path), step=1)
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert validate_manifest(man)["step"] == 1
    assert man["schema"] == MANIFEST_SCHEMA_ID
    with pytest.raises(ValueError):
        validate_manifest({**man, "schema": "repro.checkpoint/manifest/v9"})
    with pytest.raises(ValueError):
        validate_manifest({**man, "step": -1})
    with pytest.raises(ValueError):
        validate_manifest({"schema": MANIFEST_SCHEMA_ID, "step": 0})


def test_crash_between_npz_and_meta_is_invisible(tmp_path):
    """A step whose meta never landed (crash mid-protocol) must be
    unobservable: latest_step skips it, restore refuses it."""
    save({"x": np.full(3, 1.0, np.float32)}, str(tmp_path), step=1)
    # simulate the crash: step 2's npz landed, meta did not
    np.savez(tmp_path / "step_00000002.npz", x=np.full(3, 2.0, np.float32))
    assert latest_step(str(tmp_path)) == 1
    with pytest.raises(FileNotFoundError):
        restore({"x": np.zeros(3, np.float32)}, str(tmp_path), step=2)
    out, step = restore({"x": np.zeros(3, np.float32)}, str(tmp_path))
    assert step == 1 and float(out["x"][0]) == 1.0


def test_stale_manifest_falls_back_to_directory_scan(tmp_path):
    """The manifest pointer is advisory: if its step's files were deleted
    (operator GC, partial rsync) the newest *complete* step wins."""
    save({"x": np.ones(2, np.float32)}, str(tmp_path), step=1)
    save({"x": np.full(2, 2.0, np.float32)}, str(tmp_path), step=2)
    os.remove(tmp_path / "step_00000002.npz")
    assert json.loads((tmp_path / "manifest.json").read_text())["step"] == 2
    assert latest_step(str(tmp_path)) == 1


def test_manifest_is_step_monotonic(tmp_path):
    """A slow save of an OLDER step landing after a newer one must not
    move the pointer backwards (the async-save race the seed-era code
    lost)."""
    d = ckpt_io.Path(str(tmp_path))
    save({"x": np.ones(2, np.float32)}, str(tmp_path), step=5)
    ckpt_io._write_step(d, 3, {"x": np.full(2, 3.0, np.float32)})
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["step"] == 5
    assert latest_step(str(tmp_path)) == 5
    # the old step is still restorable explicitly
    out, _ = restore({"x": np.zeros(2, np.float32)}, str(tmp_path), step=3)
    assert float(out["x"][0]) == 3.0


def test_restore_reports_missing_and_extra_keys(tmp_path):
    save({"a": np.ones(2, np.float32), "b": np.ones(2, np.float32)},
         str(tmp_path), step=1)
    with pytest.raises(ValueError) as e:
        restore({"a": np.zeros(2, np.float32),
                 "c": np.zeros(2, np.float32)}, str(tmp_path))
    msg = str(e.value)
    assert "c" in msg and "b" in msg  # one error names BOTH directions


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore({"x": np.zeros(2)}, str(tmp_path))
    assert latest_step(str(tmp_path)) is None


def test_tmp_files_never_observable(tmp_path):
    """Dead tmp files from a crashed writer are ignored by every reader."""
    save({"x": np.ones(2, np.float32)}, str(tmp_path), step=1)
    (tmp_path / "step_00000009.npz.tmp.12345").write_bytes(b"torn")
    (tmp_path / "manifest.json.tmp.12345").write_text("{")
    assert latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# CheckpointManager: serialized async saves
# ---------------------------------------------------------------------------


def test_async_saves_serialize_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in range(1, 6):
        mgr.save(s, {"x": np.full(4, float(s), np.float32)})
    mgr.wait()
    assert mgr.latest_step() == 5
    out, step = mgr.restore({"x": np.zeros(4, np.float32)})
    assert step == 5 and float(out["x"][0]) == 5.0
    # every step landed complete (serialized writer, no lost updates)
    assert [int(p.stem.split("_")[1])
            for p in sorted(tmp_path.glob("step_*.npz"))] == [1, 2, 3, 4, 5]
    mgr.close()
    mgr.close()  # idempotent


def test_async_save_snapshots_at_enqueue(tmp_path):
    """The caller may donate/mutate its arrays right after save():
    flattening happens on the calling thread at enqueue time."""
    mgr = CheckpointManager(str(tmp_path))
    arr = np.full(4, 1.0, np.float32)
    mgr.save(1, {"x": arr})
    arr[:] = -99.0  # mutate after enqueue, before the writer drains
    mgr.wait()
    out, _ = mgr.restore({"x": np.zeros(4, np.float32)})
    assert float(out["x"][0]) == 1.0
    mgr.close()


def test_async_rejects_non_monotonic_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, {"x": np.ones(2, np.float32)})
    with pytest.raises(ValueError):
        mgr.save(4, {"x": np.ones(2, np.float32)})
    with pytest.raises(ValueError):
        mgr.save(2, {"x": np.ones(2, np.float32)})
    mgr.close()


def test_async_writer_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "sub"))
    # non-array payload: np.savez pickles objects only with allow_pickle;
    # the writer thread fails and wait() must re-raise, not swallow
    mgr.save(1, {"x": object()})
    with pytest.raises(RuntimeError):
        mgr.wait()


# ---------------------------------------------------------------------------
# Elastic restore across device grids
# ---------------------------------------------------------------------------


def test_restore_is_topology_independent(tmp_path, multi_device):
    """One checkpoint, three targets: host arrays, a dp=4 mesh, a dp=2
    mesh — identical bits everywhere (the on-disk layout is logical)."""
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = {"w": np.arange(24, dtype=np.float32).reshape(4, 6),
            "b": np.ones(6, np.float32)}
    save(tree, str(tmp_path), step=1)

    host, _ = restore({k: np.zeros_like(v) for k, v in tree.items()},
                      str(tmp_path))
    for dp in (4, 2):
        mesh = Mesh(np.array(multi_device[:dp]), ("data",))
        rep = NamedSharding(mesh, P())
        tmpl = {k: jax.device_put(np.zeros_like(v), rep)
                for k, v in tree.items()}
        out, step = restore(tmpl, str(tmp_path))
        assert step == 1
        for k in tree:
            assert out[k].sharding.mesh == mesh  # landed on the target grid
            assert np.array_equal(np.asarray(out[k]), np.asarray(host[k]))
            assert np.array_equal(np.asarray(out[k]), tree[k])


# ---------------------------------------------------------------------------
# Trainer-level contracts (slower: real jitted steps on the forced axis)
# ---------------------------------------------------------------------------


def test_staleness_zero_bit_matches_synchronous(multi_device):
    """AsyncPSTrainer(staleness=0, backup_workers=0) IS the synchronous
    parameter_server trainer: same losses, bit-identical params after K
    steps."""
    from repro.distributed import AsyncPSTrainer, DataParallelTrainer

    cfg = tiny_cfg()
    run, opt = run_opt()
    devs = multi_device[:4]
    kw = dict(batch=4, seq=16, steps=4, seed=0, log_every=0)

    sync = DataParallelTrainer(cfg, run, opt, strategy="parameter_server",
                               devices=devs)
    ps, ss = sync.init(0)
    r_sync = sync.train(params=ps, opt_state=ss, **kw)

    anc = AsyncPSTrainer(cfg, run, opt, staleness=0, backup_workers=0,
                         devices=devs)
    pa, sa = anc.init(0)
    r_async = anc.train(params=pa, opt_state=sa, **kw)

    assert r_async.losses == r_sync.losses
    rep = anc.async_report()
    assert rep.max_age == 0 and rep.mean_age == 0.0 and rep.drops == 0


def test_staleness_bounds_measured_age(multi_device):
    from repro.distributed import AsyncPSTrainer

    cfg = tiny_cfg()
    run, opt = run_opt()
    tr = AsyncPSTrainer(cfg, run, opt, staleness=2, backup_workers=1,
                        devices=multi_device[:4])
    tr.train(batch=4, seq=16, steps=5, seed=0, log_every=0)
    rep = tr.async_report()
    assert 0 < rep.max_age <= 2          # the bound holds, and it binds
    assert 0.0 < rep.mean_age <= rep.max_age
    assert rep.drops == 1 * 5            # k grads dropped per step
    assert rep.t_step_model["pull"] == pytest.approx(
        rep.t_step_model["push"] / 3)    # pull amortized over s+1


def test_kill_and_resume_elastic_dp4_to_dp2(tmp_path, multi_device):
    """The acceptance trajectory: train dp=4 with checkpoints, 'kill' it
    mid-run, resume the SAME directory on dp=2 — the stitched loss curve
    matches an uninterrupted run to 1e-6."""
    from repro.distributed import DataParallelTrainer

    cfg = tiny_cfg()
    run, opt = run_opt()
    kw = dict(batch=4, seq=16, seed=0, log_every=0)
    ck = str(tmp_path / "ck")

    ref = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                              devices=multi_device[:4])
    losses_ref = ref.train(steps=6, **kw).losses

    # interrupted run: same recipe, checkpoints every 2 steps, killed at 4
    part = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                               devices=multi_device[:4])
    r1 = part.train(steps=4, ckpt_dir=ck, ckpt_every=2, **kw)
    assert r1.start_step == 0 and latest_step(ck) == 4

    # resume on HALF the grid; the loop auto-restores and fast-forwards
    resumed = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                                  devices=multi_device[:2])
    r2 = resumed.train(steps=6, ckpt_dir=ck, ckpt_every=2, **kw)
    assert r2.start_step == 4
    assert len(r2.losses) == 2
    np.testing.assert_allclose(r2.losses, losses_ref[4:], atol=1e-6)
    assert latest_step(ck) == 6


def test_kill_and_resume_pipe2_to_dp(tmp_path, multi_device):
    """Elastic across the OTHER axis: checkpoints written by a pipe=2
    pipeline run restore into a flat dp run (the 1F1B trainer is
    bit-identical to the data-parallel trainer on the same token stream,
    so the stitched trajectory must match its uninterrupted run)."""
    from repro.distributed import DataParallelTrainer, PipelineTrainer

    cfg = tiny_cfg().replace(num_layers=2)  # >= 1 layer cycle per stage
    run, opt = run_opt()
    kw = dict(batch=4, seq=16, seed=0, log_every=0)
    ck = str(tmp_path / "ck")

    ref = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                              devices=multi_device[:2])
    losses_ref = ref.train(steps=4, **kw).losses

    pipe = PipelineTrainer(cfg, run, opt, pipe=2, n_microbatch=2,
                           strategy="all_reduce", devices=multi_device[:4])
    rp = pipe.train(steps=2, ckpt_dir=ck, ckpt_every=2, **kw)
    np.testing.assert_allclose(rp.losses, losses_ref[:2], atol=1e-6)

    resumed = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                                  devices=multi_device[:2])
    r2 = resumed.train(steps=4, ckpt_dir=ck, ckpt_every=2, **kw)
    assert r2.start_step == 2
    np.testing.assert_allclose(r2.losses, losses_ref[2:], atol=1e-6)
