"""Property-style coverage for core/ps.py (Lemma 3.2) — plain parametrized
sweeps, no hypothesis dependency, so these always run in tier-1."""
import math

import pytest

from repro.core import ps


GRID_SP = (1e6, 1e8, 4e9)
GRID_NW = (1, 2, 8, 64)
GRID_BW = (1e9 / 8, 10e9 / 8, 100e9 / 8)
GRID_TC = (0.05, 0.5, 5.0)


@pytest.mark.parametrize("s_p", GRID_SP)
@pytest.mark.parametrize("n_w", GRID_NW)
@pytest.mark.parametrize("b_ps", GRID_BW)
@pytest.mark.parametrize("t_c", GRID_TC)
def test_masked_iff_io_fits_in_compute(s_p, n_w, b_ps, t_c):
    """`masked` ⇔ io_time <= t_c, and the Lemma-sized server count always
    achieves masking (that is the inequality's whole point)."""
    n_ps = ps.n_parameter_servers(s_p, n_w, b_ps, t_c)
    assert ps.masked(s_p, n_w, n_ps, b_ps, t_c) == (
        ps.io_time(s_p, n_w, n_ps, b_ps) <= t_c)
    assert ps.masked(s_p, n_w, n_ps, b_ps, t_c), (
        "Lemma 3.2's own N_ps must hide I/O behind compute")
    # one server fewer must NOT mask (minimality), unless already at 1 or the
    # ceil'd bound exceeds the exact bound only by rounding
    if n_ps > 1 and not ps.masked(s_p, n_w, n_ps - 1, b_ps, t_c):
        assert ps.io_time(s_p, n_w, n_ps - 1, b_ps) > t_c


def test_n_parameter_servers_monotone_in_n_w_and_s_p():
    b_ps, t_c = 10e9 / 8, 0.5
    prev = 0
    for n_w in sorted(GRID_NW):
        cur = ps.n_parameter_servers(1e9, n_w, b_ps, t_c)
        assert cur >= prev
        prev = cur
    prev = 0
    for s_p in sorted(GRID_SP):
        cur = ps.n_parameter_servers(s_p, 16, b_ps, t_c)
        assert cur >= prev
        prev = cur


def test_n_parameter_servers_validates_inputs():
    with pytest.raises(ValueError):
        ps.n_parameter_servers(1e9, 4, 0.0, 1.0)
    with pytest.raises(ValueError):
        ps.n_parameter_servers(1e9, 4, 1e9, 0.0)
    assert ps.n_parameter_servers(0.0, 4, 1e9, 1.0) == 1  # floor at 1


def test_io_time_scales_inversely_with_servers():
    t1 = ps.io_time(1e9, 16, 1, 1e9)
    for n in (2, 4, 8):
        assert math.isclose(ps.io_time(1e9, 16, n, 1e9), t1 / n, rel_tol=1e-12)


def test_tpu_grad_sync_plan_dp1_edge():
    """dp=1: no data axis, zero wire bytes, always masked."""
    plan = ps.tpu_grad_sync_plan(8e9, 1, 1e11, t_c=0.001)
    assert plan.comm_time == 0.0
    assert plan.masked
    assert "0.00 GB" in plan.note


@pytest.mark.parametrize("dp", (2, 4, 16, 256))
def test_tpu_grad_sync_plan_wire_accounting(dp):
    param_bytes, bw = 8e9, 1e11
    plan = ps.tpu_grad_sync_plan(param_bytes, dp, bw, t_c=1.0)
    wire = 2.0 * param_bytes * (dp - 1) / dp
    assert math.isclose(plan.comm_time, wire / bw, rel_tol=1e-12)
    assert f"dp={dp}" in plan.note
    # schedule flag flips with zero_sharded
    assert plan.schedule == "reduce_scatter_all_gather"
    assert ps.tpu_grad_sync_plan(param_bytes, dp, bw, t_c=1.0,
                                 zero_sharded=False).schedule == "all_reduce"


def test_predicted_comm_time_consistency():
    """The runnable-schedule predictions agree with the closed forms."""
    s_p, dp, bw = 2e9, 8, 1e10
    ar = ps.predicted_comm_time("all_reduce", s_p, dp, bw)
    rs = ps.predicted_comm_time("reduce_scatter_all_gather", s_p, dp, bw)
    assert ar == rs == 2.0 * s_p * (dp - 1) / dp / bw
    # PS defaults to N_ps = dp; explicit n_ps follows Eq. 7
    assert ps.predicted_comm_time("parameter_server", s_p, dp, bw) == \
        ps.io_time(s_p, dp, dp, bw)
    assert ps.predicted_comm_time("parameter_server", s_p, dp, bw, n_ps=4) == \
        ps.io_time(s_p, dp, 4, bw)
    with pytest.raises(KeyError):
        ps.predicted_comm_time("bogus", s_p, dp, bw)
