"""Planner-core tests: faithful paper math (Table 2, Lemma 3.1/3.2, Eq. 6 ILP)
plus hypothesis property tests on the solvers."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import amdahl, ilp, memory_model as mm, ps
from repro.core.pipeline import StepTimes, multi_device_speedup


# ---------------------------------------------------------------------------
# Paper-faithful checks
# ---------------------------------------------------------------------------


def test_table2_ratios_close_to_paper():
    """FFT/GEMM conv memory ratios vs paper Table 2 (<=20% rel. error)."""
    for row, paper in mm.TABLE2_ROWS:
        gemm, fft = mm.conv_alg_memory(*row)
        ours = fft / gemm
        assert abs(ours - paper) / paper < 0.20, (row, ours, paper)


def test_table2_first_layer_near_exact():
    (row, paper) = mm.TABLE2_ROWS[0]
    gemm, fft = mm.conv_alg_memory(*row)
    assert abs(fft / gemm - paper) / paper < 0.02


def test_alexnet_feature_shapes():
    shapes = mm.feature_shapes(mm.ALEXNET)
    assert shapes[1] == (55, 55, 96)
    assert shapes[2] == (27, 27, 96)
    assert shapes[3] == (27, 27, 256)
    assert shapes[-1] == (6, 6, 256)


def test_alexnet_param_count_order():
    # conv params ~3.7M; classifier ~58.6M (the AlexNet split)
    conv_params = mm.m_mp(mm.ALEXNET) / (3 * 32)
    fc_params = sum(
        mm.ALEXNET.fc[j] * mm.ALEXNET.fc[j + 1]
        for j in range(len(mm.ALEXNET.fc) - 1))
    assert 3.0e6 < conv_params < 4.5e6
    assert 5.5e7 < fc_params < 6.5e7


def test_lemma31_paper_examples():
    # alpha = (1+R_O)/(1+G R_O); paper: 4 GPUs, alpha=0.8 -> R_O <= ~9%
    assert abs(amdahl.max_overhead_for(4, 0.8) - 1 / 11) < 1e-9
    # paper: R_O = 10%, 3x speedup -> G = 4
    assert amdahl.devices_for_speedup(3.0, 0.10) == 4


def test_lemma31_matches_amdahl_identity():
    for g in (1, 2, 4, 8, 64):
        for r in (0.0, 0.05, 0.3, 1.0):
            a = amdahl.efficiency(g, r)
            p = 1.0 / (1.0 + r)  # parallelizable fraction
            amdahl_speedup = 1.0 / ((1 - p) + p / g)
            assert math.isclose(a * g, amdahl_speedup, rel_tol=1e-9)


def test_lemma32_alexnet_example():
    """Paper §3.3: AlexNet push ~180 MB; on 1 Gbit Ethernet even one worker
    cannot be masked behind a sub-second T_C -> N_ps must exceed 1."""
    s_p = 180e6
    n = ps.n_parameter_servers(s_p, n_w=1, b_ps=1e9 / 8, t_c=1.0)
    assert n >= 3  # 2*180MB / 125MB/s = 2.88 s of traffic per second
    assert ps.masked(s_p, 1, n, 1e9 / 8, 1.0)
    assert not ps.masked(s_p, 1, n - 1, 1e9 / 8, 1.0)


def test_lemma32_monotonicity():
    base = ps.n_parameter_servers(1e9, 8, 1e9, 1.0)
    assert ps.n_parameter_servers(2e9, 8, 1e9, 1.0) >= base  # more params
    assert ps.n_parameter_servers(1e9, 16, 1e9, 1.0) >= base  # more workers
    assert ps.n_parameter_servers(1e9, 8, 2e9, 1.0) <= base  # more bandwidth
    assert ps.n_parameter_servers(1e9, 8, 1e9, 2.0) <= base  # longer compute


# ---------------------------------------------------------------------------
# ILP (Eq. 6)
# ---------------------------------------------------------------------------


def _random_layers(rng, n_layers, n_algs):
    layers = []
    for k in range(n_layers):
        choices = []
        for l in range(n_algs):
            t = float(rng.uniform(0.1, 10.0))
            m = float(rng.uniform(1.0, 100.0))
            choices.append(ilp.Choice(f"a{l}", t, m))
        layers.append(choices)
    return layers


def _brute_force(layers, m_bound):
    import itertools
    best_t, best = math.inf, None
    for picks in itertools.product(*[range(len(c)) for c in layers]):
        m = sum(layers[k][l].memory for k, l in enumerate(picks))
        if m > m_bound:
            continue
        t = sum(layers[k][l].time for k, l in enumerate(picks))
        if t < best_t:
            best_t, best = t, picks
    return best_t


@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(2, 3),
       st.floats(0.1, 1.0))
@settings(max_examples=40, deadline=None)
def test_ilp_bnb_exact_vs_bruteforce(seed, n_layers, n_algs, tightness):
    rng = np.random.default_rng(seed)
    layers = _random_layers(rng, n_layers, n_algs)
    min_m = sum(min(c.memory for c in ch) for ch in layers)
    max_m = sum(max(c.memory for c in ch) for ch in layers)
    m_bound = min_m + tightness * (max_m - min_m)
    sol = ilp.solve_ilp(layers, m_bound)
    want = _brute_force(layers, m_bound)
    assert sol.feasible
    assert sol.memory <= m_bound + 1e-9
    assert math.isclose(sol.time, want, rel_tol=1e-9)


@given(st.integers(0, 10_000), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_ilp_dp_feasible_and_close(seed, n_layers):
    rng = np.random.default_rng(seed)
    layers = _random_layers(rng, n_layers, 2)
    min_m = sum(min(c.memory for c in ch) for ch in layers)
    m_bound = min_m * 1.5
    exact = ilp.solve_ilp(layers, m_bound)
    approx = ilp.solve_ilp_dp(layers, m_bound, buckets=8192)
    assert approx.feasible
    assert approx.memory <= m_bound + 1e-9
    # DP discretizes memory upward -> may be slightly conservative
    assert approx.time >= exact.time - 1e-9
    assert approx.time <= exact.time * 1.2 + 1e-9


def test_ilp_infeasible_flagged():
    layers = [[ilp.Choice("x", 1.0, 10.0)]]
    sol = ilp.solve_ilp(layers, 5.0)
    assert not sol.feasible


# ---------------------------------------------------------------------------
# Pipeline model
# ---------------------------------------------------------------------------


def test_pipeline_hides_io_behind_compute():
    t = StepTimes(data_load=0.05, data_prep=0.03, h2d=0.02, compute=0.5)
    assert t.r_o() == 0.0  # io sum 0.1 < compute 0.5 -> fully hidden
    assert t.r_o(pipelined=False) > 0.19


def test_pipeline_simulator_monotone_in_g():
    t = StepTimes(data_load=0.02, h2d=0.01, compute=0.3, param_update=0.02)
    sp = [multi_device_speedup(t, g) for g in (1, 2, 4, 8)]
    assert sp[0] == pytest.approx(1.0, rel=0.05)
    assert all(sp[i] <= sp[i + 1] + 1e-6 for i in range(len(sp) - 1))
    # saturation: speedup capped by Amdahl ceiling
    r_o = t.r_o()


@given(st.floats(0.01, 0.5), st.floats(0.1, 1.0))
@settings(max_examples=20, deadline=None)
def test_simulated_speedup_below_lemma_estimate(io, comp):
    """Lemma 3.1 with R_O measured from the same StepTimes should upper-bound
    the simulated weak-scaling speedup (shared-bus contention only hurts)."""
    t = StepTimes(data_load=io, compute=comp, param_update=io / 4)
    for g in (2, 4, 8):
        sim = multi_device_speedup(t, g)
        est = amdahl.speedup(g, t.r_o(pipelined=True))
        assert sim <= est * 1.25 + 0.3
