"""Substrate tests: data pipeline, optimizer, checkpoint, train loop, serving."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import PrefetchLoader, SyntheticCorpus
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.optim import adamw as opt_lib
from repro.serve.engine import BatchScheduler, Engine
from repro.train.loop import train
from repro.checkpoint import io as ckpt_io


def tiny_cfg():
    return get_config("granite-3-2b").reduced().replace(vocab_size=256)


def test_synthetic_corpus_deterministic(tmp_path):
    c1 = SyntheticCorpus(512, shard_tokens=1024, seed=3)
    c2 = SyntheticCorpus(512, shard_tokens=1024, seed=3,
                         cache_dir=str(tmp_path))
    a, b = c1.load_shard(0), c2.load_shard(0)
    np.testing.assert_array_equal(a, b)
    # second read comes from disk, must be identical
    np.testing.assert_array_equal(b, c2.load_shard(0))
    assert (tmp_path / "shard_00000.npy").exists()


def test_prefetch_loader_shapes_and_times():
    cfg = tiny_cfg()
    loader = PrefetchLoader(cfg, batch=4, seq=32)
    try:
        batch, times = next(loader)
        assert batch["tokens"].shape == (4, 32)
        assert batch["labels"].shape == (4, 32)
        assert times.data_load >= 0 and times.h2d >= 0
        # labels are the shifted stream
        b2, _ = next(loader)
        assert not np.array_equal(np.asarray(batch["tokens"]),
                                  np.asarray(b2["tokens"]))
    finally:
        loader.close()


def test_optimizer_reduces_loss_quadratic():
    opt = opt_lib.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_lib.init_state(opt, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = opt_lib.apply_updates(opt, params, g, state)
    assert float(loss(params)) < 0.2


def test_momentum_optimizer_runs():
    opt = opt_lib.OptConfig(kind="momentum", lr=0.05, warmup_steps=0,
                            weight_decay=0.0)
    params = {"w": jnp.array([1.0])}
    state = opt_lib.init_state(opt, params)
    g = {"w": jnp.array([2.0])}
    params, state, _ = opt_lib.apply_updates(opt, params, g, state)
    assert "v" not in state and "m" in state


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    ckpt_io.save(params, str(tmp_path), step=7)
    assert ckpt_io.latest_step(str(tmp_path)) == 7
    restored, step = ckpt_io.restore(params, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_loop_loss_decreases():
    cfg = tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none")
    opt = opt_lib.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    res = train(cfg, run, opt, batch=8, seq=64, steps=40, log_every=0)
    first = float(np.mean(res.losses[:5]))
    last = float(np.mean(res.losses[-5:]))
    assert last < first - 0.25, (first, last)
    assert res.tokens_per_s > 0
    assert 0 <= res.mean_r_o < 10


def test_train_microbatch_equivalent_shapes():
    cfg = tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none", microbatch=2)
    opt = opt_lib.OptConfig(lr=1e-3)
    res = train(cfg, run, opt, batch=4, seq=32, steps=3, log_every=0)
    assert len(res.losses) == 3
    assert np.isfinite(res.losses).all()


def test_engine_greedy_matches_teacher_forcing():
    """Engine decode must agree with full-forward argmax continuation."""
    cfg = tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none")
    eng = Engine(cfg, run, s_max=64, seed=1)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
    res = eng.generate(prompt, n_new=4)
    assert res.tokens.shape == (2, 4)

    # teacher forcing: append generated tokens, recompute logits
    full = np.concatenate([prompt, res.tokens], axis=1)
    logits, _, _ = M.forward(eng.params, {"tokens": jnp.asarray(full)}, cfg, run)
    for t in range(4):
        want = np.argmax(np.asarray(logits[:, 12 + t - 1]), axis=-1)
        np.testing.assert_array_equal(res.tokens[:, t], want)


def test_engine_ragged_batch_masking():
    """Right-padded ragged prompts must not leak pad tokens into shorter
    examples (per-example pos masking)."""
    cfg = tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none")
    eng = Engine(cfg, run, s_max=64, seed=2)
    rng = np.random.default_rng(1)
    p_short = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)

    solo = np.zeros((1, 8), np.int32)
    solo[0] = p_short
    r_solo = eng.generate(solo, n_new=3)

    padded = np.zeros((2, 16), np.int32)
    padded[0, :8] = p_short
    padded[1] = rng.integers(0, cfg.vocab_size, (16,))
    r_batch = eng.generate(padded, n_new=3,
                           lengths=np.array([8, 16], np.int32))
    np.testing.assert_array_equal(r_batch.tokens[0], r_solo.tokens[0])


def test_scheduler_runs_ragged_requests():
    cfg = tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none")
    eng = Engine(cfg, run, s_max=64, seed=3)
    sched = BatchScheduler(eng, max_batch=3)
    rng = np.random.default_rng(2)
    rids = [sched.submit(rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32), 4)
            for n in (5, 9, 13, 7)]
    results = sched.run()
    assert set(results) == set(rids)
    assert all(v.shape == (4,) for v in results.values())


def test_engine_swa_ring_cache():
    """gemma2-family reduced config exercises the ring-buffer SWA cache."""
    cfg = get_config("gemma2-27b").reduced().replace(sliding_window=16)
    run = RunConfig(attn_impl="dense", remat="none")
    eng = Engine(cfg, run, s_max=48, seed=4)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    res = eng.generate(prompt, n_new=4)

    full = np.concatenate([prompt, res.tokens], axis=1)
    logits, _, _ = M.forward(eng.params, {"tokens": jnp.asarray(full)}, cfg, run)
    for t in range(4):
        want = np.argmax(np.asarray(logits[:, 24 + t - 1]), axis=-1)
        np.testing.assert_array_equal(res.tokens[:, t], want)
