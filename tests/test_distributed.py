"""Distributed-correctness tests. Each test runs in a subprocess with
--xla_force_host_platform_device_count set (the parent pytest process has
already locked jax to 1 device)."""
from conftest import run_sub


def test_moe_sharded_matches_baseline():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import moe
    from repro.models.common import materialize

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("jamba-1.5-large-398b").reduced().replace(
        num_experts=8, top_k=2, moe_d_ff=64, d_model=64)
    specs = moe.moe_specs(cfg, 1)
    p = materialize(specs, jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda a: a[0], p)  # drop layer dim

    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)
    base, aux_b = moe.moe_mlp(p, x, cfg, capacity_factor=8.0)

    xs = jax.device_put(x, NamedSharding(mesh, P(("data",), "model", None)))
    ps = {k: jax.device_put(v, NamedSharding(mesh, P("model", None, None)))
          for k, v in p.items() if k != "router"}
    ps["router"] = jax.device_put(p["router"], NamedSharding(mesh, P()))
    out, aux_s = jax.jit(lambda pp, xx: moe.moe_mlp_sharded(
        pp, xx, cfg, mesh=mesh, capacity_factor=8.0))(ps, xs)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                               rtol=2e-5, atol=2e-5)
    # aux is a mean-based estimator: per-dp-shard aux averaged != global aux
    # exactly (nonlinear in the token partition); 2% window
    np.testing.assert_allclose(float(aux_b), float(aux_s), rtol=2e-2)
    print("moe sharded == baseline OK")
    """)


def test_sharded_train_step_matches_single_device():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_train_step
    from repro.models import model as M
    from repro.models.blocks import RunConfig
    from repro.models.common import materialize, partition_specs
    from repro.optim.adamw import OptConfig, init_state

    cfg = get_config("granite-3-2b").reduced().replace(vocab_size=512)
    opt = OptConfig(lr=1e-3, warmup_steps=0)
    run = RunConfig(attn_impl="dense", remat="none")
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    state = init_state(opt, params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    # single-device reference
    p1, s1, m1 = jax.jit(build_train_step(cfg, run, opt))(params, state, batch)

    # sharded on a (2,4) mesh with the production rules + seq parallel
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = mesh_lib.sharding_rules(mesh, cfg, None, fsdp=True)
    pspecs = partition_specs(M.model_specs(cfg), rules)
    params_s = jax.tree_util.tree_map(
        lambda a, ps: jax.device_put(a, NamedSharding(mesh, ps)), params, pspecs)
    state_s = {"step": state["step"],
               "m": jax.tree_util.tree_map(
                   lambda a, ps: jax.device_put(a, NamedSharding(mesh, ps)),
                   state["m"], pspecs),
               "v": jax.tree_util.tree_map(
                   lambda a, ps: jax.device_put(a, NamedSharding(mesh, ps)),
                   state["v"], pspecs)}
    batch_s = {k: jax.device_put(v, NamedSharding(mesh, P(("data",), None)))
               for k, v in batch.items()}
    run_s = RunConfig(attn_impl="dense", remat="none",
                      act_sharding=NamedSharding(mesh, P(("data",), "model", None)))
    from repro.compat import set_mesh
    with set_mesh(mesh):
        p2, s2, m2 = jax.jit(build_train_step(cfg, run_s, opt))(
            params_s, state_s, batch_s)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4, atol=1e-5)
    # Adam normalizes by sqrt(v): for near-zero grads the update direction is
    # sensitive to cross-shard reduction order, so allow ~3 LR units of slack
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=3e-3)
    print("sharded train step == single device OK")
    """, devices=8)


def test_hlo_collective_accounting_known_program():
    run_sub("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo

    mesh = jax.make_mesh((4,), ("x",))

    def f(a):  # force an all-reduce of a (256, 256) f32 = 256 KiB operand
        return jnp.sum(a * a)

    arr = jax.ShapeDtypeStruct((256, 1024), jnp.float32,
                               sharding=NamedSharding(mesh, P("x", None)))
    comp = jax.jit(f).lower(arr).compile()
    stats = hlo.collective_bytes(comp.as_text())
    assert "all-reduce" in stats, stats.keys()
    # the final scalar all-reduce is 4 bytes; wire = 2*4*(3/4) = 6
    wire = stats["all-reduce"]["wire_bytes"]
    assert 0 < wire < 1024, wire
    print("hlo accounting OK", stats)
    """, devices=4)


def test_dryrun_single_combo_small_mesh():
    """End-to-end dryrun machinery on a small mesh (reduced arch)."""
    run_sub("""
    import jax, json
    from repro.configs.base import get_config, get_shape, ShapeConfig
    from repro.launch import dryrun as D
    from repro.launch import mesh as mesh_lib
    import repro.launch.mesh as ml

    # monkeypatch a small production mesh
    ml.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
        (2, 4), ("data", "model"))

    cfg = get_config("granite-3-2b").reduced()
    import repro.configs.base as base
    orig = base.get_config
    base.get_config = lambda a: cfg
    shape = ShapeConfig("smoke_train", 128, 8, "train")
    base.SHAPES["smoke_train"] = shape

    ok = D.run_one("granite-3-2b", "smoke_train", "single", "/tmp/dryrun_test")
    assert ok
    rec = json.loads(open(
        "/tmp/dryrun_test/granite-3-2b__smoke_train__single.json").read())
    assert rec["derived"]["flops"] > 0
    assert rec["full"]["memory"]["argument_bytes"] > 0
    print("dryrun smoke OK")
    """, devices=8)
