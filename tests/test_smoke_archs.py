"""Per-architecture smoke tests: a REDUCED variant of each assigned arch
(≤2-slot pattern, d_model≤512, ≤4 experts) runs one forward/train step and
one prefill→decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize

BS, SEQ = 2, 128


def make_batch(cfg, key):
    kt, ki = jax.random.split(key)
    shape = (BS, SEQ, cfg.num_codebooks) if cfg.num_codebooks else (BS, SEQ)
    tokens = jax.random.randint(kt, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            ki, (BS, cfg.num_image_tokens, cfg.d_model), jnp.float32
        ) * 0.02
    return batch


@pytest.fixture(scope="module")
def run():
    return RunConfig(attn_impl="auto", remat="block")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, run):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = materialize(M.model_specs(cfg), key)
    batch = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg, run), has_aux=True
    )(params)

    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # loss should start near ln(V)
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 2.0
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), f"{arch}: NaN grads"
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, run):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = materialize(M.model_specs(cfg), key)
    batch = make_batch(cfg, key)

    logits, _, _ = M.forward(params, batch, cfg, run)
    S_total = SEQ + (cfg.num_image_tokens or 0)
    if cfg.num_codebooks:
        assert logits.shape == (BS, S_total, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (BS, S_total, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # decode one step from an empty cache at pos 0
    caches = materialize(M.cache_specs(cfg, BS, s_max=64), jax.random.PRNGKey(2))
    caches = jax.tree_util.tree_map(jnp.zeros_like, caches)
    tok = (
        batch["tokens"][:, :1]
        if not cfg.num_codebooks
        else batch["tokens"][:, :1, :]
    )
    pos = jnp.zeros((BS,), jnp.int32)
    dlogits, new_caches = M.decode_step(params, tok, pos, caches, cfg, run)
    if cfg.num_codebooks:
        assert dlogits.shape == (BS, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert dlogits.shape == (BS, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(dlogits, np.float32)))
    # cache was actually written
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), caches, new_caches
    )
    assert any(jax.tree_util.tree_leaves(changed)), f"{arch}: cache not updated"


def test_decode_matches_forward_gqa():
    """Teacher-forced decode must reproduce full-seq logits (granite, no image)."""
    cfg = get_config("granite-3-2b").reduced()
    run = RunConfig(attn_impl="dense", remat="none")
    key = jax.random.PRNGKey(3)
    params = materialize(M.model_specs(cfg), key)
    S = 16
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    full_logits, _, _ = M.forward(params, {"tokens": tokens}, cfg, run)

    caches = jax.tree_util.tree_map(
        jnp.zeros_like,
        materialize(M.cache_specs(cfg, 1, s_max=S), key),
    )
    outs = []
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))
    for i in range(S):
        lg, caches = step(params, tokens[:, i : i + 1], jnp.array([i]), caches)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_matches_forward_ssm():
    """Same consistency check for the mamba2 (SSD) path."""
    cfg = get_config("mamba2-780m").reduced()
    # chunk must divide S for the forward path
    cfg = cfg.replace(ssm_chunk=8)
    run = RunConfig(attn_impl="dense", remat="none")
    key = jax.random.PRNGKey(4)
    params = materialize(M.model_specs(cfg), key)
    S = 16
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    full_logits, _, _ = M.forward(params, {"tokens": tokens}, cfg, run)

    caches = jax.tree_util.tree_map(
        jnp.zeros_like, materialize(M.cache_specs(cfg, 1, s_max=S), key)
    )
    outs = []
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))
    for i in range(S):
        lg, caches = step(params, tokens[:, i : i + 1], jnp.array([i]), caches)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec_logits, np.float32),
        rtol=5e-2, atol=5e-2,
    )
