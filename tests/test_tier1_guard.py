"""tier-1 keeps itself honest: the budget guard's static marker scan runs
*inside* the fast tier, so a new subprocess test that forgets its ``slow``
marker fails the suite immediately (the wall-clock half of the guard runs
in CI on the junitxml report — see tools/test_budget.py and ci.yml)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import test_budget  # noqa: E402  (tools/test_budget.py)


def test_no_unmarked_subprocess_tests():
    violations = test_budget.check_markers()
    assert not violations, "\n".join(violations)


def test_marker_scan_catches_violations(tmp_path, monkeypatch):
    """The scanner itself works: an unmarked run_sub test is flagged, a
    slow-marked or module-slow one is not."""
    tdir = tmp_path / "tests"
    tdir.mkdir()
    (tdir / "test_bad.py").write_text(
        "from conftest import run_sub\n"
        "def test_spawns():\n    run_sub('print(1)')\n")
    (tdir / "test_ok.py").write_text(
        "import pytest\nfrom conftest import run_sub\n"
        "@pytest.mark.slow\ndef test_spawns():\n    run_sub('print(1)')\n"
        "def test_pure():\n    assert 1\n")
    (tdir / "test_module_slow.py").write_text(
        "import pytest, subprocess\npytestmark = pytest.mark.slow\n"
        "def test_spawns():\n    subprocess.run(['true'])\n")
    # import-alias evasions are caught too
    (tdir / "test_alias.py").write_text(
        "import subprocess as sp\n"
        "def test_spawns():\n    sp.run(['true'])\n")
    (tdir / "test_from_import.py").write_text(
        "from subprocess import run\n"
        "def test_spawns():\n    run(['true'])\n")
    monkeypatch.setattr(test_budget, "TESTS_DIR", tdir)
    monkeypatch.setattr(test_budget, "ALLOW_FAST_SUBPROCESS", set())
    violations = "\n".join(test_budget.check_markers())
    assert "test_bad.py::test_spawns" in violations
    assert "test_alias.py::test_spawns" in violations
    assert "test_from_import.py::test_spawns" in violations
    assert "test_ok.py" not in violations
    assert "test_module_slow.py" not in violations


def test_budget_check_reads_junit(tmp_path):
    junit = tmp_path / "tier1.xml"
    junit.write_text(
        '<testsuites><testsuite>'
        '<testcase classname="tests.test_a" name="test_x" time="1.5"/>'
        '<testcase classname="tests.test_a" name="test_y" time="2.0"/>'
        '</testsuite></testsuites>')
    assert test_budget.check_budget(junit, budget_s=10.0) == []
    over = test_budget.check_budget(junit, budget_s=3.0)
    assert len(over) == 1 and "3.5s" in over[0]
