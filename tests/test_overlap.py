"""Overlapped gradient synchronization (repro.distributed.overlap + the
overlap-aware cost model): bucketing is a partition and survives Plan JSON,
the overlapped execution path bit-matches the serial 3-phase trainer, the
measured overlap stays in [0, 1] with exposed comm below serial comm, and
``estimate_step_time(sync_overlap=True)`` never prices above the serial
formula (degrading to it exactly when overlap is off)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import ps
from repro.distributed.overlap import (BucketPlan, DEFAULT_BUCKET_MB,
                                       bucket_leaves, build_bucket_plan,
                                       leaf_sizes_bytes, mb_to_bytes,
                                       unbucket_leaves)


def _tree(sizes):
    """A nested pytree with the given per-leaf element counts (np arrays:
    build_bucket_plan only reads shapes)."""
    leaves = [np.zeros((n,), np.float32) for n in sizes]
    return {"a": leaves[0], "b": {"c": leaves[1:3], "d": leaves[3:]}} \
        if len(sizes) > 3 else leaves


# ---------------------------------------------------------------------------
# BucketPlan: partition property + serialization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bucket_bytes", [1.0, 64.0, 4096.0, 1e9])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bucket_plan_is_partition(bucket_bytes, seed):
    """Every leaf lands in exactly one bucket, buckets walk the flatten
    order backwards (grad-availability order), and bucket/unbucket is the
    identity."""
    rng = np.random.default_rng(seed)
    sizes = [int(n) for n in rng.integers(1, 2000, size=rng.integers(1, 24))]
    tree = _tree(sizes)
    plan = build_bucket_plan(tree, bucket_bytes)

    flat = [i for b in plan.buckets for i in b]
    assert sorted(flat) == list(range(plan.n_leaves))       # partition
    assert flat == list(range(plan.n_leaves - 1, -1, -1))   # reverse order
    assert plan.total_bytes == sum(plan.leaf_bytes) == sum(plan.sizes_bytes)
    assert plan.leaf_bytes == leaf_sizes_bytes(tree)
    # cap semantics: no bucket exceeds the cap unless a single leaf does
    # on its own, and each bucket is maximal (the next bucket's first leaf
    # would have pushed it past the cap)
    for b, size in zip(plan.buckets, plan.sizes_bytes):
        assert size <= plan.bucket_bytes or len(b) == 1
    for (b, size), nxt in zip(zip(plan.buckets, plan.sizes_bytes),
                              plan.buckets[1:]):
        assert size + plan.leaf_bytes[nxt[0]] > plan.bucket_bytes
    # the size-level model count is a lower bound on the leaf-level count
    # when no single leaf overflows the cap on its own
    if all(lb <= plan.bucket_bytes for lb in plan.leaf_bytes):
        import math
        assert plan.n_buckets >= max(
            math.ceil(plan.total_bytes / plan.bucket_bytes), 1)

    import jax

    leaves = jax.tree_util.tree_leaves(tree)
    rt = unbucket_leaves(bucket_leaves(leaves, plan), plan)
    assert all(a is b for a, b in zip(leaves, rt))          # order restored


def test_bucket_plan_validation():
    with pytest.raises(ValueError):
        BucketPlan(bucket_bytes=64.0, buckets=((0, 1), (1, 2)),
                   leaf_bytes=(4.0, 4.0, 4.0))  # leaf 1 twice
    with pytest.raises(ValueError):
        BucketPlan(bucket_bytes=64.0, buckets=((0,),),
                   leaf_bytes=(4.0, 4.0))       # leaf 1 missing
    with pytest.raises(ValueError):
        BucketPlan(bucket_bytes=0.0, buckets=((0,),), leaf_bytes=(4.0,))
    with pytest.raises(ValueError):
        build_bucket_plan(_tree([4, 4]), 0.0)
    plan = build_bucket_plan(_tree([10, 20, 30]), 64.0)
    with pytest.raises(ValueError):
        bucket_leaves([1, 2], plan)             # wrong leaf count
    with pytest.raises(ValueError):
        unbucket_leaves([[1]], plan)            # wrong bucket count


def test_bucket_plan_order_stable_roundtrip_through_plan_json():
    """The leaf-level BucketPlan survives a Plan JSON round trip with
    bucket *order* intact (the grad-availability order is the schedule)."""
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import Plan, plan_train

    tree = _tree([100, 300, 50, 1200, 7, 900])
    bp = build_bucket_plan(tree, 1000 * 4.0)
    assert bp.n_buckets > 1

    p = plan_train(get_config("granite-3-2b"), get_shape("train_4k"),
                   sync_overlap=True, bucket_mb=2.0)
    assert p.sync_overlap and p.bucket_mb == 2.0
    p = dataclasses.replace(p, bucket_plan=bp.to_dict())
    q = Plan.from_json(p.to_json())
    assert q == p
    back = BucketPlan.from_dict(q.bucket_plan)
    assert back == bp
    assert back.buckets == bp.buckets  # order-stable, not just set-equal
    assert BucketPlan.from_json(bp.to_json()) == bp
    # a serial plan round-trips its (default) overlap knobs too
    s = plan_train(get_config("granite-3-2b"), get_shape("train_4k"))
    assert not s.sync_overlap and s.bucket_plan is None
    assert Plan.from_json(s.to_json()) == s


# ---------------------------------------------------------------------------
# Cost model: degradation, bounds, and the sweep-grid acceptance criterion
# ---------------------------------------------------------------------------


def test_overlap_cost_model_degrades_to_serial():
    t_comm, t_bwd = 0.3, 1.2
    # single bucket / zero efficiency / zero backward: fully exposed
    assert ps.overlap_exposed_comm(t_comm, t_bwd, 1) == t_comm
    assert ps.overlap_exposed_comm(t_comm, t_bwd, 8,
                                   overlap_efficiency=0.0) == t_comm
    assert ps.overlap_exposed_comm(t_comm, 0.0, 8) == t_comm
    assert ps.overlap_exposed_comm(0.0, t_bwd, 8) == 0.0
    # more buckets -> monotonically less exposed comm
    prev = t_comm + 1
    for n in (1, 2, 4, 8, 64):
        e = ps.overlap_exposed_comm(t_comm, t_bwd, n)
        assert 0.0 <= e <= t_comm
        assert e <= prev
        prev = e
    # the step-time form: serial equality at n=1, monotone improvement
    serial = ps.overlap_step_time(0.4, t_bwd, t_comm, 1)
    assert serial["total"] == pytest.approx(0.4 + t_bwd + t_comm)
    assert serial["overlap_fraction"] == 0.0
    over = ps.overlap_step_time(0.4, t_bwd, t_comm, 8)
    assert over["total"] <= serial["total"]
    assert 0.0 <= over["overlap_fraction"] <= 1.0
    assert over["hidden_comm"] + over["exposed_comm"] == pytest.approx(t_comm)
    # efficiency derating interpolates between the two
    half = ps.overlap_step_time(0.4, t_bwd, t_comm, 8, overlap_efficiency=0.5)
    assert over["total"] <= half["total"] <= serial["total"]


def test_bucket_count():
    assert ps.bucket_count(0.0, 4.0) == 1
    assert ps.bucket_count(4 * 2**20, 4.0) == 1
    assert ps.bucket_count(4 * 2**20 + 1, 4.0) == 2
    assert ps.bucket_count(40 * 2**20, 4.0) == 10
    # 0 falls back to the shared default
    assert ps.bucket_count(ps.DEFAULT_BUCKET_MB * 2**20, 0.0) == 1
    assert DEFAULT_BUCKET_MB == ps.DEFAULT_BUCKET_MB
    assert mb_to_bytes(2.0) == 2 * 2**20


def test_estimate_step_time_overlap_never_above_serial_on_sweep_grid():
    """The acceptance criterion, checked over the same grid
    ``benchmarks/sweep.py`` fans out (topologies x archs): overlap pricing
    is never above serial, and with overlap off the terms degrade to the
    serial formula exactly."""
    from repro.configs.base import get_config, get_shape
    from repro.core.hardware import MeshSpec, get_cluster
    from repro.core.planner import estimate_step_time

    shape = get_shape("train_4k")
    for topo in ("flat8", "2x4", "4x4-ib", "pod"):
        mesh = MeshSpec.from_cluster(get_cluster(topo))
        for arch in ("granite-3-2b", "mamba2-780m"):
            cfg = get_config(arch)
            serial = estimate_step_time(cfg, shape, mesh, "block", 1)
            over = estimate_step_time(cfg, shape, mesh, "block", 1,
                                      sync_overlap=True)
            assert over["total"] <= serial["total"], (topo, arch)
            assert 0.0 <= over["overlap_fraction"] <= 1.0
            assert over["collective_grad_exposed"] <= over["collective_grad"]
            # serial: effective == serial sum, overlap fields inert
            assert serial["collective_effective"] == serial["collective"]
            assert serial["overlap_fraction"] == 0.0
            assert serial["collective_grad_exposed"] == serial["collective_grad"]
            # the serial keys are priced identically in both modes
            for key in ("compute", "memory", "collective", "collective_grad",
                        "collective_tp"):
                assert over[key] == serial[key]


def test_plan_train_overlap_knobs_and_note():
    from repro.configs.base import get_config, get_shape
    from repro.core.hardware import MeshSpec, get_cluster
    from repro.core.planner import plan_train

    mesh = MeshSpec.from_cluster(get_cluster("2pod-dcn"))
    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    serial = plan_train(cfg, shape, mesh)
    over = plan_train(cfg, shape, mesh, sync_overlap=True)
    assert over.sync_overlap and not serial.sync_overlap
    assert over.est_step_time <= serial.est_step_time
    assert over.efficiency >= serial.efficiency  # hidden comm shrinks R_O
    assert any("overlap" in n and "bound after overlap" in n
               for n in over.notes)
    assert not any("bound after overlap" in n for n in serial.notes)
    # resolve_sync & job kwargs carry the knobs
    kw = over.to_job_kwargs()
    assert kw["sync_overlap"] is True and "bucket_mb" in kw


# ---------------------------------------------------------------------------
# Execution: overlapped path vs the serial 3-phase path (multi-device)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.base import get_config

    return get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128)


def _trainers(strategy, compression, multi_device, **overlap_kw):
    from repro.distributed import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    cfg = _tiny_cfg()
    run = RunConfig(attn_impl="dense", remat="none")

    def make(**kw):
        return DataParallelTrainer(
            cfg, run, OptConfig(lr=1e-3, warmup_steps=0, total_steps=8),
            strategy=strategy, compression=compression,
            devices=multi_device, **kw)

    return make(), make(sync_overlap=True, **overlap_kw)


def _run_steps(trainer, steps, batch=16, seq=32):
    """Drive the trainer's step_fn directly on a deterministic batch
    sequence (no loader): returns the final params pytree."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    cfg = trainer.cfg
    params, state = trainer.init(0)
    step = trainer.step_fn()
    rng = np.random.default_rng(0)
    for _ in range(steps):
        toks = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
        b = {k: jax.device_put(jnp.asarray(toks),
                               NamedSharding(trainer.mesh,
                                             trainer._data_spec))
             for k in ("tokens", "labels")}
        params, state, _ = step(params, state, b)
    return params


@pytest.mark.parametrize("strategy", ["all_reduce", "reduce_scatter_all_gather",
                                      "parameter_server", "hier_all_reduce"])
def test_overlapped_numerics_bit_match_serial_all_strategies(
        strategy, multi_device):
    """Same seed, 4 steps (2 serial-bucketed calibration + 2 fused
    overlapped): the overlapped trainer's parameters are BIT-identical to
    the serial 3-phase trainer's for every sync strategy."""
    import jax

    serial, overlapped = _trainers(strategy, "none", multi_device,
                                   bucket_mb=0.05)
    p_serial = _run_steps(serial, 4)
    p_overlap = _run_steps(overlapped, 4)
    assert overlapped._bucket_plan.n_buckets > 1, "bucketing never engaged"
    for a, b in zip(jax.tree_util.tree_leaves(p_serial),
                    jax.tree_util.tree_leaves(p_overlap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("compression", ["bf16", "int8", "topk"])
def test_overlapped_numerics_bit_match_serial_compressors(
        compression, multi_device):
    """The same bit-match holds under every gradient compressor (incl. the
    stateful error-feedback ones, whose EF state rides per bucket)."""
    import jax

    serial, overlapped = _trainers("all_reduce", compression, multi_device,
                                   bucket_mb=0.05)
    p_serial = _run_steps(serial, 4)
    p_overlap = _run_steps(overlapped, 4)
    for a, b in zip(jax.tree_util.tree_leaves(p_serial),
                    jax.tree_util.tree_leaves(p_overlap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overlap_report_measures_hiding(multi_device):
    """The acceptance measurement on a forced multi-device run: the
    overlapped trainer's exposed comm is strictly below the serial comm,
    the fraction is a true fraction, and the per-bucket decomposition is
    self-consistent."""
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig
    from repro.distributed import DataParallelTrainer

    tr = DataParallelTrainer(
        _tiny_cfg(), RunConfig(attn_impl="dense", remat="none"),
        OptConfig(lr=1e-3, warmup_steps=0, total_steps=10),
        strategy="all_reduce", devices=multi_device,
        sync_overlap=True, bucket_mb=0.05)
    tr.train(batch=16, seq=32, steps=10, log_every=0)
    rep = tr.report()
    assert rep.sync_overlap and rep.bucket_mb == 0.05
    assert rep.n_buckets == tr._bucket_plan.n_buckets > 1
    assert len(rep.per_bucket_comm_s) == rep.n_buckets
    assert len(rep.bucket_sizes_bytes) == rep.n_buckets
    assert sum(rep.bucket_sizes_bytes) == pytest.approx(rep.grad_bytes)
    assert 0.0 <= rep.overlap_fraction <= 1.0
    assert rep.measured_comm_s > 0
    assert rep.exposed_comm_time < rep.measured_comm_s, \
        "overlap hid nothing: exposed == serial comm"
    assert rep.overlapped_step_s > 0
    # the dict view (what lands in Report.measured["sync"]) carries it all
    d = rep.as_dict()
    for key in ("sync_overlap", "n_buckets", "overlap_fraction",
                "exposed_comm_time", "per_bucket_comm_s"):
        assert key in d


def test_session_overlap_report_validates(multi_device):
    """JobSpec(sync_overlap=True) end to end through Session.train: the
    Report's measured.sync block passes the schema's overlap checks."""
    from repro.api import JobSpec, Session, validate_report

    spec = JobSpec(arch="granite-3-2b", steps=6, batch=8, seq=32, dp=4,
                   sync="all_reduce", sync_overlap=True, bucket_mb=0.05,
                   log_every=0)
    assert JobSpec.from_json(spec.to_json()) == spec
    rep = Session(spec, config=_tiny_cfg()).train()
    d = json.loads(rep.to_json())
    validate_report(d)
    s = d["measured"]["sync"]
    assert s["sync_overlap"] and s["n_buckets"] > 1
    assert 0.0 <= s["overlap_fraction"] <= 1.0
    assert d["plan"]["sync_overlap"] is True
    assert "overlap" in d["predicted"]["lemma32"]


def test_report_schema_rejects_bad_overlap_sync():
    """Single-field mutations of an overlapped sync block must be
    rejected."""
    from repro.api import validate_report

    def base():
        return {
            "schema": "repro.api/report/v1", "kind": "plan",
            "spec": {k: 0 for k in ("arch", "shape", "reduced", "steps",
                                    "batch", "seq", "seed")},
            "plan": {k: 0 for k in ("arch", "mesh", "microbatch", "attn_impl",
                                    "remat", "sync_schedule",
                                    "est_step_time")},
            "measured": {"sync": {
                "strategy": "all_reduce", "dp": 8,
                "measured_comm_s": 0.01, "predicted_comm_s": 0.01,
                "sync_overlap": True, "n_buckets": 4,
                "overlap_fraction": 0.5, "exposed_comm_time": 0.005,
                "bucket_sizes_bytes": [256.0] * 4,
                "per_bucket_comm_s": [0.0025] * 4,
                "overlapped_step_s": 0.02,
            }},
            "predicted": {"lemma31": {}, "lemma32": {}},
        }

    validate_report(base())  # the unmutated block passes
    mutations = [
        lambda s: s.pop("overlap_fraction"),
        lambda s: s.pop("n_buckets"),
        lambda s: s.pop("exposed_comm_time"),
        lambda s: s.update(overlap_fraction=1.5),
        lambda s: s.update(overlap_fraction=-0.1),
        lambda s: s.update(n_buckets=0),
        lambda s: s.update(exposed_comm_time=0.02),  # > measured_comm_s
        lambda s: s.pop("strategy"),
    ]
    for mutate in mutations:
        d = base()
        mutate(d["measured"]["sync"])
        with pytest.raises(ValueError):
            validate_report(d)
    # a serial sync block needs no overlap fields
    d = base()
    for key in ("sync_overlap", "n_buckets", "overlap_fraction",
                "exposed_comm_time"):
        d["measured"]["sync"].pop(key)
    validate_report(d)


def test_calibrated_zero_overlap_is_honored():
    """A calibration whose overlap sweep *measured* 0.0 hiding must derate
    the window to zero (serial pricing), not fall back to the ideal 1.0 —
    bucket_mb > 0 marks 'the sweep ran'."""
    from repro.api import JobSpec, Session
    from repro.core.autotune import Calibration

    spec = JobSpec(arch="granite-3-2b", steps=2, sync_overlap=True)
    measured_zero = Calibration(backend="cpu", cluster="flat8",
                                achieved_flops=1e12,
                                overlap_fraction=0.0, bucket_mb=4.0)
    unmeasured = Calibration(backend="cpu", cluster="flat8",
                             achieved_flops=1e12)
    sess_zero = Session(spec, calibration=measured_zero)
    sess_ideal = Session(spec, calibration=unmeasured)
    assert sess_zero._overlap_kwargs()["overlap_efficiency"] == 0.0
    assert sess_ideal._overlap_kwargs()["overlap_efficiency"] == 1.0
    # measured-zero overlap ⇒ the lemma32 overlap block exposes ALL comm
    l32 = sess_zero.plan().predicted["lemma32"]["overlap"]
    assert l32["exposed_comm_s"] == pytest.approx(
        sess_zero.plan().predicted["lemma32"]["predicted_comm_s"])
    assert l32["hidden_comm_s"] == pytest.approx(0.0)


def test_train_launcher_overlap_flags():
    from repro.launch.train import build_parser, build_spec

    ap = build_parser()
    spec = build_spec(ap.parse_args(["--arch", "granite-3-2b"]))
    assert not spec.sync_overlap and spec.bucket_mb == 0.0
    spec = build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--overlap", "--bucket-mb", "2.5"]))
    assert spec.sync_overlap and spec.bucket_mb == 2.5
    spec = build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--no-overlap"]))
    assert not spec.sync_overlap
