"""Continuous-batching serving runtime: allocator invariants, paged-KV
round trips, scheduler accounting, arrival traces, and the inference
replica lemma.

The load-bearing claims, asserted here:

- the continuous scheduler computes exactly ``sum(n_new)`` decode-token
  steps (the static ``BatchScheduler`` computes ``len(batch) * max(n_new)``
  per batch — the waste this PR removes),
- decoding through the paged KV cache is bit-identical to the linear-cache
  engine for the same token stream,
- chunked prefill and whole-prompt prefill produce the same numbers,
- arrival traces replay deterministically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import memory_model as mm, ps as ps_lib
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.serve import arrivals
from repro.serve.continuous import ContinuousEngine, ContinuousScheduler
from repro.serve.engine import BatchScheduler, Engine
from repro.serve.kvcache import BlockAllocator, PagedKVCache


def tiny_cfg():
    return get_config("granite-3-2b").reduced().replace(vocab_size=256)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = tiny_cfg()
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


RUN = RunConfig(attn_impl="dense", remat="none")


def _workload(cfg, seed=0, n=4, n_new=(1, 4, 2, 3)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, (int(rng.integers(8, 24)),))
             .astype(np.int32), n_new[i % len(n_new)]) for i in range(n)]


def _run_static(cfg, params, reqs, *, s_max=64, max_batch=2):
    eng = Engine(cfg, RUN, params, s_max=s_max)
    sched = BatchScheduler(eng, max_batch=max_batch)
    for prompt, n_new in reqs:
        sched.submit(prompt, n_new)
    return sched.run(), sched


def _run_continuous(cfg, params, reqs, *, s_max=64, max_batch=2,
                    n_blocks=16, block_size=16, prefill_chunk=0,
                    steps=None):
    eng = ContinuousEngine(cfg, RUN, params, s_max=s_max,
                           max_batch=max_batch, prefill_chunk=prefill_chunk)
    kv = PagedKVCache(cfg, block_size=block_size, n_blocks=n_blocks,
                      s_max=s_max)
    sched = ContinuousScheduler(eng, kv)
    for i, (prompt, n_new) in enumerate(reqs):
        sched.submit(prompt, n_new,
                     arrival_step=steps[i] if steps else 0)
    return sched.run(), sched, kv


# ---------------------------------------------------------------------------
# Arrival traces (pure python)
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic_and_monotone():
    a = arrivals.poisson_trace(32, 0.5, seed=7)
    b = arrivals.poisson_trace(32, 0.5, seed=7)
    assert a == b
    assert all(x <= y for x, y in zip(a, a[1:]))
    assert a != arrivals.poisson_trace(32, 0.5, seed=8)
    # higher rate arrives sooner on average
    slow = arrivals.poisson_trace(64, 0.1, seed=1)
    fast = arrivals.poisson_trace(64, 2.0, seed=1)
    assert sum(fast) < sum(slow)


def test_burst_trace_structure():
    t = arrivals.burst_trace(7, 3, 10)
    assert t == [0, 0, 0, 10, 10, 10, 20]


def test_parse_trace():
    assert arrivals.parse_trace("") == ("static", ())
    assert arrivals.parse_trace("poisson:0.25") == ("poisson", (0.25,))
    assert arrivals.parse_trace("burst:4x8") == ("burst", (4, 8))
    for bad in ("poisson", "poisson:-1", "burst:4", "burst:0x8", "drizzle:1"):
        with pytest.raises(ValueError):
            arrivals.parse_trace(bad)
    assert arrivals.make_trace("", 3) == [0, 0, 0]
    assert len(arrivals.make_trace("poisson:0.5", 5, seed=2)) == 5


# ---------------------------------------------------------------------------
# Block allocator free-list invariants (pure python)
# ---------------------------------------------------------------------------


def test_allocator_no_double_free_and_exhaustion():
    a = BlockAllocator(4, 16)
    bids = [a.alloc() for _ in range(4)]
    assert len(set(bids)) == 4 and a.n_free == 0
    with pytest.raises(RuntimeError):
        a.alloc()
    a.free(bids[0])
    assert a.n_free == 1
    with pytest.raises(RuntimeError):
        a.free(bids[0])
    assert a.peak_used == 4


def test_allocator_prefix_share_refcounts():
    a = BlockAllocator(4, 16)
    bid = a.alloc()
    key = ("tok", 1, 2, 3)
    a.publish(bid, key)
    assert a.lookup(key) == bid
    assert a.share(key) == bid          # refcount 2
    assert a.refcount(bid) == 2
    a.free(bid)                         # refcount 1: still allocated
    assert a.refcount(bid) == 1 and a.n_free == 3
    a.free(bid)                         # refcount 0: returns to free list
    assert a.n_free == 4 and a.lookup(key) is None
    assert a.shared_hits == 1


def test_allocator_randomized_invariants():
    """Property check: under a random alloc/share/free walk, used + free
    always partitions the pool and no live block is handed out twice."""
    rng = np.random.default_rng(0)
    a = BlockAllocator(8, 4)
    live = {}  # bid -> refcount we believe it has
    for step in range(400):
        op = rng.integers(0, 3)
        if op == 0 and a.can_alloc(1):
            bid = a.alloc()
            assert bid not in live, "allocator handed out a live block"
            live[bid] = 1
        elif op == 1 and live:
            bid = int(rng.choice(list(live)))
            key = ("k", bid)
            if a.lookup(key) is None:
                a.publish(bid, key)
            a.share(key)
            live[bid] += 1
        elif op == 2 and live:
            bid = int(rng.choice(list(live)))
            a.free(bid)
            live[bid] -= 1
            if live[bid] == 0:
                del live[bid]
        assert a.n_used + a.n_free == 8
        assert a.n_used == len(live)
        for bid, refs in live.items():
            assert a.refcount(bid) == refs


# ---------------------------------------------------------------------------
# Memory bound (Eq. 5 analogue) and the replica lemma (pure python)
# ---------------------------------------------------------------------------


def test_kv_memory_bound_per_arch():
    attn = get_config("granite-3-2b").reduced()
    ssm = get_config("mamba2-780m").reduced()
    assert mm.kv_token_bytes(attn) > 0 and mm.request_state_bytes(attn) == 0
    assert mm.kv_token_bytes(ssm) == 0 and mm.request_state_bytes(ssm) > 0
    assert mm.max_kv_blocks(ssm, 2**34, block_size=16) == 0  # nothing paged


def test_max_kv_blocks_monotone_in_hbm():
    cfg = get_config("granite-3-2b").reduced()
    sizes = [mm.max_kv_blocks(cfg, hbm, block_size=16)
             for hbm in (2**28, 2**30, 2**34)]
    assert sizes == sorted(sizes)
    assert sizes[-1] > 0
    # a budget below the resident weights leaves no room for blocks
    assert mm.max_kv_blocks(cfg, 1024.0, block_size=16) == 0


def test_replica_lemma_properties():
    assert ps_lib.md1_wait(0.0, 1.0) == 0.0
    assert ps_lib.md1_wait(0.9, 1.0) > ps_lib.md1_wait(0.5, 1.0)
    rho = ps_lib.serve_utilization_bound(2.0, 1.0)
    assert 0.0 < rho < 1.0
    # at rho* the M/D/1 wait exactly meets the slack
    assert ps_lib.md1_wait(rho, 1.0) == pytest.approx(2.0 - 1.0)
    assert ps_lib.serve_utilization_bound(0.5, 1.0) == 0.0  # slack <= 0
    # replicas scale with offered load
    n = [ps_lib.n_replicas(lam, 0.5, 4, 0.8) for lam in (1.0, 10.0, 100.0)]
    assert n == sorted(n) and n[-1] > n[0]


def test_replica_plan_json_safe():
    import json

    ok = ps_lib.serve_replica_plan(arrival_rate=8.0, t_prefill_s=0.01,
                                   t_step_s=0.002, n_new=16, batch=4,
                                   slo_s=0.5)
    assert ok["attainable"] and ok["replicas"] >= 1
    bad = ps_lib.serve_replica_plan(arrival_rate=8.0, t_prefill_s=1.0,
                                    t_step_s=0.1, n_new=16, batch=4,
                                    slo_s=0.5)
    assert not bad["attainable"] and bad["replicas"] == 0
    for plan in (ok, bad):  # no inf/nan may reach a Report
        json.dumps(plan)


def test_spec_serving_validation():
    from repro.api import JobSpec

    JobSpec(arch="granite-3-2b", arrival="poisson:0.5")  # valid
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", serve_mode="adaptive")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", kv_block=0)
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", arrival="poisson:fast")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", max_kv_blocks=-1)


# ---------------------------------------------------------------------------
# Scheduler accounting: the wasted-decode fix
# ---------------------------------------------------------------------------


def test_decode_steps_continuous_equals_sum_n_new(cfg_params):
    """The regression this PR exists for: the static scheduler decodes
    every request for the batch max and truncates; per-request retirement
    computes exactly ``sum(n_new)`` token steps."""
    cfg, params = cfg_params
    reqs = _workload(cfg)
    want = sum(n for _, n in reqs)

    _, ssched = _run_static(cfg, params, reqs)
    static_steps = ssched.stats["decode_token_steps"]
    # len(batch) * max(n_new) per batch, by construction of the workload
    assert static_steps == 2 * 4 + 2 * 3
    assert static_steps > want
    assert ssched.stats["wasted_decode_steps"] == static_steps - want

    _, csched, _ = _run_continuous(cfg, params, reqs)
    assert csched.stats["decode_token_steps"] == want
    assert csched.stats["wasted_decode_steps"] == 0
    assert csched.stats["delivered_tokens"] == want


def test_continuous_stream_bit_identical_to_static(cfg_params):
    """Same requests, same params: the paged-KV continuous runtime must
    reproduce the linear-cache engine's token streams exactly."""
    cfg, params = cfg_params
    reqs = _workload(cfg, seed=3)
    sres, _ = _run_static(cfg, params, reqs)
    cres, _, kv = _run_continuous(cfg, params, reqs)
    assert set(sres) == set(cres)
    for rid in sres:
        np.testing.assert_array_equal(sres[rid], cres[rid])
    assert kv.stats()["peak_blocks"] > 0  # the pools were load-bearing


def test_chunked_prefill_stream_identical(cfg_params):
    cfg, params = cfg_params
    reqs = _workload(cfg, seed=5)
    whole, _, _ = _run_continuous(cfg, params, reqs)
    chunked, sched, _ = _run_continuous(cfg, params, reqs, prefill_chunk=8)
    assert sched.stats["prefill_chunks"] > 0
    for rid in whole:
        np.testing.assert_array_equal(whole[rid], chunked[rid])


def test_extend_step_matches_whole_prefill(cfg_params):
    """model.extend_step chunks == one whole-prompt forward.  Tight
    allclose, not bitwise: under the suite's forced 8-device XLA config
    the two attention lengths accumulate in different orders (~5e-7 on
    f32 logits); the *token streams* are asserted bit-identical above."""
    cfg, params = cfg_params
    assert M.supports_extend(cfg)
    assert not M.supports_extend(get_config("deepseek-v2-236b").reduced())
    rng = np.random.default_rng(1)
    L, C = 24, 8
    toks = rng.integers(0, cfg.vocab_size, (1, L)).astype(np.int32)
    want, _, _ = M.forward(params, {"tokens": jnp.asarray(toks)}, cfg, RUN,
                           with_cache=True)
    caches = jax.tree_util.tree_map(
        lambda sp: jnp.zeros(sp.shape, jnp.bfloat16),
        M.cache_specs(cfg, batch=1, s_max=32))
    got = []
    for lo in range(0, L, C):
        pos0 = jnp.full((1,), lo, jnp.int32)
        logits, caches = M.extend_step(params, jnp.asarray(toks[:, lo:lo + C]),
                                       pos0, caches, cfg, RUN)
        got.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(np.concatenate(got, axis=1),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_arrival_replay_deterministic(cfg_params):
    cfg, params = cfg_params
    reqs = _workload(cfg, seed=2)
    steps = arrivals.make_trace("poisson:0.3", len(reqs), seed=4)
    r1, s1, _ = _run_continuous(cfg, params, reqs, steps=steps)
    r2, s2, _ = _run_continuous(cfg, params, reqs, steps=steps)
    for rid in r1:
        np.testing.assert_array_equal(r1[rid], r2[rid])
    assert s1.stats == s2.stats
    assert s1.stats["virtual_steps"] >= max(steps)


def test_prefix_sharing_and_admission_bound(cfg_params):
    """Identical prompts share their full prompt blocks; a pool sized for
    one request at a time forces serialized admission but still delivers,
    and an impossible request raises instead of deadlocking."""
    cfg, params = cfg_params
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
    reqs = [(prompt, 3), (prompt, 3)]
    cres, _, kv = _run_continuous(cfg, params, reqs, block_size=16,
                                  n_blocks=8)
    assert kv.stats()["shared_block_hits"] >= 2  # both full prompt blocks
    sres, _ = _run_static(cfg, params, reqs)
    for rid in sres:
        np.testing.assert_array_equal(sres[rid], cres[rid])

    # pool of 3 blocks: one 32+3-token request needs 3, so two requests
    # must serialize through the pool
    small = _workload(cfg, seed=7, n=3, n_new=(3,))
    _, sched, kv2 = _run_continuous(cfg, params, small, block_size=16,
                                    n_blocks=3)
    assert sched.stats["requests"] == 3
    assert kv2.stats()["peak_blocks"] <= 3

    with pytest.raises(RuntimeError):  # 50+3 tokens = 4 blocks, pool has 3
        _run_continuous(cfg, params,
                        [(np.concatenate([prompt, prompt])[:50], 3)],
                        block_size=16, n_blocks=3)


def test_oversized_request_rejected(cfg_params):
    cfg, params = cfg_params
    prompt = np.zeros((60,), np.int32)
    with pytest.raises(ValueError):  # 60 + 8 > s_max=64
        _run_continuous(cfg, params, [(prompt, 8)])
