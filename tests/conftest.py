"""Shared test plumbing.

- The in-process backend is pinned to CPU **and** forced to 8 simulated
  host devices before anything imports jax, so multi-device code paths
  (repro.distributed strategies, bucketed overlap, hierarchical meshes)
  execute *inside* pytest instead of silently degenerating to dp=1 — the
  same environment CI's fast tier runs (`XLA_FLAGS` in ci.yml).
- ``multi_device``: fixture for tests that require the forced device
  count; it fails (not skips) when the axis is missing, so a broken
  environment cannot silently pass the suite with dp=1.
- ``run_sub``: run a snippet in a fresh subprocess with its own
  ``--xla_force_host_platform_device_count`` (for tests that need a
  different device count, or heavyweight compiles kept out of the main
  process). Subprocess tests must carry the ``slow`` marker unless listed
  in ``tools/test_budget.py``'s allowlist (tier-1 budget guard).
- The ``slow`` marker (registered in pytest.ini) keeps tier-1
  (``pytest -x -q``) to the fast subset; ``pytest -m ""`` runs everything.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# Pin the in-process backend before anything imports jax: without it jax
# probes the TPU backend (libtpu is installed) and stalls ~8 min in
# GCP-metadata retries on non-TPU hosts.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Force a real multi-device axis in-process (matches ci.yml's fast tier).
# Only when the caller has not already forced a count of their own.
N_FORCED_DEVICES = 8
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_FORCED_DEVICES}").strip()

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture
def multi_device():
    """The in-process devices of the forced multi-device axis.  Tests that
    exercise dp>1 paths take this fixture so they *assert* the axis exists
    instead of silently falling back to a single device."""
    import jax

    devs = jax.devices()
    assert len(devs) >= N_FORCED_DEVICES, (
        f"expected >= {N_FORCED_DEVICES} forced host devices, got "
        f"{len(devs)} — XLA_FLAGS was set after jax initialized?")
    return devs[:N_FORCED_DEVICES]


def run_sub(body: str, devices: int = 8, timeout: int = 520) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        # JAX_PLATFORMS=cpu is load-bearing: without it jax probes the TPU
        # backend and libtpu retries GCP metadata fetches for ~8 MINUTES
        # before falling back to CPU
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout
