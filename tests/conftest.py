"""Shared test plumbing.

- ``run_sub``: run a snippet in a fresh subprocess with
  ``--xla_force_host_platform_device_count`` set (the parent pytest process
  has already locked jax to 1 device, so multi-device tests must re-exec).
- The ``slow`` marker (registered in pytest.ini) keeps tier-1
  (``pytest -x -q``) to the fast subset; ``pytest -m ""`` runs everything.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

# Pin the in-process backend before anything imports jax: without it jax
# probes the TPU backend (libtpu is installed) and stalls ~8 min in
# GCP-metadata retries on non-TPU hosts.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = Path(__file__).resolve().parent.parent


def run_sub(body: str, devices: int = 8, timeout: int = 520) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        # JAX_PLATFORMS=cpu is load-bearing: without it jax probes the TPU
        # backend and libtpu retries GCP metadata fetches for ~8 MINUTES
        # before falling back to CPU
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout
