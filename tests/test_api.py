"""repro.api facade tests: JobSpec validation/round-trip, Plan round-trip
serialization, the Session smoke path on CPU, and the shared Report schema
that every entry point (launchers, benchmarks, examples) must emit."""
import json

import pytest

from conftest import REPO, run_sub

from repro.api import (COMPRESSIONS, JobSpec, Report, SCHEMA_ID, Session,
                       SYNCS, validate_report)
from repro.configs.base import ARCH_IDS, get_config, get_shape
from repro.core.planner import Plan, plan as plan_fn, plan_train


# ---------------------------------------------------------------------------
# JobSpec
# ---------------------------------------------------------------------------


def test_jobspec_validates():
    with pytest.raises(ValueError):
        JobSpec(arch="not-a-model")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", shape="no_such_shape")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", sync="gossip")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", compress="zip")
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", steps=0)
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", batch=6, dp=4)  # not divisible
    assert "auto" in SYNCS and "none" in COMPRESSIONS


def test_spec_name_tuples_match_runtime_registries():
    """spec.py keeps its own name tuples to stay import-light; they must
    not drift from the executable registries."""
    from repro.core.ps import SCHEDULES
    from repro.distributed.collectives import STRATEGIES
    from repro.distributed.compression import COMPRESSORS

    assert SYNCS == ("auto",) + SCHEDULES
    assert tuple(STRATEGIES) == SCHEDULES
    assert tuple(COMPRESSORS) == COMPRESSIONS


def test_jobspec_json_roundtrip():
    spec = JobSpec(arch="gemma2-27b", reduced=False, shape="decode_32k",
                   mesh="multi", steps=7, batch=4, seq=96, dp=2,
                   sync="all_reduce", compress="bf16", seed=3)
    back = JobSpec.from_json(spec.to_json())
    assert back == spec
    # unknown keys are ignored (forward compatibility)
    d = spec.to_dict()
    d["future_knob"] = 1
    assert JobSpec.from_dict(d) == spec


# ---------------------------------------------------------------------------
# Plan round-trip (satellite: lossless for all registered archs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_plan_json_roundtrip_lossless(arch):
    p = plan_train(get_config(arch), get_shape("train_4k"))
    q = Plan.from_json(p.to_json())
    assert q == p
    s1, s2 = p.resolve_sync(), q.resolve_sync()
    assert s1.name == s2.name and s1.n_servers == s2.n_servers


def test_plan_to_job_kwargs():
    p = plan_train(get_config("granite-3-2b"), get_shape("train_4k"))
    kw = p.to_job_kwargs()
    assert kw["microbatch"] == p.microbatch
    assert kw["opt_kind"] == p.opt_kind
    assert kw["sync"] == p.sync_schedule
    # decode plans serialize too (sync "-" round-trips, resolve raises)
    d = plan_fn(get_config("granite-3-2b"), get_shape("decode_32k"))
    d2 = Plan.from_json(d.to_json())
    assert d2 == d
    with pytest.raises(ValueError):
        d2.resolve_sync()


def test_train_launcher_reduced_flag():
    """Satellite: --reduced used to be store_true with default=True, so it
    could never be disabled; --full / --no-reduced must now work."""
    from repro.launch.train import build_parser, build_spec

    ap = build_parser()
    assert build_spec(ap.parse_args(["--arch", "granite-3-2b"])).reduced
    assert build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--reduced"])).reduced
    assert not build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--full"])).reduced
    assert not build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--no-reduced"])).reduced
    # the launcher's flags land in the spec unchanged
    spec = build_spec(ap.parse_args(
        ["--arch", "granite-3-2b", "--steps", "2", "--dp", "2",
         "--sync", "all_reduce", "--compress", "bf16"]))
    assert (spec.steps, spec.dp, spec.sync, spec.compress) == (
        2, 2, "all_reduce", "bf16")


# ---------------------------------------------------------------------------
# Session + Report schema
# ---------------------------------------------------------------------------


def test_session_train_smoke_returns_valid_report():
    """The ISSUE's acceptance smoke: a 2-step reduced train run must return
    a Report with populated measured fields whose JSON validates."""
    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=2, batch=4,
                   seq=32, log_every=0)
    rep = Session(spec).train()
    assert isinstance(rep, Report)
    m = rep.measured
    assert m["steps"] == 2 and len(m["losses"]) == 2
    assert m["tokens_per_s"] > 0
    assert m["step_times_mean"]["compute"] > 0
    assert rep.plan["sync_schedule"] in ("all_reduce",
                                         "reduce_scatter_all_gather",
                                         "parameter_server")
    d = json.loads(rep.to_json())
    assert d["schema"] == SCHEMA_ID
    validate_report(d)
    # the report round-trips through JSON
    back = Report.from_json(rep.to_json())
    assert back.kind == "train" and back.spec["arch"] == "granite-3-2b"


def test_session_predictive_kinds_share_schema():
    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=2)
    sess = Session(spec)
    plan_rep = sess.plan()
    dry_rep = sess.dryrun()
    for rep in (plan_rep, dry_rep):
        d = json.loads(rep.to_json())
        validate_report(d)
        assert d["predicted"]["lemma31"]["per_device"]["8"]["speedup"] > 0
        assert d["predicted"]["lemma32"]["schedule"] == d["plan"]["sync_schedule"]
    assert dry_rep.predicted["memory_bytes"]["total"] > 0
    assert plan_rep.measured == {}


def test_validate_report_rejects_malformed():
    spec = JobSpec(arch="granite-3-2b", steps=2)
    good = Session(spec).plan().to_dict()
    for breakage in (
        lambda d: d.pop("plan"),
        lambda d: d.update(schema="repro.api/report/v0"),
        lambda d: d.update(kind="profile"),
        lambda d: d["spec"].pop("arch"),
        lambda d: d["predicted"].pop("lemma32"),
    ):
        bad = json.loads(json.dumps(good))
        breakage(bad)
        with pytest.raises(ValueError):
            validate_report(bad)
    # a measured kind must actually carry measurements
    bad = json.loads(json.dumps(good))
    bad["kind"] = "train"
    with pytest.raises(ValueError):
        validate_report(bad)


def test_validate_report_rejects_malformed_tuning_section():
    """The repro.api/tuning/v1 section is validated whenever present —
    a tune-kind report without it, or with a wrong/incomplete one, fails."""
    spec = JobSpec(arch="granite-3-2b", steps=2)
    good = Session(spec).plan().to_dict()
    # kind "tune" with no tuning section at all
    bad = json.loads(json.dumps(good))
    bad["kind"] = "tune"
    with pytest.raises(ValueError, match="tuning"):
        validate_report(bad)
    # a structurally complete section validates...
    tuning = {
        "schema": "repro.api/tuning/v1",
        "minibatch": {"chosen": 128},
        "kernels": {"flash_attention": {"chosen": "ref", "times_s": {}}},
        "calibration": {"achieved_flops": 1e10},
        "replan": {"measured_step_s": 0.01,
                   "est_step_time_calibrated_s": 0.01,
                   "est_step_time_uncalibrated_s": 1e-5},
    }
    ok = json.loads(json.dumps(good))
    ok["kind"] = "tune"
    ok["measured"]["tuning"] = tuning
    validate_report(ok)
    # ...and each schema violation is rejected, even on non-tune kinds
    for breakage in (
        lambda t: t.update(schema="repro.api/tuning/v0"),
        lambda t: t.pop("minibatch"),
        lambda t: t["minibatch"].pop("chosen"),
        lambda t: t.pop("calibration"),
        lambda t: t["replan"].pop("measured_step_s"),
        lambda t: t["kernels"]["flash_attention"].pop("chosen"),
        # a stringly replan must not pass via substring containment
        lambda t: t.update(replan="measured_step_s est_step_time_"
                                  "calibrated_s est_step_time_"
                                  "uncalibrated_s"),
        lambda t: t.update(calibration="not-a-dict"),
    ):
        bad = json.loads(json.dumps(ok))
        breakage(bad["measured"]["tuning"])
        with pytest.raises(ValueError):
            validate_report(bad)
    bad = json.loads(json.dumps(good))  # kind "plan" with a broken section
    bad["measured"]["tuning"] = {"schema": "nope"}
    with pytest.raises(ValueError):
        validate_report(bad)


@pytest.mark.slow
def test_session_serve_and_dp_bench_reports():
    out = run_sub("""
    import json
    from repro.api import JobSpec, Session, validate_report
    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=2, batch=4,
                   seq=32, dp=2, sync="auto", log_every=0,
                   requests=2, n_new=4, s_max=64)
    sess = Session(spec)
    bench = sess.bench()
    validate_report(json.loads(bench.to_json()))
    assert bench.measured["sync"]["dp"] == 2
    assert bench.measured["sync"]["strategy"] == sess.resolved_plan.sync_schedule
    serve = sess.serve()
    validate_report(json.loads(serve.to_json()))
    assert serve.measured["requests"] == 2
    assert len(serve.measured["per_request"]) == 2
    print("API-DP-OK")
    """, devices=2)
    assert "API-DP-OK" in out


@pytest.mark.slow
def test_sync_benchmark_emits_unified_schema(tmp_path):
    """The benchmark JSON (as run by CI's examples-smoke job) must carry the
    unified Report schema."""
    import subprocess
    import sys

    out = tmp_path / "sync.json"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.sync_strategies", "--quick",
         "--out", str(out)],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=str(REPO), capture_output=True, text=True, timeout=520)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    d = json.loads(out.read_text())
    validate_report(d)
    # one run per member of the strategy zoo (no compression grid in --quick)
    from repro.distributed.collectives import STRATEGIES

    assert d["kind"] == "bench" and len(d["measured"]["runs"]) == len(STRATEGIES)
    assert {r["strategy"] for r in d["measured"]["runs"]} == set(STRATEGIES)
