"""Schema golden tests — one canonical checked-in JSON per report kind.

Schema drift used to be caught only incidentally (a benchmark failing
validation somewhere downstream).  These goldens pin the contract: each
canonical artifact must validate as-is, and *single-field mutations* —
deleting any required key, or corrupting the schema id / kind / bounded
overlap fields — must be rejected.  The mutation lists are derived from
``repro.api.report``'s own requirement tables so they cannot drift from
the validator."""
import copy
import json
from pathlib import Path

import pytest

from repro.api import Campaign, validate_report
from repro.api.report import (_MEASURED_REQUIRED, _PLAN_REQUIRED,
                              _PREDICTED_REQUIRED, _SERVING_REQUIRED,
                              _SERVING_SUBKEYS, _SPEC_REQUIRED,
                              _SYNC_OVERLAP_REQUIRED, _TUNING_REQUIRED,
                              KINDS, SCHEMA_ID, SERVING_SCHEMA_ID)
from repro.obs.metrics import (HISTOGRAM_KEYS, METRICS_SCHEMA_ID,
                               validate_metrics)

GOLDENS = Path(__file__).resolve().parent / "goldens"
REPORT_GOLDENS = ("report_v1_plan.json", "report_v1_train.json",
                  "tuning_v1.json", "report_v1_serve.json")


def _load(name):
    return json.loads((GOLDENS / name).read_text())


# ---------------------------------------------------------------------------
# The canonical artifacts validate as-is
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", REPORT_GOLDENS)
def test_golden_reports_validate(name):
    d = _load(name)
    validate_report(d)
    assert d["schema"] == SCHEMA_ID and d["kind"] in KINDS


def test_golden_campaign_validates():
    camp = Campaign.from_json((GOLDENS / "campaign_v1.json").read_text())
    assert len(camp) == 2
    for rep in camp.reports:
        validate_report(json.loads(rep.to_json()))


def test_goldens_cover_the_overlap_fields():
    """The checked-in artifacts exercise the PR's schema additions, not
    just the seed schema."""
    plan = _load("report_v1_plan.json")
    assert plan["plan"]["sync_overlap"] is True
    assert "overlap" in plan["predicted"]["lemma32"]
    train = _load("report_v1_train.json")
    sync = train["measured"]["sync"]
    assert sync["sync_overlap"] and sync["n_buckets"] > 1
    assert 0.0 <= sync["overlap_fraction"] <= 1.0
    tune = _load("tuning_v1.json")
    assert tune["measured"]["tuning"]["overlap"]["measured"] is True


# ---------------------------------------------------------------------------
# Single-field mutations are rejected
# ---------------------------------------------------------------------------


def _required_paths(d):
    """(section, key) deletions that must each break validation, derived
    from the validator's own requirement tables."""
    paths = [(None, k) for k in ("schema", "kind", "spec", "plan",
                                 "measured", "predicted")]
    paths += [("spec", k) for k in _SPEC_REQUIRED]
    paths += [("plan", k) for k in _PLAN_REQUIRED]
    paths += [("predicted", k) for k in _PREDICTED_REQUIRED]
    paths += [("measured", k) for k in _MEASURED_REQUIRED.get(d["kind"], ())]
    return paths


@pytest.mark.parametrize("name", REPORT_GOLDENS)
def test_golden_rejects_required_key_deletions(name):
    golden = _load(name)
    for section, key in _required_paths(golden):
        d = copy.deepcopy(golden)
        if section is None:
            d.pop(key)
        else:
            d[section].pop(key)
        with pytest.raises(ValueError):
            validate_report(d)


@pytest.mark.parametrize("name", REPORT_GOLDENS)
def test_golden_rejects_field_corruption(name):
    golden = _load(name)
    corruptions = [
        lambda d: d.update(schema="repro.api/report/v0"),
        lambda d: d.update(kind="vibes"),
        lambda d: d.update(spec=[]),
    ]
    for corrupt in corruptions:
        d = copy.deepcopy(golden)
        corrupt(d)
        with pytest.raises(ValueError):
            validate_report(d)


def test_golden_train_rejects_sync_overlap_mutations():
    golden = _load("report_v1_train.json")
    for key in _SYNC_OVERLAP_REQUIRED:
        d = copy.deepcopy(golden)
        d["measured"]["sync"].pop(key)
        with pytest.raises(ValueError):
            validate_report(d)
    d = copy.deepcopy(golden)
    d["measured"]["sync"]["overlap_fraction"] = 2.0
    with pytest.raises(ValueError):
        validate_report(d)
    d = copy.deepcopy(golden)
    d["measured"]["sync"]["exposed_comm_time"] = \
        d["measured"]["sync"]["measured_comm_s"] * 10 + 1.0
    with pytest.raises(ValueError):
        validate_report(d)


def test_goldens_cover_the_pipe_fields():
    """The plan golden is a *pipelined* plan: it pins the Plan's 1F1B
    fields (pipe/n_microbatch/stage_cut), the priced p2p + bubble roofline
    terms, and the predicted pipeline block."""
    plan = _load("report_v1_plan.json")
    p = plan["plan"]
    assert p["pipe"] == 2 and p["n_microbatch"] >= p["pipe"]
    cut = p["stage_cut"]
    assert cut[0] == 0 and len(cut) == p["pipe"] + 1
    assert cut == sorted(cut) and all(b > a for a, b in zip(cut, cut[1:]))
    terms = plan["predicted"]["step_time_terms"]
    assert terms["collective_p2p"] > 0
    assert 0 < terms["pipeline_bubble"] < 1
    pp = plan["predicted"]["pipeline"]
    assert pp["pipe"] == p["pipe"]
    assert pp["bubble_model"] == pytest.approx(
        (p["pipe"] - 1) / (p["n_microbatch"] + p["pipe"] - 1))


def test_golden_plan_rejects_pipe_mutations():
    """Single-field corruptions of the pipeline shape must each be
    rejected: a microbatch count below the stage count (1F1B cannot fill
    its warmup), and a stage count that breaks ``pipe * dp * tp == world``
    against the plan's own topology."""
    golden = _load("report_v1_plan.json")
    d = copy.deepcopy(golden)
    d["plan"]["n_microbatch"] = d["plan"]["pipe"] - 1
    with pytest.raises(ValueError):
        validate_report(d)
    d = copy.deepcopy(golden)
    d["plan"].pop("n_microbatch")
    with pytest.raises(ValueError):
        validate_report(d)
    d = copy.deepcopy(golden)
    d["plan"]["pipe"] = d["plan"]["pipe"] * 2  # pipe*dp*tp != world now
    with pytest.raises(ValueError):
        validate_report(d)
    d = copy.deepcopy(golden)
    d["plan"]["pipe"] = 0
    with pytest.raises(ValueError):
        validate_report(d)
    # legacy plan dicts (no pipe field at all) still validate: the check
    # is conditional, migration fills the no-pipelining defaults
    d = copy.deepcopy(golden)
    d["plan"].pop("pipe")
    validate_report(d)


def test_golden_tuning_rejects_section_mutations():
    golden = _load("tuning_v1.json")
    for key in _TUNING_REQUIRED:
        d = copy.deepcopy(golden)
        d["measured"]["tuning"].pop(key)
        with pytest.raises(ValueError):
            validate_report(d)
    d = copy.deepcopy(golden)
    d["measured"]["tuning"]["schema"] = "repro.api/tuning/v0"
    with pytest.raises(ValueError):
        validate_report(d)
    d = copy.deepcopy(golden)
    d["measured"]["tuning"]["overlap"]["overlap_fraction"] = -0.5
    with pytest.raises(ValueError):
        validate_report(d)


def test_goldens_cover_the_serving_fields():
    """The serve golden exercises this PR's serving/v1 schema — a
    continuous-mode run with paged-KV occupancy and the replica lemma."""
    serve = _load("report_v1_serve.json")
    sv = serve["measured"]["serving"]
    assert sv["schema"] == SERVING_SCHEMA_ID
    assert sv["mode"] == "continuous"
    assert sv["kv_cache"]["peak_blocks"] > 0
    assert 0.0 < sv["kv_cache"]["peak_occupancy"] <= 1.0
    assert sv["throughput"]["wasted_decode_steps"] == 0
    assert sv["replica_lemma"]["predicted"]["replicas"] >= 1
    assert sv["replica_lemma"]["measured"]["t_step_s"] > 0


def test_golden_serve_rejects_serving_mutations():
    """Single-field mutations of the serving/v1 section must each be
    rejected; the deletion lists come from the validator's own tables."""
    golden = _load("report_v1_serve.json")
    for key in _SERVING_REQUIRED:
        d = copy.deepcopy(golden)
        d["measured"]["serving"].pop(key)
        with pytest.raises(ValueError):
            validate_report(d)
    for sect, keys in _SERVING_SUBKEYS.items():
        for key in keys:
            d = copy.deepcopy(golden)
            d["measured"]["serving"][sect].pop(key)
            with pytest.raises(ValueError):
                validate_report(d)
    corruptions = [
        lambda d: d["measured"]["serving"].update(
            schema="repro.api/serving/v0"),
        lambda d: d["measured"]["serving"].update(mode="adaptive"),
        lambda d: d["measured"]["serving"]["kv_cache"].update(
            peak_occupancy=1.5),
        lambda d: d["measured"]["serving"]["latency_s"].update(
            p50=d["measured"]["serving"]["latency_s"]["p99"] + 1.0),
        lambda d: d["measured"].pop("serving"),
    ]
    for corrupt in corruptions:
        d = copy.deepcopy(golden)
        corrupt(d)
        with pytest.raises(ValueError):
            validate_report(d)


def test_golden_metrics_validates():
    """The standalone metrics/v1 golden and the copy embedded in the train
    report both validate, and the train report carries the telemetry the
    observability layer promises (phase histograms + overlap gauges)."""
    m = _load("metrics_v1.json")
    validate_metrics(m)
    assert m["schema"] == METRICS_SCHEMA_ID
    train = _load("report_v1_train.json")
    validate_metrics(train["measured"]["metrics"])
    hists = train["measured"]["metrics"]["histograms"]
    for name in ("train/compute_s", "train/dist_update_s",
                 "train/param_update_s", "train/step_s",
                 "train/bucket_comm_s"):
        assert name in hists, f"train metrics missing {name}"
        for key in HISTOGRAM_KEYS:
            assert key in hists[name]
    assert "train/overlap_fraction" in train["measured"]["metrics"]["gauges"]


def test_golden_metrics_rejects_single_field_mutations():
    """Every single-field mutation the validator guards against must be
    rejected — section deletions, histogram-key deletions (derived from
    HISTOGRAM_KEYS so the list cannot drift), schema corruption, negative
    counters, and quantile disorder."""
    golden = _load("metrics_v1.json")
    hist_name = next(iter(golden["histograms"]))

    def mutations():
        for sect in ("schema", "counters", "gauges", "histograms"):
            yield lambda d, s=sect: d.pop(s)
        for key in HISTOGRAM_KEYS:
            yield lambda d, k=key: d["histograms"][hist_name].pop(k)
        yield lambda d: d.update(schema="repro.api/metrics/v0")
        yield lambda d: d["counters"].update({"train/steps": -1.0})
        yield lambda d: d["gauges"].update({"train/r_o": "high"})
        yield lambda d: d["histograms"][hist_name].update(
            p50=d["histograms"][hist_name]["max"] + 1.0)
        yield lambda d: d["histograms"][hist_name].update(count=0)

    for i, corrupt in enumerate(mutations()):
        d = copy.deepcopy(golden)
        corrupt(d)
        with pytest.raises(ValueError):
            validate_metrics(d)
    # and through the Report path: a corrupted embedded section is rejected
    train = _load("report_v1_train.json")
    d = copy.deepcopy(train)
    d["measured"]["metrics"]["schema"] = "repro.api/metrics/v0"
    with pytest.raises(ValueError):
        validate_report(d)


def test_golden_campaign_rejects_schema_corruption():
    raw = json.loads((GOLDENS / "campaign_v1.json").read_text())
    bad = copy.deepcopy(raw)
    bad["schema"] = "repro.api/campaign/v0"
    with pytest.raises(ValueError):
        Campaign.from_dict(bad)
    bad = copy.deepcopy(raw)
    bad["reports"][0].pop("plan")
    with pytest.raises(ValueError):
        Campaign.from_dict(bad)
