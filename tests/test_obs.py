"""repro.obs — tracer, metrics, and their reconciliation with the
measurements they replaced.

The telemetry layer's contract is in three parts, each tested here:

1. **Tracer semantics** — span nesting/ordering, the Chrome-trace export
   shape, and the disabled fast path being genuinely free (identity
   singleton + no lingering allocations).
2. **Metrics semantics** — histogram percentiles against numpy, reservoir
   bounds, counter monotonicity, the ``metrics/v1`` section/validator
   round trip.
3. **Reconciliation** — spans do not *add* a second clock next to the old
   ``time.perf_counter()`` pairs, they ARE the clock: the values feeding
   ``SyncReport`` and ``GenResult.stats()`` must equal the span durations
   exactly, and a traced overlapped ``Session.train`` must emit a
   Chrome-trace file plus a validated ``metrics/v1`` section whose phase
   spans reconcile with the SyncReport wall clocks within 5%.
"""
import json
import threading
import tracemalloc

import numpy as np
import pytest

from repro.obs import (METRICS_SCHEMA_ID, Histogram, MetricsRegistry,
                       NULL_TRACER, Tracer, percentile, validate_metrics)
from repro.obs.trace import NULL_SPAN


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_span_nesting_depth_and_order():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner", k=1):
            pass
        with tr.span("inner", k=2):
            pass
    # completion order: children before parents
    names = [e.name for e in tr.events()]
    assert names == ["inner", "inner", "outer"]
    inner1, inner2, outer = tr.events()
    assert outer.depth == 0 and inner1.depth == inner2.depth == 1
    assert inner1.args == {"k": 1} and inner2.args == {"k": 2}
    # containment: children inside the parent's interval, in order
    assert outer.t0_s <= inner1.t0_s <= inner1.t1_s <= inner2.t0_s
    assert inner2.t1_s <= outer.t1_s
    assert outer.dur_s >= inner1.dur_s + inner2.dur_s


def test_span_elapsed_is_the_measurement():
    """elapsed_s after exit equals the recorded duration — one clock."""
    tr = Tracer()
    with tr.span("phase") as sp:
        sum(range(1000))
    assert sp.elapsed_s == tr.events("phase")[0].dur_s
    assert tr.total_s("phase") == sp.elapsed_s


def test_tracer_per_thread_stacks():
    tr = Tracer()
    errs = []
    # barrier keeps all 4 threads alive at once (thread idents are recycled
    # after a join, which would collapse the tid assertion)
    barrier = threading.Barrier(4)

    def worker(i):
        try:
            barrier.wait(timeout=10)
            with tr.span("t", i=i):
                with tr.span("u", i=i):
                    pass
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    evs = tr.events()
    assert len(evs) == 8
    # each thread saw its own stack: depth 0 for "t", 1 for "u"
    for e in evs:
        assert e.depth == (0 if e.name == "t" else 1)
    assert len({e.tid for e in evs}) == 4


def test_disabled_tracer_zero_allocation_fast_path():
    tr = Tracer(enabled=False)
    # identity: every disabled span() is the one shared singleton
    assert tr.span("a") is NULL_SPAN is tr.span("b", x=1)
    assert NULL_TRACER.span("c") is NULL_SPAN
    with tr.span("a") as sp:
        pass
    assert sp.elapsed_s == 0.0 and len(tr) == 0
    # no allocations survive the call (the transient kwargs dict may exist
    # inside it; nothing may linger)
    tr.span("warmup", k=0)
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    for i in range(1000):
        with tr.span("hot", step=i):
            pass
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    growth = sum(s.size_diff for s in after.compare_to(before, "lineno")
                 if s.size_diff > 0)
    # allow a little interpreter noise, but nothing O(iterations)
    assert growth < 16_384, f"disabled tracer leaked {growth} bytes"
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_max_events_caps_memory_not_timing():
    tr = Tracer(max_events=3)
    durs = []
    for i in range(5):
        with tr.span("s", i=i) as sp:
            pass
        durs.append(sp.elapsed_s)
    assert len(tr) == 3 and tr.dropped == 2
    assert all(d > 0.0 for d in durs)  # capped spans still time correctly


def test_chrome_trace_shape_and_save(tmp_path):
    tr = Tracer()
    with tr.span("step", step=0):
        with tr.span("compute"):
            pass
    d = tr.chrome_trace(process_name="test")
    assert d["displayTimeUnit"] == "ms"
    evs = d["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"] == {"name": "test"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"step", "compute"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 1
    path = tr.save(tmp_path / "sub" / "trace.json")
    loaded = json.loads(path.read_text())
    # save() uses the default process name; content otherwise identical
    assert loaded == json.loads(json.dumps(tr.chrome_trace()))


def test_clear_resets_epoch_and_events():
    tr = Tracer()
    with tr.span("a"):
        pass
    assert len(tr) == 1
    tr.clear()
    assert len(tr) == 0
    with tr.span("b"):
        pass
    assert tr.events("b")[0].t0_s >= 0.0


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for values in (rng.normal(10, 3, 257), rng.exponential(1.0, 100),
                   np.array([4.2]), np.arange(10.0)):
        for p in (0, 10, 50, 90, 95, 99, 100):
            assert percentile(list(values), p) == pytest.approx(
                float(np.percentile(values, p)), rel=1e-12, abs=1e-12)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


def test_histogram_exact_until_reservoir_cap():
    h = Histogram(max_samples=1000)
    rng = np.random.default_rng(1)
    xs = rng.normal(0, 1, 500)
    for x in xs:
        h.observe(x)
    assert h.count == 500
    assert h.sum == pytest.approx(float(np.sum(xs)))
    assert h.min == float(np.min(xs)) and h.max == float(np.max(xs))
    for p in (50, 95, 99):
        assert h.quantile(p) == pytest.approx(float(np.percentile(xs, p)))
    s = h.summary()
    assert s["count"] == 500 and s["p50"] <= s["p95"] <= s["p99"]


def test_histogram_reservoir_bounds_memory_and_stays_sane():
    h = Histogram(max_samples=64, seed=0)
    for x in np.random.default_rng(2).uniform(0, 100, 10_000):
        h.observe(float(x))
    assert h.count == 10_000 and len(h._samples) == 64
    # quantiles of a uniform[0,100) sample stay in-range and ordered
    s = h.summary()
    assert 0 <= s["p50"] <= s["p95"] <= s["p99"] <= 100
    assert s["min"] <= s["p50"] and s["p99"] <= s["max"]
    # deterministic: same seed + stream -> same summary (CI reproducibility)
    h2 = Histogram(max_samples=64, seed=0)
    for x in np.random.default_rng(2).uniform(0, 100, 10_000):
        h2.observe(float(x))
    assert h2.summary() == s


def test_counter_monotonic_and_gauge_last_write():
    reg = MetricsRegistry()
    reg.inc("n", 2)
    reg.inc("n")
    assert reg.counter("n").value == 3.0
    with pytest.raises(ValueError):
        reg.inc("n", -1)
    reg.set_gauge("g", 1.0)
    reg.set_gauge("g", 2.5)
    assert reg.gauge("g").value == 2.5


def test_registry_section_validates_and_skips_empty_histograms():
    reg = MetricsRegistry()
    reg.inc("train/steps", 3)
    reg.set_gauge("train/r_o", 0.25)
    for v in (0.1, 0.2, 0.3):
        reg.observe("train/step_s", v)
    reg.histogram("train/empty")  # created but never observed
    sect = reg.section()
    assert sect["schema"] == METRICS_SCHEMA_ID
    assert "train/empty" not in sect["histograms"]
    assert validate_metrics(sect) is sect
    assert json.loads(json.dumps(sect)) == sect  # JSON-safe


def test_validate_metrics_rejects_malformed():
    good = MetricsRegistry()
    good.observe("h", 1.0)
    base = good.section()
    for mutate in (
        lambda d: d.update(schema="nope"),
        lambda d: d.pop("counters"),
        lambda d: d["histograms"]["h"].pop("p95"),
        lambda d: d["histograms"]["h"].update(count=0),
        lambda d: d["histograms"]["h"].update(p50=d["histograms"]["h"]["max"]
                                              + 1),
        lambda d: d["counters"].update(bad=-1),
    ):
        d = json.loads(json.dumps(base))
        mutate(d)
        with pytest.raises(ValueError):
            validate_metrics(d)


# ---------------------------------------------------------------------------
# Reconciliation: spans ARE the measurements
# ---------------------------------------------------------------------------


def test_trainer_spans_reconcile_with_sync_report(multi_device):
    """Serial trainer: the compute/dist_update/param_update spans of each
    step are exactly the phase values the loop folds into StepTimes, and
    the dist_update span total matches the SyncReport's measured comm."""
    from repro.configs.base import get_config
    from repro.distributed.trainer import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    cfg = get_config("granite-3-2b").reduced()
    run = RunConfig(attn_impl="dense", remat="none")
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=3)
    tracer = Tracer()
    metrics = MetricsRegistry()
    tr = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                             devices=multi_device[:2], tracer=tracer,
                             metrics=metrics)
    res = tr.train(batch=4, seq=32, steps=3, seed=0, log_every=0)
    rep = tr.report()
    # span totals vs the trainer's phase bookkeeping: same clock, so the
    # 5% tolerance guards plumbing (not noise) — they're identical floats
    comm_spans = [e.dur_s for e in tracer.events("dist_update")]
    assert len(comm_spans) == 3
    # report() averages the steady window (first 2 steps are warmup/compile)
    assert np.mean(comm_spans[2:]) == pytest.approx(rep.measured_comm_s,
                                                    rel=0.05)
    # the StepTimes the loop reports decompose exactly into the spans
    for st, sp_comm, sp_upd in zip(res.step_times,
                                   comm_spans,
                                   [e.dur_s for e in
                                    tracer.events("param_update")]):
        assert st.dist_update == pytest.approx(sp_comm, rel=1e-9)
        assert st.param_update == pytest.approx(sp_upd, rel=1e-9)
    # metrics published alongside
    sect = validate_metrics(metrics.section())
    assert sect["counters"]["train/steps"] == 3.0
    assert sect["histograms"]["train/dist_update_s"]["count"] == 3


def test_engine_stats_equal_span_durations(multi_device):
    """GenResult.stats() prefill/decode ARE the span durations (identity,
    not approximation — the satellite's 'values identical' requirement)."""
    from repro.configs.base import get_config
    from repro.models.blocks import RunConfig
    from repro.serve.engine import BatchScheduler, Engine

    cfg = get_config("granite-3-2b").reduced()
    tracer = Tracer()
    metrics = MetricsRegistry()
    eng = Engine(cfg, RunConfig(attn_impl="dense", remat="none"),
                 s_max=64, tracer=tracer, metrics=metrics)
    sched = BatchScheduler(eng, max_batch=2)
    rng = np.random.default_rng(0)
    for _ in range(3):
        sched.submit(rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32),
                     3)
    results = sched.run()
    assert len(results) == 3
    stats = [g.stats() for g in sched.history]
    prefills = [e.dur_s for e in tracer.events("prefill")]
    decodes = [e.dur_s for e in tracer.events("decode")]
    assert [s["prefill_s"] for s in stats] == prefills
    assert [s["decode_s"] for s in stats] == decodes
    sect = validate_metrics(metrics.section())
    assert sect["counters"]["serve/requests"] == 3.0
    assert sect["histograms"]["serve/prefill_s"]["count"] == len(prefills)
    assert sect["histograms"]["serve/queue_depth"]["max"] == 3.0


def test_overlapped_session_train_emits_trace_and_metrics(multi_device,
                                                          tmp_path):
    """The PR's acceptance path: an overlapped Session.train run emits a
    Chrome-trace file plus a validated metrics/v1 section whose per-phase
    span sums reconcile with the SyncReport wall clock within 5%."""
    from repro.api import JobSpec, Session

    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=6, batch=8,
                   seq=32, dp=2, sync="all_reduce", sync_overlap=True,
                   bucket_mb=0.05, log_every=0, trace_dir=str(tmp_path))
    sess = Session(spec)
    rep = sess.train()
    d = rep.to_dict()
    sync = d["measured"]["sync"]
    sect = validate_metrics(d["measured"]["metrics"])
    assert sect["gauges"]["train/overlap_fraction"] == \
        sync["overlap_fraction"]
    # per-bucket reconciliation: the last calibration step's bucket_sync
    # spans are per_bucket_comm_s (same clock -> 5% is plumbing tolerance)
    per_bucket = sync["per_bucket_comm_s"]
    spans = [e.dur_s for e in sess.last_tracer.events("bucket_sync")]
    assert spans[-len(per_bucket):] == pytest.approx(per_bucket, rel=0.05)
    # the trace file landed and carries the phase tree
    trace_path = tmp_path / "trace_train.json"
    assert str(trace_path) == d["meta"]["trace_file"]
    trace = json.loads(trace_path.read_text())
    names = {e.get("name") for e in trace["traceEvents"]}
    for needed in ("step", "compute", "dist_update", "bucket_sync",
                   "param_update", "fused_step"):
        assert needed in names
    buckets = [e for e in trace["traceEvents"]
               if e.get("name") == "bucket_sync"]
    assert all("bytes" in b["args"] and "bucket" in b["args"]
               for b in buckets)


def test_measuring_components_substitute_disabled_tracers(multi_device):
    """Passing a disabled tracer to a measuring component must not zero its
    measurements: the trainer/engine substitute a private live clock."""
    from repro.configs.base import get_config
    from repro.distributed.trainer import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig
    from repro.serve.engine import Engine

    cfg = get_config("granite-3-2b").reduced()
    run = RunConfig(attn_impl="dense", remat="none")
    tr = DataParallelTrainer(cfg, run, OptConfig(lr=1e-3),
                             strategy="all_reduce", devices=multi_device[:2],
                             tracer=NULL_TRACER)
    assert tr.tracer is not NULL_TRACER and tr.tracer.enabled
    res = tr.train(batch=4, seq=32, steps=2, seed=0, log_every=0)
    assert all(t.compute > 0 for t in res.step_times)
    eng = Engine(cfg, run, s_max=32, tracer=NULL_TRACER)
    assert eng.tracer is not NULL_TRACER and eng.tracer.enabled
    out = eng.generate(np.zeros((1, 4), np.int32), 2)
    assert out.prefill_s > 0 and out.decode_s > 0
