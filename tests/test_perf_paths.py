"""§Perf optimization paths must be numerically equivalent to baselines."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize


def _decode_seq(cfg, run, S=12):
    key = jax.random.PRNGKey(0)
    params = materialize(M.model_specs(cfg), key)
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
    caches = jax.tree_util.tree_map(
        jnp.zeros_like, materialize(M.cache_specs(cfg, 2, s_max=S), key))
    outs = []
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))
    for i in range(S):
        lg, caches = step(params, tokens[:, i : i + 1],
                          jnp.full((2,), i, jnp.int32), caches)
        outs.append(np.asarray(lg[:, 0], np.float32))
    return np.stack(outs, 1)


def test_cache_scatter_matches_onehot_gqa():
    cfg = get_config("granite-3-2b").reduced()
    a = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none"))
    b = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none",
                                   cache_scatter=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_cache_scatter_matches_onehot_swa_ring():
    cfg = get_config("gemma2-27b").reduced().replace(sliding_window=8)
    a = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none"), S=16)
    b = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none",
                                   cache_scatter=True), S=16)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_cache_scatter_matches_onehot_mla():
    cfg = get_config("minicpm3-4b").reduced()
    a = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none"))
    b = _decode_seq(cfg, RunConfig(attn_impl="dense", remat="none",
                                   cache_scatter=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_logit_sharding_noop_on_single_device():
    """The logit constraint must not change values (single-device: no-op
    sharding, value equality is exact)."""
    cfg = get_config("granite-3-2b").reduced()
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    run_a = RunConfig(attn_impl="dense", remat="none")
    la, _, _ = M.forward(params, {"tokens": toks}, cfg, run_a)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run_b = RunConfig(attn_impl="dense", remat="none",
                      logit_sharding=NamedSharding(mesh, P(None, None, None)))
    lb, _, _ = M.forward(params, {"tokens": toks}, cfg, run_b)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_int8_kv_cache_close_to_bf16():
    """int8-quantized KV cache: greedy decode tokens should match and logits
    stay close to the bf16-cache path."""
    cfg = get_config("granite-3-2b").reduced()
    run = RunConfig(attn_impl="dense", remat="none", cache_scatter=True)
    key = jax.random.PRNGKey(0)
    params = materialize(M.model_specs(cfg), key)
    S = 24
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)

    def roll(quant):
        caches = jax.tree_util.tree_map(
            jnp.zeros_like,
            materialize(M.cache_specs(cfg, 2, s_max=S, kv_quant=quant), key))
        step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))
        outs = []
        for i in range(S):
            lg, caches = step(params, tokens[:, i:i+1],
                              jnp.full((2,), i, jnp.int32), caches)
            outs.append(np.asarray(lg[:, 0], np.float32))
        return np.stack(outs, 1)

    a, b = roll(False), roll(True)
    # greedy decisions must agree on the vast majority of steps (random-init
    # logits have near-ties, so a margin below 1.0 is expected)
    agree = np.mean(np.argmax(a, -1) == np.argmax(b, -1))
    assert agree >= 0.8, agree
    # logits close in aggregate
    assert np.mean(np.abs(a - b)) < 0.15 * np.mean(np.abs(a))
