"""repro.analysis — known-bad/known-good fixtures per rule + repo self-run.

Every analyzer must (a) flag its known-bad fixture with the exact finding
code, (b) stay silent on the known-good twin, and (c) the combined pass
must run *clean* on this repo (zero unbaselined findings) — the same gate
``tools/repro_lint.py`` enforces in CI.
"""
import importlib.util
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (apply_baseline, determinism, kernel_contracts,
                            load_baseline, make_baseline, mesh_axes,
                            run_analyzers, schema_drift, validate_baseline,
                            validate_findings)
from repro.analysis.findings import Finding, make_findings_payload

REPO = Path(__file__).resolve().parent.parent


def codes(findings):
    return sorted({f.code for f in findings})


def dedent(s):
    return textwrap.dedent(s).lstrip()


# ---------------------------------------------------------------------------
# Kernel contracts (KC1xx)
# ---------------------------------------------------------------------------


def test_kc101_block_must_tile_array():
    c = kernel_contracts.KernelContract(
        op="flash_attention", context="fixture", grid=(1, 1, 2),
        blocks=(kernel_contracts.Block("q", (1, 48, 128), 2, "in",
                                       (1, 128, 128)),))
    assert codes(kernel_contracts.check_contract(c)) == ["KC101"]


def test_kc102_lane_misalignment():
    # last dim 100: not a lane multiple, not the full array dim
    c = kernel_contracts.KernelContract(
        op="flash_attention", context="fixture", grid=(1, 2),
        blocks=(kernel_contracts.Block("q", (8, 100), 2, "in",
                                       (8, 200)),))
    assert "KC102" in codes(kernel_contracts.check_contract(c))


def test_kc103_sublane_misalignment():
    # bf16 wants sublane %16; 12 is split (array 24), not 1, not full
    c = kernel_contracts.KernelContract(
        op="flash_attention", context="fixture", grid=(2,),
        blocks=(kernel_contracts.Block("q", (12, 128), 2, "in",
                                       (24, 128)),))
    assert codes(kernel_contracts.check_contract(c)) == ["KC103"]


def test_kc104_ssd_chunk_contract():
    c, findings = kernel_contracts.ssd_contract(
        B=1, H=4, L=100, P=64, N=128, chunk=64, context="fixture")
    assert c is None and codes(findings) == ["KC104"]


def test_kc105_vmem_budget():
    # a 256 MiB block cannot fit the 64 MiB (vmem/2) budget
    c = kernel_contracts.KernelContract(
        op="flash_attention", context="fixture", grid=(1,),
        blocks=(kernel_contracts.Block("q", (16384, 4096), 4, "scratch"),))
    assert "KC105" in codes(kernel_contracts.check_contract(c))


def test_kc106_gqa_head_mapping():
    c, findings = kernel_contracts.flash_contract(
        B=1, H=7, KV=2, Sq=128, Sk=128, D=64, context="fixture")
    assert c is None and codes(findings) == ["KC106"]


def test_kc_known_good_contract_is_clean():
    c, findings = kernel_contracts.flash_contract(
        B=1, H=8, KV=2, Sq=4096, Sk=4096, D=128, context="fixture")
    assert not findings
    assert kernel_contracts.check_contract(c) == []


def test_kc_registry_clean_and_audited():
    findings, audit = kernel_contracts.check_registry()
    assert findings == [], [str(f) for f in findings]
    from repro.kernels.ops import TUNABLE_OPS
    for op in TUNABLE_OPS:
        # acceptance: every tunable op checked against >= 2 registry
        # configs (distinct archs, not just dtype variants)
        archs = {ctx.split(":")[1] for ctx in audit[op]}
        assert len(archs) >= 2, (op, audit[op])


def test_kc_mla_decode_wide_lane_is_admitted():
    # deepseek-v2 absorbed MLA decode: D=576 (not a lane multiple) must
    # pass as a full, 8-aligned unsplit dim
    c, findings = kernel_contracts.decode_contract(
        B=1, H=128, KV=1, S=32768, D=576, context="fixture")
    assert not findings and kernel_contracts.check_contract(c) == []


def test_kc107_stage_overflow_fires_on_tiny_hbm():
    # known-bad fixture: a 2 GiB chip cannot hold granite's pipe=4 stage
    # working set at m=64 — every stage must flag
    import dataclasses

    from repro.configs.base import get_config, get_shape
    from repro.core.hardware import TPU_V5E

    tiny = dataclasses.replace(TPU_V5E, hbm_bytes=2 * 2 ** 30,
                               name="tiny-hbm")
    found = kernel_contracts.pipeline_stage_findings(
        get_config("granite-3-2b"), get_shape("train_4k"),
        pipe=4, n_microbatch=64, dp=2, chip=tiny, context="fixture")
    assert found and codes(found) == ["KC107"]


def test_kc107_uncuttable_pipe_is_a_finding():
    from repro.configs.base import get_config, get_shape

    cfg = get_config("granite-3-2b")
    cycles = (cfg.num_layers - cfg.first_k_dense) // len(cfg.pattern)
    found = kernel_contracts.pipeline_stage_findings(
        cfg, get_shape("train_4k"), pipe=cycles + 1,
        n_microbatch=2 * (cycles + 1), dp=1, context="fixture")
    assert codes(found) == ["KC107"]
    assert "non-empty stages" in found[0].message


def test_kc107_pipeline_registry_clean_and_audited():
    findings, audit = kernel_contracts.check_pipeline_registry()
    assert findings == [], [str(f) for f in findings]
    # non-vacuous: the Eq.-5 gate admits cells at both pipe depths
    cells = audit["pipeline_stage"]
    assert len(cells) >= 3, cells
    depths = {c.split(":")[3] for c in cells}
    assert {"p2", "p4"} <= depths, cells


# ---------------------------------------------------------------------------
# Determinism (DT1xx)
# ---------------------------------------------------------------------------


DT_BAD_RNG = dedent("""
    import numpy as np
    import random

    def sample():
        a = np.random.rand(4)                  # legacy global RNG
        rng = np.random.default_rng()          # unseeded generator
        r = random.Random()                    # unseeded instance
        x = random.random()                    # module-level draw
        return a, rng, r, x
""")

DT_GOOD_RNG = dedent("""
    import numpy as np
    import random

    def sample(seed):
        rng = np.random.default_rng(seed)
        r = random.Random(seed)
        return rng.standard_normal(4), r.random()
""")


def test_dt101_unseeded_rng():
    found = determinism.analyze_source(DT_BAD_RNG, "src/repro/fix.py")
    assert codes(found) == ["DT101"] and len(found) == 4


def test_dt101_seeded_rng_is_clean():
    assert determinism.analyze_source(DT_GOOD_RNG, "src/repro/fix.py") == []


DT_BAD_CLOCK = dedent("""
    import time
    from time import perf_counter as pc

    def measure(fn):
        t0 = time.perf_counter()
        fn()
        return pc() - t0
""")

DT_GOOD_CLOCK = dedent("""
    from repro.obs.trace import monotonic

    def measure(fn):
        t0 = monotonic()
        fn()
        return monotonic() - t0
""")


def test_dt102_wall_clock_reads():
    found = determinism.analyze_source(DT_BAD_CLOCK, "src/repro/fix.py")
    assert codes(found) == ["DT102"] and len(found) == 2


def test_dt102_exempts_the_clock_module():
    assert determinism.analyze_source(
        DT_BAD_CLOCK, "src/repro/obs/trace.py") == []


def test_dt102_monotonic_is_clean():
    assert determinism.analyze_source(DT_GOOD_CLOCK, "src/repro/fix.py") == []


DT_BAD_SYNC = dedent("""
    import jax
    import numpy as np

    def sync_phase(grads, axis):
        g = jax.lax.psum(grads, axis)
        host = float(g.sum())       # device->host sync inside the phase
        arr = np.asarray(g)
        return host, arr, g.mean().item()
""")

DT_GOOD_SYNC = dedent("""
    import jax

    def sync_phase(grads, axis):
        return jax.lax.psum(grads, axis)

    def report(metrics):
        return float(metrics["loss"])  # no collective in this scope
""")


def test_dt103_host_sync_in_collective_phase():
    found = determinism.analyze_source(DT_BAD_SYNC, "src/repro/fix.py")
    assert codes(found) == ["DT103"] and len(found) == 3


def test_dt103_host_sync_outside_collectives_is_clean():
    assert determinism.analyze_source(DT_GOOD_SYNC, "src/repro/fix.py") == []


DT_BAD_WRITE = dedent("""
    import json
    import numpy as np

    def save_meta(d, meta):
        (d / "meta.json").write_text(json.dumps(meta))

    def save_arrays(d, arrays):
        with open(d / "step.npz", "wb") as f:
            np.savez(f, **arrays)
""")

DT_GOOD_WRITE = dedent("""
    import json
    import os
    import numpy as np

    def save_meta(d, meta):
        tmp = d / "meta.json.tmp"
        tmp.write_text(json.dumps(meta))
        os.replace(tmp, d / "meta.json")

    def save_arrays(d, arrays):
        tmp = d / "step.npz.tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        tmp.replace(d / "step.npz")  # Path.replace = same atomic syscall
""")


def test_dt104_bare_write_in_checkpoint_path():
    found = determinism.analyze_source(DT_BAD_WRITE,
                                       "src/repro/checkpoint/fix.py")
    assert codes(found) == ["DT104"] and len(found) == 2


def test_dt104_tmp_plus_replace_is_clean():
    assert determinism.analyze_source(DT_GOOD_WRITE,
                                      "src/repro/checkpoint/fix.py") == []


def test_dt104_scoped_to_checkpoint_subtree():
    # the same bare writes elsewhere in the repo are some other rule's
    # problem — DT104 only guards the checkpoint protocol
    assert determinism.analyze_source(DT_BAD_WRITE, "src/repro/fix.py") == []


# ---------------------------------------------------------------------------
# Mesh axes (MX1xx)
# ---------------------------------------------------------------------------


MX_DECL = dedent("""
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(devices, ("nodes", "data"))
    spec = P("data", None)
""")

MX_BAD = dedent("""
    import jax

    def sync(g):
        return jax.lax.psum(g, "model")  # axis never declared
""")

MX_MISSING = dedent("""
    import jax

    def sync(g):
        return jax.lax.psum(g)  # no axis at all
""")

MX_GOOD = dedent("""
    import jax

    def sync(g):
        return jax.lax.psum(g, ("nodes", "data"))
""")


def test_mx101_unbound_axis():
    found = mesh_axes.analyze_sources(
        [("src/repro/mesh.py", MX_DECL), ("src/repro/bad.py", MX_BAD)])
    assert codes(found) == ["MX101"]


def test_mx102_missing_axis_argument():
    found = mesh_axes.analyze_sources([("src/repro/bad.py", MX_MISSING)])
    assert codes(found) == ["MX102"]


def test_mx_bound_axes_are_clean():
    assert mesh_axes.analyze_sources(
        [("src/repro/mesh.py", MX_DECL), ("src/repro/ok.py", MX_GOOD)]) == []


def test_mx_variable_axis_is_skipped():
    src = dedent("""
        import jax

        def sync(g, axis):
            return jax.lax.psum(g, axis)
    """)
    assert mesh_axes.analyze_sources([("src/repro/var.py", src)]) == []


def test_mx_repo_declares_the_pipe_axis():
    """The 1F1B trainer's (pipe, data) grid must keep the ``pipe`` axis in
    the repo-global declared set — a rename there would silently orphan
    any collective that reduces over it."""
    axes = set()
    for p in sorted((REPO / "src" / "repro").rglob("*.py")):
        axes |= mesh_axes.declared_axes(
            p.read_text(), p.relative_to(REPO).as_posix())
    assert {"data", "nodes", "pipe"} <= axes, sorted(axes)


# ---------------------------------------------------------------------------
# Schema drift (SD1xx)
# ---------------------------------------------------------------------------


def test_sd101_orphan_schema_id():
    src = 'SCHEMA_ID = "repro.api/phantom/v9"\n'
    found = schema_drift.analyze_literals(
        [("src/repro/phantom.py", src)], schema_drift.known_schema_ids())
    assert any(f.code == "SD101" for f in found)
    assert all(f.code in ("SD101", "SD102") for f in found)


def test_sd_known_ids_have_validators_and_no_orphans():
    known = schema_drift.known_schema_ids()
    assert "repro.api/report/v1" in known
    assert "repro.analysis/findings/v1" in known
    pairs = []
    for d in schema_drift.SCAN_DIRS:
        pairs.extend((p.relative_to(REPO).as_posix(), p.read_text())
                     for p in sorted((REPO / d).rglob("*.py")))
    assert schema_drift.analyze_literals(pairs, known) == []


def test_sd103_histogram_keys_reconcile():
    assert schema_drift.check_histogram_keys() == []


def test_sd104_sd105_goldens(tmp_path):
    g = tmp_path / "tests" / "goldens"
    g.mkdir(parents=True)
    (g / "report_broken.json").write_text('{"schema": "nope"}')
    (g / "mystery_thing.json").write_text("{}")
    got = {f.code for f in schema_drift.check_goldens(tmp_path)}
    assert got == {"SD104", "SD105"}


def test_sd_repo_goldens_validate():
    assert schema_drift.check_goldens(REPO) == []


# ---------------------------------------------------------------------------
# Baseline + findings schema plumbing
# ---------------------------------------------------------------------------


def test_baseline_roundtrip_and_stale(tmp_path):
    f1 = Finding("src/repro/a.py", 10, "DT102", "clock", "f")
    f2 = Finding("src/repro/b.py", 20, "DT101", "rng", "g")
    doc = make_baseline([f1], {f1.fingerprint: "justified: startup only"})
    validate_baseline(doc)
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(doc))
    sup = load_baseline(p)
    kept, suppressed, stale = apply_baseline([f1, f2], sup)
    assert kept == [f2] and suppressed == [f1] and stale == []
    # fingerprints are line-stable: moving the finding keeps it suppressed
    moved = Finding("src/repro/a.py", 99, "DT102", "clock", "f")
    kept2, suppressed2, _ = apply_baseline([moved], sup)
    assert kept2 == [] and suppressed2 == [moved]
    # a suppression matching nothing is reported stale
    _, _, stale3 = apply_baseline([f2], sup)
    assert stale3 == [f1.fingerprint]


def test_baseline_requires_reasons():
    with pytest.raises(ValueError):
        validate_baseline({"schema": "repro.analysis/baseline/v1",
                           "suppressions": [{"fingerprint": "A:b:c",
                                             "reason": ""}]})


def test_findings_payload_validates():
    f = Finding("src/repro/a.py", 1, "MX101", "axis", "fn")
    payload = make_findings_payload([f], [], [], 0.5)
    validate_findings(payload)
    assert payload["clean"] is False
    clean = make_findings_payload([], [f], ["X:y:z"], 0.1)
    validate_findings(clean)
    assert clean["clean"] is True


# ---------------------------------------------------------------------------
# Self-run: the repo itself is clean, and the CLI gate agrees
# ---------------------------------------------------------------------------


def _load_cli():
    spec = importlib.util.spec_from_file_location(
        "repro_lint", REPO / "tools" / "repro_lint.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_repo_self_run_is_clean():
    findings = run_analyzers(REPO)
    sup = load_baseline(REPO / "tools" / "lint_baseline.json")
    unbaselined, _, stale = apply_baseline(findings, sup)
    assert unbaselined == [], [str(f) for f in unbaselined]
    assert stale == [], stale


def test_cli_exits_zero_on_repo_and_writes_valid_payload(tmp_path, capsys):
    cli = _load_cli()
    out = tmp_path / "findings.json"
    assert cli.main(["--json", "--out", str(out)]) == 0
    payload = json.loads(out.read_text())
    validate_findings(payload)
    assert payload["clean"] and payload["findings"] == []
    capsys.readouterr()


def test_cli_exits_nonzero_on_known_bad_tree(tmp_path, capsys):
    bad = tmp_path / "src" / "repro"
    bad.mkdir(parents=True)
    (bad / "bad.py").write_text(DT_BAD_CLOCK + DT_BAD_RNG)
    cli = _load_cli()
    assert cli.main(["--root", str(tmp_path),
                     "--analyzer", "determinism"]) == 1
    capsys.readouterr()
