"""Topology-aware cluster API: ClusterSpec tiers, tier-aware Lemma 3.2,
planner schedule selection on hierarchies, Plan topology round-trips, and
Session.sweep campaigns (the ISSUE-3 acceptance surface)."""
import json

import pytest

from repro.core import ps
from repro.core.hardware import (CLUSTERS, ClusterSpec, MeshSpec, MULTI_POD,
                                 SINGLE_POD, TPU_V5E, Tier, get_cluster)


# ---------------------------------------------------------------------------
# ClusterSpec geometry + serialization
# ---------------------------------------------------------------------------


def test_cluster_geometry_and_bottleneck():
    c = get_cluster("2x4")
    assert c.n_chips == 8
    assert c.tier_sizes == (4, 2)
    assert not c.uniform
    assert c.bottleneck_tier == "cluster"
    assert c.min_bw == c.tier("cluster").bw < c.tier("node").bw

    flat = ClusterSpec.flat(8)
    assert flat.n_chips == 8 and flat.uniform
    assert flat.min_bw == TPU_V5E.link_bw
    with pytest.raises(KeyError):
        c.tier("rack")
    with pytest.raises(KeyError):
        get_cluster("no-such-cluster")


def test_tier_validation():
    with pytest.raises(ValueError):
        Tier("node", 0, 1e9)
    with pytest.raises(ValueError):
        Tier("node", 4, 0.0)
    with pytest.raises(ValueError):
        Tier("node", 4, 1e9, latency=-1.0)
    with pytest.raises(ValueError):
        ClusterSpec("empty", TPU_V5E, ())


def test_cluster_dict_roundtrip():
    for name, c in CLUSTERS.items():
        back = ClusterSpec.from_dict(c.to_dict())
        assert back == c, name
    # chip identity survives (the paper-era K80 cluster) …
    p2 = get_cluster("p2-2x8")
    assert ClusterSpec.from_dict(p2.to_dict()).chip.name == "k80-gk210"
    # … and an unknown chip fails loudly instead of silently repricing
    bad = p2.to_dict()
    bad["chip"] = "h100-sxm"
    with pytest.raises(KeyError):
        ClusterSpec.from_dict(bad)


def test_dp_view_packs_tp_innermost():
    # MULTI_POD: 2 pods x 256 chips, tp=16 consumed in-pod
    tiers = MULTI_POD.cluster.dp_view(MULTI_POD.dp, MULTI_POD.tp)
    assert tuple(t.size for t in tiers) == (16, 2)
    assert tiers[0].name == "pod" and tiers[1].name == "dcn"
    # flat single pod: one spanning tier of dp
    tiers = SINGLE_POD.cluster.dp_view(SINGLE_POD.dp, SINGLE_POD.tp)
    assert tuple(t.size for t in tiers) == (16,)
    with pytest.raises(ValueError):
        get_cluster("2x4").dp_view(4, 1)  # 4*1 != 8 chips


def test_mesh_cluster_defaults_flat():
    """Omitted topology => single-tier cluster equivalent to the old
    scalar-link_bw mesh (backward compatibility)."""
    mesh = MeshSpec(chips=8, dp=8, tp=1)
    c = mesh.cluster
    assert c.uniform and c.n_chips == 8 and c.min_bw == mesh.chip.link_bw
    m2 = MeshSpec.from_cluster(get_cluster("2x4"))
    assert (m2.chips, m2.dp, m2.tp) == (8, 8, 1)
    with pytest.raises(ValueError):
        MeshSpec.from_cluster(get_cluster("2x4"), tp=3)


# ---------------------------------------------------------------------------
# Tier-aware Lemma 3.2
# ---------------------------------------------------------------------------


def test_hier_wire_bytes_shrinks_outward():
    """Each outer tier only carries the shard that survived the inner
    reductions — the FireCaffe reduction-tree property."""
    wires = ps.hier_wire_bytes(1e9, (4, 2, 2))
    assert wires[0] == pytest.approx(2e9 * 3 / 4)
    assert wires[1] == pytest.approx(2 * (1e9 / 4) / 2)
    assert wires[2] == pytest.approx(2 * (1e9 / 8) / 2)
    assert wires[0] > wires[1] > wires[2]
    # degenerate single tier == the flat form
    assert ps.hier_wire_bytes(1e9, (8,))[0] == ps.flat_wire_bytes(1e9, 8)


def test_hier_comm_time_beats_flat_on_slow_cross_tier():
    c = get_cluster("2x4")
    tiers = c.dp_view(8, 1)
    s_p = 1e9
    hier, per_tier = ps.hier_comm_time(s_p, tiers)
    flat = ps.flat_wire_bytes(s_p, 8) / c.min_bw
    assert hier < flat
    assert [p["tier"] for p in per_tier] == ["node", "cluster"]
    assert hier == pytest.approx(sum(p["time_s"] for p in per_tier))
    # predicted_comm_time speaks the same form
    assert ps.predicted_comm_time("hier_all_reduce", s_p, 8, c.min_bw,
                                  tiers=tiers) == pytest.approx(hier)


def test_ps_placement_regimes():
    """Lemma 3.2's B_ps is a placement choice: in-node servers ride the
    fast tier and need fewer of themselves than cross-node servers."""
    c = get_cluster("2x4")
    s_p, n_w, t_c = 4e9, 8, 0.5
    plan = ps.ps_placement_plan(s_p, n_w, c, t_c)
    assert plan["in_node"]["b_ps"] == c.tiers[0].bw
    assert plan["cross_node"]["b_ps"] == c.min_bw
    assert plan["in_node"]["n_ps"] <= plan["cross_node"]["n_ps"]
    assert plan["recommended"] == "in_node"
    assert ps.n_parameter_servers_tiered(s_p, n_w, c, t_c,
                                         placement="in_node") == \
        plan["in_node"]["n_ps"]
    # both regimes still satisfy the lemma's maskability
    for reg in ("in_node", "cross_node"):
        assert ps.masked(s_p, n_w, plan[reg]["n_ps"], plan[reg]["b_ps"], t_c)
    with pytest.raises(KeyError):
        ps.ps_placement_bw(c, "on_the_moon")


def test_grad_sync_plan_prices_latency_on_both_sides():
    """Per-tier latency must hit the flat ring too (it spans every tier),
    so a latency-heavy hierarchy cannot bias selection flat-ward."""
    tiers = (Tier("node", 4, 50e9, latency=0.0),
             Tier("cluster", 2, 2.5e9, latency=5e-3))
    s_p = 1e6  # tiny payload: latency dominates wire time
    plan = ps.grad_sync_plan(s_p, tiers, t_c=1.0)
    hier_time = ps.hier_comm_time(s_p, tiers)[0]
    flat_time = ps.flat_wire_bytes(s_p, 8) / 2.5e9 + 5e-3
    assert plan.comm_time == pytest.approx(min(hier_time, flat_time))
    # uniform branch: a single spanning tier's latency lands in comm_time
    uni = ps.grad_sync_plan(s_p, (Tier("pod", 8, 50e9, latency=2e-3),),
                            t_c=1.0)
    assert uni.comm_time == pytest.approx(
        ps.flat_wire_bytes(s_p, 8) / 50e9 + 2e-3)


def test_grad_sync_plan_uniform_matches_tpu_form():
    tiers = (Tier("pod", 16, 50e9),)
    got = ps.grad_sync_plan(8e9, tiers, t_c=1.0)
    ref = ps.tpu_grad_sync_plan(8e9, 16, 50e9, t_c=1.0)
    assert got.schedule == ref.schedule == "reduce_scatter_all_gather"
    assert got.comm_time == ref.comm_time
    assert got.bottleneck_tier == "pod"


def test_grad_sync_plan_picks_hier_on_hierarchy():
    tiers = get_cluster("2x4").dp_view(8, 1)
    plan = ps.grad_sync_plan(8e9, tiers, t_c=10.0)
    assert plan.schedule == "hier_all_reduce"
    assert plan.per_tier and len(plan.per_tier) == 2
    assert plan.bottleneck_tier == "cluster"
    assert plan.comm_time < ps.flat_wire_bytes(8e9, 8) / min(t.bw for t in tiers)


# ---------------------------------------------------------------------------
# Planner: topology changes the plan (acceptance criterion)
# ---------------------------------------------------------------------------


def test_plan_diverges_flat_vs_tiered_8_chips():
    """plan() on a 2-node x 4-chip ClusterSpec selects a different sync
    schedule (and bottleneck tier) than the equivalent flat 8-chip mesh."""
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import plan_train

    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    flat = plan_train(cfg, shape, MeshSpec.from_cluster(get_cluster("flat8")))
    tiered = plan_train(cfg, shape, MeshSpec.from_cluster(get_cluster("2x4")))
    assert flat.mesh == tiered.mesh == (8, 1)
    assert (flat.sync_schedule, flat.bottleneck_tier) != \
        (tiered.sync_schedule, tiered.bottleneck_tier)
    assert tiered.sync_schedule == "hier_all_reduce"
    assert tiered.bottleneck_tier == "cluster"
    strat = tiered.resolve_sync()
    assert strat.name == "hier_all_reduce" and strat.tiers == (4, 2)


def test_estimate_step_time_prices_tiers():
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import estimate_step_time

    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    flat = estimate_step_time(cfg, shape,
                              MeshSpec.from_cluster(get_cluster("flat8")),
                              "block", 1)
    tiered = estimate_step_time(cfg, shape,
                                MeshSpec.from_cluster(get_cluster("2x4")),
                                "block", 1)
    for terms in (flat, tiered):
        assert terms["collective"] == pytest.approx(
            terms["collective_grad"] + terms["collective_tp"])
    # same compute, but the slow cross-node tier makes sync dearer even
    # with the hierarchical schedule
    assert tiered["compute"] == flat["compute"]
    assert tiered["collective_grad"] > flat["collective_grad"]


def test_plan_topology_json_roundtrip_and_legacy_link_bw():
    from repro.configs.base import get_config, get_shape
    from repro.core.planner import Plan, plan_train

    p = plan_train(get_config("granite-3-2b"), get_shape("train_4k"),
                   MeshSpec.from_cluster(get_cluster("2x4")))
    q = Plan.from_json(p.to_json())
    assert q == p
    assert q.cluster == get_cluster("2x4")
    assert q.link_bw == get_cluster("2x4").min_bw
    # a pre-topology plan dict (scalar link_bw) migrates to a flat cluster
    d = p.to_dict()
    d.pop("topology")
    d.pop("bottleneck_tier")
    d["link_bw"] = 7e9
    legacy = Plan.from_dict(d)
    assert legacy.cluster is not None and legacy.cluster.uniform
    assert legacy.link_bw == 7e9


# ---------------------------------------------------------------------------
# Session.sweep acceptance: >= 8 validated cells + Pareto summary
# ---------------------------------------------------------------------------


def test_session_sweep_campaign_pareto():
    from repro.api import (CAMPAIGN_SCHEMA_ID, Campaign, JobSpec, Session,
                           validate_report)

    base = JobSpec(arch="granite-3-2b", steps=2, batch=4, seq=32)
    camp = Session.sweep(base, {
        "topology": ["flat8", "2x4"],
        "arch": ["granite-3-2b", "mamba2-780m"],
        "batch": [4, 8],
    }, kind="plan")
    assert len(camp) == 8 and not camp.skipped
    for rep in camp.reports:
        validate_report(json.loads(rep.to_json()))
    summary = camp.summary()
    assert summary["n_ok"] == 8
    assert summary["pareto"], "Pareto front must be non-empty"
    front = camp.pareto()
    metrics = camp.metrics()
    # front members are non-dominated
    for i in front:
        for j, q in enumerate(metrics):
            if j == i:
                continue
            assert not (q["tokens_per_s"] > metrics[i]["tokens_per_s"]
                        and q["efficiency"] > metrics[i]["efficiency"])
    # tiered cells surface the hierarchy in their plan
    by_topo = {c["topology"]: m for c, m in zip(camp.cells, metrics)}
    assert by_topo["2x4"]["schedule"] == "hier_all_reduce"
    assert by_topo["flat8"]["schedule"] != "hier_all_reduce"
    # the campaign artifact round-trips
    d = json.loads(camp.to_json())
    assert d["schema"] == CAMPAIGN_SCHEMA_ID
    back = Campaign.from_json(camp.to_json())
    assert len(back) == 8 and back.summary()["pareto_indices"] == \
        summary["pareto_indices"]


def test_sweep_records_invalid_cells_as_skipped():
    from repro.api import JobSpec, Session

    base = JobSpec(arch="granite-3-2b", steps=2, batch=4, seq=32)
    camp = Session.sweep(base, {"dp": [1, 3]}, kind="plan")  # 4 % 3 != 0
    assert len(camp) == 1 and len(camp.skipped) == 1
    assert "dp" in camp.skipped[0]["cell"]
    with pytest.raises(ValueError):
        Session.sweep(base, {}, kind="plan")
    with pytest.raises(ValueError):
        Session.sweep(base, {"dp": [1]}, kind="explode")


def test_jobspec_topology_validation_and_roundtrip():
    from repro.api import JobSpec, TOPOLOGIES

    assert "" in TOPOLOGIES and "2x4" in TOPOLOGIES
    with pytest.raises(ValueError):
        JobSpec(arch="granite-3-2b", topology="ring-of-fire")
    spec = JobSpec(arch="granite-3-2b", topology="2x4", steps=2)
    assert JobSpec.from_json(spec.to_json()) == spec


@pytest.mark.slow
def test_sweep_quick_benchmark_emits_campaign_schema(tmp_path):
    """The CI smoke cell: `sweep --quick` (1 arch x 2 sync x 2 dp training
    cells on 2 CPU-pinned devices) must emit a valid campaign artifact.
    Full sweeps stay out of tier-1 (slow marker)."""
    import subprocess
    import sys

    from conftest import REPO

    out = tmp_path / "campaign.json"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep", "--quick",
         "--out", str(out)],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=str(REPO), capture_output=True, text=True, timeout=520)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    from repro.api import Campaign

    camp = Campaign.from_json(out.read_text())
    assert len(camp) == 4 and camp.kind == "train"
    m = camp.metrics()
    assert all(c["source"] == "measured" and c["tokens_per_s"] > 0 for c in m)
    assert camp.summary()["pareto"]


def test_session_predicted_carries_tier_view():
    from repro.api import JobSpec, Session

    rep = Session(JobSpec(arch="granite-3-2b", steps=2,
                          topology="2x4")).plan()
    l32 = rep.predicted["lemma32"]
    assert l32["schedule"] == "hier_all_reduce"
    assert l32["bottleneck_tier"] == "cluster"
    assert l32["ps_placement"]["recommended"] in ("in_node", "cross_node")
    assert rep.plan["topology"]["name"] == "2x4"
    # flat session: no placement block, same schema otherwise
    flat = Session(JobSpec(arch="granite-3-2b", steps=2,
                           topology="flat8")).plan()
    assert "ps_placement" not in flat.predicted["lemma32"]
