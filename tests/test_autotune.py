"""Closed-loop autotuner + the paper's memory procedure (ISSUE 4).

Fast-tier coverage of the Eq.-5 minibatch search edge cases, the Table-2
conv-algorithm ordering, `train_memory` at the dp/tp extremes, the
Calibration overlay/cache, and the acceptance property end to end:
`Session.tune()` returns a validated Report whose chosen minibatch is the
largest batch satisfying `m_bound`, and the calibrated re-plan lands closer
to the measured step time than the datasheet prediction.
"""
import json

import pytest

from repro.configs.base import get_config, get_shape
from repro.core import memory_model as mm
from repro.core.autotune import (Calibration, cached_calibration,
                                 choose_conv_algs, save_calibration,
                                 TUNING_SCHEMA_ID)
from repro.core.hardware import ClusterSpec, MeshSpec, Tier, TPU_V5E


# ---------------------------------------------------------------------------
# Eq. 5: the minibatch bound and its binary search
# ---------------------------------------------------------------------------


def test_m_bound_negative_at_infeasible_minibatch():
    hbm = TPU_V5E.hbm_bytes
    assert mm.m_bound(mm.ALEXNET, 1, hbm) > 0
    # m_fm is linear in X_mini, so some batch always breaks the budget
    assert mm.m_bound(mm.ALEXNET, 10_000_000, hbm) < 0


def test_max_x_mini_matches_brute_force():
    # small budget keeps the brute-force check cheap (AlexNet's classifier
    # alone needs ~700 MB at the paper's fp32 x3, so 1 GiB leaves room for
    # only a few dozen samples)
    m_gpu = 1 * 2 ** 30
    x_star = mm.max_x_mini(mm.ALEXNET, m_gpu)
    assert x_star >= 1
    assert mm.m_bound(mm.ALEXNET, x_star, m_gpu) >= 0
    assert mm.m_bound(mm.ALEXNET, x_star + 1, m_gpu) < 0
    brute = max(x for x in range(1, x_star + 2)
                if mm.m_bound(mm.ALEXNET, x, m_gpu) >= 0)
    assert x_star == brute


def test_max_x_mini_nothing_fits():
    # a budget below the model's own footprint: not even X_mini=1 fits
    assert mm.max_x_mini(mm.ALEXNET, 1 * 2 ** 20) == 0


def test_max_x_mini_monotone_in_memory():
    sizes = [2 ** 30, 2 ** 32, 2 ** 34]
    stars = [mm.max_x_mini(mm.ALEXNET, s) for s in sizes]
    assert stars == sorted(stars)
    assert stars[-1] > stars[0] > 0
    # below the model's own footprint (~750 MB fp32 x3) nothing fits
    assert mm.max_x_mini(mm.ALEXNET, 2 ** 28) == 0


# ---------------------------------------------------------------------------
# Table 2: conv algorithm memory ordering
# ---------------------------------------------------------------------------


def test_conv_alg_memory_ordering_matches_table2():
    """FFT's working set dominates GEMM's on every Table-2 layer, conv1 is
    the extreme case, and our ratios track the paper's within 20%."""
    ratios = []
    for row, paper in mm.TABLE2_ROWS:
        gemm, fft = mm.conv_alg_memory(*row)
        assert fft > gemm > 0
        ours = fft / gemm
        ratios.append(ours)
        assert abs(ours - paper) / paper < 0.20, (row, ours, paper)
    assert ratios[0] == max(ratios)  # conv1 (11.6x) dominates


def test_choose_conv_algs_is_feasibility_driven():
    rich = choose_conv_algs(128, TPU_V5E.hbm_bytes)
    assert all(l["chosen"] == "fft" for l in rich["layers"])
    # a budget that cannot hold every FFT working set: the choice must obey
    # the feasibility rule per layer, and at least one layer falls back
    used = (mm.m_fm(mm.ALEXNET, 128) + mm.m_mp(mm.ALEXNET)
            + mm.m_c(mm.ALEXNET)) / 8.0
    poor = choose_conv_algs(128, used + 250 * 2 ** 20)
    b = poor["m_bound_bytes"]
    for l in poor["layers"]:
        if l["fft_bytes"] <= b:
            assert l["chosen"] == "fft"
        elif l["gemm_bytes"] <= b:
            assert l["chosen"] == "gemm"
        else:
            assert l["chosen"] == "none" and not l["feasible"]
    assert any(l["chosen"] != "fft" for l in poor["layers"])


# ---------------------------------------------------------------------------
# train_memory at the dp/tp extremes + the microbatch search
# ---------------------------------------------------------------------------


def _train_mem(cfg, shape, **kw):
    base = dict(fsdp=False, microbatch=1, attn_impl="chunked", remat="block",
                seq_parallel=True, opt_kind="adamw")
    base.update(kw)
    return mm.train_memory(cfg, shape, **base)


def test_train_memory_tp_extremes():
    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    lone = _train_mem(cfg, shape, dp=256, tp=1)
    wide = _train_mem(cfg, shape, dp=16, tp=16)
    # model-parallel sharding must shrink params/grads/logits per chip
    assert wide.params < lone.params
    assert wide.grads < lone.grads
    assert wide.logits < lone.logits


def test_train_memory_dp_extremes():
    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    # dp = global_batch: one sample per replica, the smallest activations
    narrow = _train_mem(cfg, shape, dp=shape.global_batch, tp=1, microbatch=1)
    fat = _train_mem(cfg, shape, dp=1, tp=1,
                     microbatch=shape.global_batch)
    assert narrow.activations < fat.activations
    # optimizer state is ZeRO-1 sharded over all chips either way
    assert narrow.opt_state < fat.opt_state


def test_max_microbatch_edge_of_feasibility():
    cfg, shape = get_config("granite-3-2b"), get_shape("train_4k")
    kw = dict(dp=16, tp=16, fsdp=False, attn_impl="chunked", remat="block",
              seq_parallel=True)
    hbm = TPU_V5E.hbm_bytes
    mb = mm.max_microbatch(cfg, shape, hbm_bytes=hbm, **kw)
    b_rep = shape.global_batch // 16
    assert 1 <= mb <= b_rep
    mem = mm.train_memory(cfg, shape, microbatch=mb, opt_kind="adamw", **kw)
    assert mem.total <= 0.9 * hbm
    if mb < b_rep:  # the next microbatch must break the budget
        over = mm.train_memory(cfg, shape, microbatch=mb + 1,
                               opt_kind="adamw", **kw)
        assert over.total > 0.9 * hbm
    # an impossible budget: nothing fits
    assert mm.max_microbatch(cfg, shape, hbm_bytes=1.0, **kw) == 0


# ---------------------------------------------------------------------------
# Calibration overlay + cache
# ---------------------------------------------------------------------------


def _cal(**kw):
    base = dict(backend="cpu", cluster="2x4", achieved_flops=5e10,
                matmul_flops=8e10, hbm_bw=2e10, link_bw=1e9)
    base.update(kw)
    return Calibration(**base)


def test_calibration_apply_scales_chip_and_tiers():
    cluster = ClusterSpec("2x4", TPU_V5E,
                          (Tier("node", 4, 50e9), Tier("cluster", 2, 2.5e9)))
    mesh = MeshSpec.from_cluster(cluster)
    cal = _cal()
    out = cal.apply(mesh)
    assert out.chip.calibrated and out.chip.name == "tpu-v5e+cal"
    assert out.chip.peak_flops == 5e10
    assert out.chip.hbm_bw == 2e10
    # bottleneck tier anchored at the measured link bw, hierarchy preserved
    assert out.cluster.min_bw == pytest.approx(1e9)
    ratio = out.cluster.tiers[0].bw / out.cluster.tiers[1].bw
    assert ratio == pytest.approx(50e9 / 2.5e9)
    # the serialized plan topology still round-trips (+cal chip tolerated)
    back = ClusterSpec.from_dict(out.cluster.to_dict())
    assert back.chip.name == "tpu-v5e"


def test_calibration_unmeasured_link_leaves_tiers():
    mesh = MeshSpec(chips=8, dp=8, tp=1)
    out = _cal(link_bw=0.0).apply(mesh)
    assert out.cluster.tiers[0].bw == TPU_V5E.link_bw
    assert out.chip.peak_flops == 5e10


def test_calibration_cache_roundtrip(tmp_path):
    path = tmp_path / "cache.json"
    cal = _cal()
    save_calibration(path, cal)
    assert cached_calibration(path, "cpu/2x4") == cal
    assert cached_calibration(path, "cpu/other") is None
    # a second key merges rather than clobbers
    save_calibration(path, _cal(cluster="flat8", achieved_flops=7e10))
    assert cached_calibration(path, "cpu/2x4") == cal
    d = json.loads(path.read_text())
    assert sorted(d["calibrations"]) == ["cpu/2x4", "cpu/flat8"]


def test_calibration_key_is_arch_qualified():
    """The cached wall clock only compares to predictions for the config
    it was measured on — a reduced member must not share a key with the
    full config, nor with another arch."""
    from repro.core.autotune import cfg_cache_key

    full = get_config("granite-3-2b")
    assert cfg_cache_key(full) != cfg_cache_key(full.reduced())
    assert cfg_cache_key(full) != cfg_cache_key(get_config("minicpm3-4b"))
    assert _cal(arch=cfg_cache_key(full)).key.startswith("cpu/2x4/")


def test_tuning_schema_id_matches_api():
    from repro.api import TUNING_SCHEMA_ID as API_ID
    assert TUNING_SCHEMA_ID == API_ID


# ---------------------------------------------------------------------------
# Acceptance: Session.tune() end to end on the CPU backend
# ---------------------------------------------------------------------------


def test_session_tune_acceptance(tmp_path):
    """The ISSUE's acceptance criteria: a validated tune Report whose
    chosen minibatch is the largest `m_bound`-feasible batch, and whose
    calibrated step-time prediction beats the datasheet one."""
    from repro.api import JobSpec, Report, Session, validate_report

    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=2, batch=2,
                   seq=16, log_every=0, tune=True, tune_steps=2,
                   tune_cache=str(tmp_path / "cal.json"))
    sess = Session(spec)
    rep = sess.tune()
    assert isinstance(rep, Report) and rep.kind == "tune"
    d = json.loads(rep.to_json())
    validate_report(d)

    t = d["measured"]["tuning"]
    assert t["schema"] == TUNING_SCHEMA_ID
    # chosen == the largest batch satisfying m_bound (feasibility edge)
    chosen, hbm = t["minibatch"]["chosen"], t["minibatch"]["m_gpu_bytes"]
    assert mm.m_bound(mm.ALEXNET, chosen, hbm) >= 0
    assert mm.m_bound(mm.ALEXNET, chosen + 1, hbm) < 0
    # the calibrated re-plan is the better predictor of the wall clock
    r = t["replan"]
    assert r["calibrated_closer"]
    assert (r["abs_err_calibrated_s"] <= r["abs_err_uncalibrated_s"])
    # every tunable op got a measured winner
    assert set(t["kernels"]) == {"flash_attention", "decode_attention",
                                 "paged_decode_attention", "ssd_scan"}
    assert all(e["chosen"] in e["times_s"] for e in t["kernels"].values())
    # the calibration persisted under backend/cluster/executed-config
    key = Calibration.from_dict(t["calibration"]).key
    assert key.count("/") == 2  # arch-qualified: another config must re-fit
    cached = cached_calibration(spec.tune_cache, key)
    assert cached is not None and cached.achieved_flops > 0
    # a train() on the same session adopts the tuned knobs and carries the
    # tuning section
    trep = sess.train()
    validate_report(json.loads(trep.to_json()))
    assert trep.measured["tuning"]["minibatch"]["chosen"] == chosen
    run, _ = sess.build_run_opt()
    assert run.attn_impl == ("dense" if t["kernels"]["flash_attention"]
                             ["chosen"] == "ref" else "auto")
