"""Hypothesis property tests on system invariants: ring-cache position
reconstruction, MoE capacity/drop behaviour, quantization bounds, and the
counting-mode extrapolation identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import _ring_positions, quantize_kv, dequantize_kv
from repro.models import moe as moe_lib
from repro.configs.base import get_config
from repro.models.common import materialize


@given(st.integers(1, 10_000), st.integers(4, 64))
@settings(max_examples=50, deadline=None)
def test_ring_positions_invariants(pos, window):
    """Every valid slot holds a position in (pos-window, pos]; the write slot
    holds exactly pos; invalid slots are negative."""
    p = jnp.array([pos], jnp.int32)
    wpos, k_pos = _ring_positions(p, window, window, 1)
    k = np.asarray(k_pos[0])
    w = int(wpos[0])
    assert w == pos % window
    assert k[w] == pos  # the just-written slot
    valid = k[k >= 0]
    assert np.all(valid <= pos)
    assert np.all(pos - valid < window)
    # all valid positions distinct (no aliasing inside the window)
    assert len(np.unique(valid)) == len(valid)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_kv_quantization_bounded_error(seed):
    """int8 KV round-trip error is bounded by scale/2 = max|x|/254."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 1, 4, 32), jnp.float32) * 3.0
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1) / 254.0 * 1.01)
    err = np.asarray(jnp.max(jnp.abs(back - x), axis=-1))
    assert np.all(err <= bound + 1e-6)


@given(st.sampled_from([1.0, 1.25, 2.0, 8.0]), st.integers(0, 1000))
@settings(max_examples=12, deadline=None)
def test_moe_capacity_drop_monotonic(cap, seed):
    """Higher capacity factor ⇒ output closer to the uncapped reference
    (dropped tokens produce zero MoE output, shrinking ||out||)."""
    cfg = get_config("jamba-1.5-large-398b").reduced().replace(
        num_experts=4, top_k=2, moe_d_ff=32, d_model=32)
    p = materialize(moe_lib.moe_specs(cfg, 1), jax.random.PRNGKey(seed))
    p = jax.tree_util.tree_map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    ref, _ = moe_lib.moe_mlp(p, x, cfg, capacity_factor=64.0)  # effectively uncapped
    out, _ = moe_lib.moe_mlp(p, x, cfg, capacity_factor=cap)
    gap = float(jnp.linalg.norm(out - ref))
    if cap >= 8.0:
        assert gap < 1e-4  # capacity covers everything
    # with lower capacity the output never exceeds the reference norm by drop
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(ref)) + 1e-3


def test_moe_capacity_sweep_drop_rate():
    """Ablation: token-drop fraction vs capacity factor (recorded, monotone)."""
    cfg = get_config("deepseek-v2-236b").reduced().replace(
        num_experts=4, top_k=2, moe_d_ff=32, d_model=32, num_shared_experts=0)
    p = materialize(moe_lib.moe_specs(cfg, 1), jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    ref, _ = moe_lib.moe_mlp(p, x, cfg, capacity_factor=64.0)
    gaps = []
    for cap in (0.5, 1.0, 1.5, 2.0):
        out, _ = moe_lib.moe_mlp(p, x, cfg, capacity_factor=cap)
        changed = jnp.any(jnp.abs(out - ref) > 1e-6, axis=-1)
        gaps.append(float(jnp.mean(changed)))
    # drop rate decreases with capacity
    assert all(gaps[i] >= gaps[i + 1] - 1e-9 for i in range(len(gaps) - 1)), gaps
    assert gaps[-1] < 0.2


@given(st.integers(1, 40), st.floats(1.0, 100.0), st.floats(0.0, 10.0))
@settings(max_examples=30, deadline=None)
def test_counting_extrapolation_identity(n, base, delta):
    """total = base + (n-1)·Δ is exact for any per-cycle-linear cost — the
    dry-run's derivation is an identity, not an approximation, whenever the
    per-cycle cost is constant (which unrolled counting lowers guarantee)."""
    f = lambda cycles: base + cycles * delta
    one, two = f(1), f(2)
    derived = one + (n - 1) * (two - one)
    assert abs(derived - f(n)) < 1e-6 * max(f(n), 1.0)
