"""The paper's configuration guidelines, automated: for every assigned
architecture × input shape, print the planner's recommendation (microbatch =
X_mini, attention algorithm = the GEMM/FFT analogue, remat, FSDP, optimizer,
Lemma-3.2 sync schedule, fit verdict).

    PYTHONPATH=src python examples/planner_demo.py [--mesh single|multi]
"""
import argparse

from repro.configs.base import ARCH_IDS, SHAPES, get_config, get_shape
from repro.core.hardware import MULTI_POD, SINGLE_POD
from repro.core.planner import plan

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default="single", choices=["single", "multi"])
args = ap.parse_args()
mesh = SINGLE_POD if args.mesh == "single" else MULTI_POD

hdr = (f"{'arch':24s} {'shape':12s} {'mb':>3s} {'attn':8s} {'remat':6s} "
       f"{'fsdp':5s} {'opt':9s} {'mem(GB)':>8s} {'fit':3s} {'t_est(s)':>9s}")
print(f"mesh: dp={mesh.dp} tp={mesh.tp} ({mesh.chips} chips)")
print(hdr)
print("-" * len(hdr))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    for shape_name in SHAPES:
        p = plan(cfg, get_shape(shape_name), mesh)
        print(f"{arch:24s} {shape_name:12s} {p.microbatch:3d} {p.attn_impl:8s} "
              f"{p.remat:6s} {str(p.fsdp):5s} {p.opt_kind:9s} "
              f"{p.est_memory_gb:8.2f} {'Y' if p.fits else 'N':3s} "
              f"{p.est_step_time:9.3f}")
        for note in p.notes:
            print(f"{'':24s} - {note}")
