"""Quickstart: the whole public API is one JobSpec.

Plan, train (with checkpoints), and serve a tiny decoder through the
``repro.api`` facade; every call returns the same Report schema.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import JobSpec, Session

spec = JobSpec(arch="granite-3-2b", reduced=True,  # same family, laptop-sized
               steps=60, batch=8, seq=64, lr=3e-3,
               ckpt_dir="results/quickstart_ckpt", ckpt_every=30,
               s_max=128, n_new=8, requests=2)
sess = Session(spec)

print(f"== plan: {sess.resolved_plan.sync_schedule} sync, "
      f"microbatch {sess.resolved_plan.microbatch} (full-size job)")

print(f"== training reduced {sess.cfg.name}: d={sess.cfg.d_model} "
      f"L={sess.cfg.num_layers} V={sess.cfg.vocab_size}")
rep = sess.train()
m = rep.measured
print(f"loss {m['losses'][0]:.3f} -> {m['losses'][-1]:.3f}; "
      f"{m['tokens_per_s']:,.0f} tok/s; pipeline R_O={m['r_o']:.3f}")
rep.save("results/quickstart_train_report.json")

print("== generating")
srep = sess.serve()
for r in srep.measured["per_request"]:
    print(f"req {r['rid']}: head={r['head']}")
print(f"{srep.measured['n_tokens']} tokens in "
      f"{srep.measured['wall_s']*1e3:.0f} ms "
      f"({srep.measured['tokens_per_s']:.1f} tok/s)")
srep.save("results/quickstart_serve_report.json")
print("reports: results/quickstart_{train,serve}_report.json "
      "(one schema: spec + plan + measured + predicted)")
