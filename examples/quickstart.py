"""Quickstart: train a tiny decoder on the synthetic corpus, checkpoint it,
and generate a few tokens — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import get_config
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.serve.engine import Engine
from repro.train.loop import train

cfg = get_config("granite-3-2b").reduced()  # same family, laptop-sized
run = RunConfig(attn_impl="dense", remat="none")
opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=100)

print(f"== training reduced {cfg.name}: d={cfg.d_model} L={cfg.num_layers} "
      f"V={cfg.vocab_size}")
result = train(cfg, run, opt, batch=8, seq=64, steps=60,
               ckpt_dir="results/quickstart_ckpt", ckpt_every=30)
print(f"loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f}; "
      f"{result.tokens_per_s:,.0f} tok/s; pipeline R_O={result.mean_r_o:.3f}")

print("== generating")
eng = Engine(cfg, run, s_max=128)
prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
res = eng.generate(prompt, n_new=8)
print("tokens:", res.tokens)
print(f"prefill {res.prefill_s*1e3:.0f} ms, decode {res.decode_s*1e3:.0f} ms, "
      f"{res.tokens_per_s:.1f} tok/s")
