"""Batched serving example: ragged requests through the BatchScheduler on a
reduced gemma2 (sliding-window + softcap) and a reduced musicgen
(multi-codebook audio decoder).

    PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.configs.base import get_config
from repro.models.blocks import RunConfig
from repro.serve.engine import BatchScheduler, Engine

rng = np.random.default_rng(0)
run = RunConfig(attn_impl="dense", remat="none")

print("== gemma2 (SWA ring cache) ==")
cfg = get_config("gemma2-27b").reduced().replace(sliding_window=32)
eng = Engine(cfg, run, s_max=128)
sched = BatchScheduler(eng, max_batch=4)
rids = [sched.submit(rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32), 8)
        for n in (9, 17, 33, 21, 12)]
out = sched.run()
for rid in rids:
    print(f"  req {rid}: {out[rid].tolist()}")

print("== musicgen (4 EnCodec codebooks) ==")
mcfg = get_config("musicgen-large").reduced()
meng = Engine(mcfg, run, s_max=64)
prompts = rng.integers(0, mcfg.vocab_size, (2, 12, mcfg.num_codebooks)).astype(np.int32)
res = meng.generate(prompts, n_new=6)
print(f"  generated {res.tokens.shape} codebook tokens "
      f"({res.tokens_per_s:.1f} tok/s)")
print(f"  frame 0: {res.tokens[0, 0].tolist()}")
