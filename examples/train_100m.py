"""End-to-end driver: train a ~100M-parameter decoder for a few hundred
steps through the ``repro.api`` facade, reporting the paper's quantities
(R_O, Lemma-3.1 efficiency projection, Lemma-3.2 sizing) straight from the
unified Report.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--arch granite-3-2b]
"""
import argparse

import numpy as np

from repro.api import JobSpec, Session
from repro.configs.base import get_config
from repro.core import ps
from repro.core.memory_model import n_params

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M-param member of the chosen family: 16L d=640 ff=2560 V=4096
cfg = get_config(args.arch).reduced().replace(
    d_model=640, num_heads=8, num_kv_heads=2, head_dim=80, d_ff=2560,
    vocab_size=4096,
)
cfg = cfg.replace(num_layers=16 - 16 % len(cfg.pattern))
print(f"== {cfg.name} ~{n_params(cfg)/1e6:.0f}M params, "
      f"{cfg.num_layers}L d={cfg.d_model} V={cfg.padded_vocab}")

spec = JobSpec(arch=args.arch, reduced=True, steps=args.steps,
               batch=args.batch, seq=args.seq, lr=3e-3, log_every=20,
               ckpt_dir="results/train_100m_ckpt", ckpt_every=100)
rep = Session(spec, config=cfg).train()

m = rep.measured
print(f"\nloss {np.mean(m['losses'][:10]):.3f} -> "
      f"{np.mean(m['losses'][-10:]):.3f}")
print(f"throughput {m['tokens_per_s']:,.0f} tok/s")

print(f"\n== paper quantities from the unified Report ==")
print(f"R_O (pipelined) = {m['r_o']:.4f}")
lemma31 = rep.predicted["lemma31"]
for g, v in lemma31["per_device"].items():
    print(f"  Lemma 3.1: G={int(g):3d} -> efficiency {v['efficiency']:.3f}, "
          f"speedup {v['speedup']:.2f}x")
t_c = m["step_times_mean"]["compute"]
s_p = 4.0 * n_params(cfg)
n_ps = ps.n_parameter_servers(s_p, n_w=8, b_ps=10e9 / 8, t_c=t_c)
print(f"  Lemma 3.2: S_p={s_p/1e6:.0f} MB, 8 workers, 10 Gbit -> N_ps={n_ps}")
print(f"report -> {rep.save('results/train_100m_report.json')}")
