"""End-to-end driver: train a ~100M-parameter decoder for a few hundred
steps on the synthetic corpus with the instrumented pipeline, reporting the
paper's quantities (R_O, Lemma-3.1 efficiency projection, Lemma-3.2 sizing).

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--arch granite-3-2b]
"""
import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import amdahl, ps
from repro.core.memory_model import n_params
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M-param member of the chosen family: 16L d=640 ff=2560 V=4096
cfg = get_config(args.arch).reduced().replace(
    d_model=640, num_heads=8, num_kv_heads=2, head_dim=80, d_ff=2560,
    vocab_size=4096,
)
cfg = cfg.replace(num_layers=16 - 16 % len(cfg.pattern))
print(f"== {cfg.name} ~{n_params(cfg)/1e6:.0f}M params, "
      f"{cfg.num_layers}L d={cfg.d_model} V={cfg.padded_vocab}")

run = RunConfig(attn_impl="auto", remat="block")
opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
res = train(cfg, run, opt, batch=args.batch, seq=args.seq, steps=args.steps,
            ckpt_dir="results/train_100m_ckpt", ckpt_every=100, log_every=20)

print(f"\nloss {np.mean(res.losses[:10]):.3f} -> {np.mean(res.losses[-10:]):.3f}")
print(f"throughput {res.tokens_per_s:,.0f} tok/s")

r_o = res.mean_r_o
print(f"\n== paper quantities from measured step times ==")
print(f"R_O (pipelined) = {r_o:.4f}")
for g in (2, 4, 8, 16):
    print(f"  Lemma 3.1: G={g:3d} -> efficiency {amdahl.efficiency(g, r_o):.3f}, "
          f"speedup {amdahl.speedup(g, r_o):.2f}x")
t_c = float(np.median([t.compute for t in res.step_times]))
s_p = 4.0 * n_params(cfg)
n_ps = ps.n_parameter_servers(s_p, n_w=8, b_ps=10e9 / 8, t_c=t_c)
print(f"  Lemma 3.2: S_p={s_p/1e6:.0f} MB, 8 workers, 10 Gbit -> N_ps={n_ps}")
