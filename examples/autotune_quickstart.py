"""Autotune quickstart: close the loop from measurement to plan.

One JobSpec with ``tune=True``: the Session times the kernel algorithm
variants, measures a few trainer steps, calibrates the hardware constants,
runs the paper's minibatch procedure (largest batch under Eq. 5's
``m_bound``), and re-plans on the measured numbers.  The following
``train()`` adopts the tuned knobs, and a calibrated sweep compares
topologies on measured constants.

    PYTHONPATH=src python examples/autotune_quickstart.py
"""
from repro.api import JobSpec, Session

spec = JobSpec(arch="granite-3-2b", reduced=True, steps=6, batch=4, seq=32,
               log_every=0, tune=True,
               tune_cache="results/calibration_cache.json")
sess = Session(spec)

rep = sess.tune()
t = rep.measured["tuning"]
print(f"== tuned: minibatch*={t['minibatch']['chosen']} (m_bound), "
      f"attention -> {t['kernels']['flash_attention']['chosen']}")
r = t["replan"]
print(f"   step: measured {r['measured_step_s']*1e3:.1f}ms, "
      f"calibrated model {r['est_step_time_calibrated_s']*1e3:.1f}ms, "
      f"datasheet model {r['est_step_time_uncalibrated_s']*1e3:.4g}ms")
rep.save("results/autotune_tune_report.json")

print("== training with the tuned knobs")
trep = sess.train()
m = trep.measured
print(f"   loss {m['losses'][0]:.3f} -> {m['losses'][-1]:.3f}; "
      f"{m['tokens_per_s']:,.0f} tok/s")

print("== calibrated sweep: topologies priced on measured constants")
camp = Session.sweep(spec.replace(tune=False),
                     {"topology": ["flat8", "2x4"]}, kind="plan",
                     calibration=sess.tuned.calibration)
for cell in camp.metrics():
    print(f"   {cell['topology']:6s} -> {cell['schedule']:26s} "
          f"{cell['tokens_per_s']:.3g} tok/s (predicted, calibrated)")
