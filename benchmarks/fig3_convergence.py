"""Paper Fig. 3: learning curves across mini-batch sizes — a range of
X_mini reaches the same loss in a similar number of EPOCHS (i.e. samples),
which is what licenses choosing X_mini on system grounds (§3.1.4)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import get_config
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train

TOKENS_BUDGET = 160 * 8 * 64  # fixed token budget = fixed "epochs"
TARGET = None  # filled from the first run


def run(csv_rows):
    cfg = get_config("granite-3-2b").reduced().replace(vocab_size=512)
    run_cfg = RunConfig(attn_impl="dense", remat="none")
    seq = 64
    print("\n== Fig. 3: convergence vs mini-batch size (fixed token budget) ==")
    print(f"{'batch':>6s} {'steps':>6s} {'final_loss':>11s}")
    finals = {}
    for batch in (4, 8, 16):
        steps = TOKENS_BUDGET // (batch * seq)
        # LR scaled linearly with batch (standard practice the paper predates)
        opt = OptConfig(lr=1e-3 * batch / 8, warmup_steps=steps // 10,
                        total_steps=steps)
        res = train(cfg, run_cfg, opt, batch=batch, seq=seq, steps=steps,
                    log_every=0, seed=0)
        final = float(np.mean(res.losses[-5:]))
        finals[batch] = final
        print(f"{batch:6d} {steps:6d} {final:11.4f}")
        csv_rows.append((f"fig3/batch{batch}_final_loss", final,
                         f"steps={steps}"))
    spread = max(finals.values()) - min(finals.values())
    print(f"loss spread across batch sizes: {spread:.3f} "
          f"(similar convergence per sample, as in the paper)")
    csv_rows.append(("fig3/loss_spread", spread, ""))
