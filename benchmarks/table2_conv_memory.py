"""Paper Table 2: FFT vs GEMM convolution memory (AlexNet conv1-5), plus the
transformer analogue (dense vs flash attention memory) for the assigned
shapes — the same speed<->memory trade the ILP optimizes."""
from __future__ import annotations

from repro.configs.base import SHAPES, get_config
from repro.core import memory_model as mm


def run(csv_rows):
    print("\n== Table 2: conv algorithm memory, FFT/GEMM (AlexNet) ==")
    print(f"{'layer':6s} {'paper':>6s} {'ours':>6s} {'rel.err':>8s}")
    errs = []
    for i, (row, paper) in enumerate(mm.TABLE2_ROWS):
        gemm, fft = mm.conv_alg_memory(*row)
        ours = fft / gemm
        err = abs(ours - paper) / paper
        errs.append(err)
        print(f"conv{i+1:<2d} {paper:6.1f} {ours:6.2f} {err:8.1%}")
        csv_rows.append((f"table2/conv{i+1}_ratio", ours, f"paper={paper}"))
    print(f"mean abs rel err: {sum(errs)/len(errs):.1%}")
    csv_rows.append(("table2/mean_rel_err", sum(errs) / len(errs), ""))

    print("\n== transformer analogue: dense vs flash attention memory ==")
    print(f"{'arch':14s} {'shape':12s} {'dense_GB':>9s} {'flash_GB':>9s} {'ratio':>7s}")
    for arch in ("granite-3-2b", "gemma2-27b", "qwen2-72b"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k"):
            sh = SHAPES[shape_name]
            B = max(sh.global_batch // 16, 1)  # per data-parallel replica
            H = cfg.num_heads
            S = sh.seq_len
            dense = 2 * B * H * S * S * 4  # scores+probs f32
            flash = 2 * B * H * S * (1024 + 2) * 4  # one kv block + stats
            print(f"{arch:14s} {shape_name:12s} {dense/2**30:9.1f} "
                  f"{flash/2**30:9.3f} {dense/flash:7.1f}")
            csv_rows.append((f"attn_mem/{arch}/{shape_name}", dense / flash,
                             "dense/flash"))
