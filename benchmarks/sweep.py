"""Campaign sweep — the paper's configuration guidelines as one grid.

Fans a scenario grid (sync x dp x topology x batch ...) out through
``Session.sweep`` and writes the :class:`repro.api.Campaign` artifact
(``repro.api/campaign/v1``: one validated ``repro.api/report/v1`` per cell
plus the Pareto summary of throughput vs efficiency):

    PYTHONPATH=src python -m benchmarks.sweep \
        [--arch granite-3-2b] [--kind plan|dryrun|train] [--quick]
        [--out results/sweep_campaign.json]

``--quick`` is the CI smoke cell: 1 arch x 2 sync x 2 dp *training* runs
(2 steps, tiny batch, 2 simulated devices, CPU-pinned) — just enough to
prove the campaign surface end to end.  The default (no ``--quick``) is a
predictive plan-mode sweep over topologies and batch sizes, cheap enough
for a laptop.
"""
from __future__ import annotations

import argparse
import os
from pathlib import Path


def _grids(args):
    if args.quick:
        return {"sync": ["all_reduce", "reduce_scatter_all_gather"],
                "dp": [1, 2]}
    # predictive (plan/dryrun) cells only see plan-affecting fields — the
    # planner prices (arch, shape, topology, sync_overlap), not execution
    # knobs like batch/compress/dp; sweep those with --kind train instead
    archs = [args.arch] + [a for a in ("mamba2-780m",) if a != args.arch]
    return {"topology": ["flat8", "2x4", "4x4-ib", "pod"], "arch": archs,
            "sync_overlap": [False, True]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--kind", default="plan",
                    help="Session method per cell: plan|dryrun|train|bench")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 1 arch x 2 sync x 2 dp training cells "
                         "on 2 simulated devices")
    ap.add_argument("--out", default="results/sweep_campaign.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.kind, args.steps, args.batch, args.seq = "train", 2, 4, 32
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    # without the cpu pin, jax probes the TPU backend (libtpu is installed)
    # and stalls ~8 min in GCP-metadata retries on non-TPU hosts
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.api import JobSpec, Session

    base = JobSpec(arch=args.arch, reduced=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, log_every=0)
    camp = Session.sweep(base, _grids(args), kind=args.kind, progress=True)
    summary = camp.summary()
    print(f"\n{summary['n_ok']}/{summary['n_cells']} cells ok; "
          f"Pareto front ({len(summary['pareto'])} cells):")
    for cell in summary["pareto"]:
        knobs = {k: v for k, v in cell.items()
                 if k not in ("tokens_per_s", "efficiency", "source")}
        print(f"  {knobs}  ->  {cell['tokens_per_s']:,.0f} tok/s "
              f"@ eff {cell['efficiency']:.3f}")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(camp.to_json())
    print(f"wrote {out}")
    return camp


def run(csv_rows):
    """Harness entry: predictive topology sweep, no training."""
    print("\n== campaign sweep: topology x batch x compress (plan mode) ==")
    camp = main(["--kind", "plan", "--out", "results/sweep_campaign.json"])
    for cell, m in zip(camp.cells, camp.metrics()):
        key = "sweep/" + "/".join(f"{k}={cell[k]}" for k in sorted(cell))
        csv_rows.append((f"{key}/tokens_per_s", m["tokens_per_s"],
                         f"sched={m['schedule']} eff={m['efficiency']:.3f}"))


if __name__ == "__main__":
    main()
