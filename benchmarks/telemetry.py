"""Telemetry benchmark cell — the observability layer exercised end to end,
feeding the per-PR BENCH trajectory.

Runs one overlapped data-parallel train and one batched serve through the
``Session`` facade with tracing on, then:

1. validates both Reports (their ``metrics/v1`` sections included),
2. reconciles the trace against the measured numbers — the per-phase span
   sums must match ``SyncReport``'s wall clocks within 5% (they are the
   same clock, so this guards the plumbing, not the noise),
3. appends one record per area to ``BENCH_train.json`` / ``BENCH_serve.json``
   via ``tools/bench_trajectory.py`` and prints the comparison against the
   previous record (warn-only here; CI decides the posture).

    PYTHONPATH=src python -m benchmarks.telemetry [--quick] \
        [--no-bench-append]

``--quick`` is the CI/seed setting: 2 devices, few steps, tiny shapes.
Also callable from the harness (``python -m benchmarks.run --only
telemetry``), where it re-execs itself so the forced device count applies
before jax initializes.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _bench(args) -> dict:
    from repro.api import JobSpec, Session
    from repro.obs import validate_metrics

    out: dict = {}
    trace_dir = str(Path(args.outdir) / "traces")

    # -- overlapped train ---------------------------------------------------
    spec = JobSpec(arch=args.arch, reduced=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, dp=args.devices,
                   sync="auto", sync_overlap=True,
                   bucket_mb=args.bucket_mb, log_every=0,
                   trace_dir=trace_dir)
    sess = Session(spec)
    rep = sess.train()
    validate_metrics(rep.measured["metrics"])
    sync = rep.measured["sync"]

    # reconciliation: the bucket_sync spans of the last calibration step ARE
    # per_bucket_comm_s (same clock); 5% tolerates only float plumbing, not
    # a second timer
    tracer = sess.last_tracer
    per_bucket = sync["per_bucket_comm_s"]
    spans = [e.dur_s for e in tracer.events("bucket_sync")][-len(per_bucket):]
    for k, (a, b) in enumerate(zip(spans, per_bucket)):
        err = abs(a - b) / max(b, 1e-12)
        assert err < 0.05, (f"bucket {k}: span {a:.6f}s vs SyncReport "
                            f"{b:.6f}s ({err:.1%})")
    trace_file = rep.meta["trace_file"]
    trace = json.loads(Path(trace_file).read_text())
    names = {e.get("name") for e in trace["traceEvents"]}
    for needed in ("compute", "bucket_sync", "fused_step", "step"):
        assert needed in names, f"trace missing {needed!r} spans: {names}"
    train_path = Path(args.outdir) / "telemetry_train_report.json"
    rep.save(train_path)
    print(f"train: overlap {sync['overlap_fraction']:.0%} across "
          f"{sync['n_buckets']} buckets, trace {trace_file} "
          f"({rep.meta['trace_events']} events), report {train_path}")
    out["train"] = {"report": str(train_path),
                    "overlap_fraction": sync["overlap_fraction"],
                    "trace_events": rep.meta["trace_events"]}

    # -- serve (static mode: this cell reconciles GenResult.stats() against
    # the batch spans, which only the FIFO BatchScheduler emits; the
    # continuous runtime has its own cell, benchmarks/serve_continuous.py,
    # which also owns the BENCH_serve ledger) ------------------------------
    sspec = JobSpec(arch=args.arch, reduced=True, shape="decode_32k",
                    requests=args.requests, n_new=args.n_new,
                    s_max=args.s_max, max_batch=2, serve_mode="static",
                    trace_dir=trace_dir)
    ssess = Session(sspec)
    srep = ssess.serve()
    validate_metrics(srep.measured["metrics"])
    # reconciliation: GenResult.stats() values are the prefill/decode spans
    prefill_spans = sorted(e.dur_s
                           for e in ssess.last_tracer.events("prefill"))
    prefill_stats = sorted(b["prefill_s"] for b in srep.measured["batches"])
    assert prefill_spans == prefill_stats, "prefill spans != GenResult stats"
    serve_path = Path(args.outdir) / "telemetry_serve_report.json"
    srep.save(serve_path)
    print(f"serve: {srep.measured['n_tokens']} tokens at "
          f"{srep.measured['tokens_per_s']:.1f} tok/s, trace "
          f"{srep.meta['trace_file']}, report {serve_path}")
    out["serve"] = {"report": str(serve_path),
                    "tokens_per_s": srep.measured["tokens_per_s"]}

    # -- BENCH trajectory ---------------------------------------------------
    if args.bench_append:
        tool = str(REPO / "tools" / "bench_trajectory.py")
        for cmd in (["append", "--area", "train", "--report",
                     str(train_path)],
                    ["compare", "--area", "train", "--warn-only"]):
            r = subprocess.run([sys.executable, tool] + cmd,
                               cwd=str(REPO),
                               env=dict(os.environ,
                                        PYTHONPATH=str(REPO / "src")))
            if r.returncode != 0:
                raise SystemExit(f"bench_trajectory {cmd} failed")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--bucket-mb", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--n-new", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--outdir", default="results")
    ap.add_argument("--quick", action="store_true",
                    help="CI/seed setting: 2 devices, few steps, tiny shapes")
    ap.add_argument("--no-bench-append", dest="bench_append",
                    action="store_false", default=True,
                    help="skip appending to BENCH_<area>.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.devices, args.steps, args.batch, args.seq = 2, 6, 4, 32
        args.requests, args.n_new = 3, 3

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    # without the cpu pin, jax probes the TPU backend (libtpu is installed)
    # and stalls ~8 min in GCP-metadata retries on non-TPU hosts
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    return _bench(args)


def run(csv_rows):
    """Harness entry: re-exec so the forced device count beats jax init."""
    print("\n== telemetry: traced overlapped train + serve, BENCH ledger ==")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.telemetry"],
                       env=env, cwd=str(REPO))
    if r.returncode != 0:
        print("telemetry benchmark failed", file=sys.stderr)
        return
    rep = json.loads((REPO / "results" /
                      "telemetry_train_report.json").read_text())
    sync = rep["measured"]["sync"]
    csv_rows.append(("telemetry/overlap_fraction", sync["overlap_fraction"],
                     f"{sync['n_buckets']} buckets"))
    csv_rows.append(("telemetry/tokens_per_s",
                     rep["measured"]["tokens_per_s"], "train"))
    srep = json.loads((REPO / "results" /
                       "telemetry_serve_report.json").read_text())
    hists = srep["measured"]["metrics"]["histograms"]
    csv_rows.append(("telemetry/serve_decode_p99_s",
                     hists["serve/decode_s"]["p99"],
                     f"{srep['measured']['tokens_per_s']:.1f} tok/s"))


if __name__ == "__main__":
    main()
