"""Paper Fig. 4: estimated (Lemma 3.1) vs actual multi-device speedup for
four networks. 'Actual' here is the pipeline simulator driven by REAL
single-device step times measured on the reduced architectures — the same
role the paper's measured multi-GPU runs play, minus the GPUs."""
from __future__ import annotations

import numpy as np

from repro.core import amdahl
from repro.core.pipeline import StepTimes, multi_device_speedup
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train

ARCHS = ("granite-3-2b", "gemma2-27b", "mamba2-780m", "musicgen-large")


def run(csv_rows):
    import json
    from pathlib import Path

    from repro.api import JobSpec, Report, Session

    print("\n== Fig. 4: estimated (Lemma 3.1) vs simulated actual speedup ==")
    reports = []
    for arch in ARCHS:
        spec = JobSpec(arch=arch, reduced=True, steps=6, batch=8, seq=64,
                       lr=1e-3, log_every=0)
        sess = Session(spec)
        cfg = sess.cfg
        # the one extent of this run is the spec; only the RunConfig differs
        # from Session defaults (dense/none keeps T_C comparable across archs)
        run_cfg = RunConfig(attn_impl="dense", remat="none")
        res = train(cfg, run_cfg, OptConfig(lr=spec.lr), batch=spec.batch,
                    seq=spec.seq, steps=spec.steps, log_every=0)
        med = lambda f: float(np.median([getattr(t, f) for t in res.step_times[2:]]))
        t = StepTimes(data_load=med("data_load"), data_prep=med("data_prep"),
                      h2d=med("h2d"), compute=med("compute"),
                      param_update=0.05 * med("compute"))
        r_o = t.r_o()
        print(f"{arch}: T_C={t.compute*1e3:.0f}ms R_O={r_o:.3f}")
        print(f"  {'G':>3s} {'estimated':>10s} {'actual(sim)':>12s}")
        speedups = {}
        for g in (1, 2, 4, 8):
            est = amdahl.speedup(g, r_o)
            act = multi_device_speedup(t, g)
            print(f"  {g:3d} {est:10.2f} {act:12.2f}")
            csv_rows.append((f"fig4/{arch}/G{g}", act, f"est={est:.2f}"))
            speedups[str(g)] = {"estimated": est, "actual_sim": act}
        measured = res.summary()
        measured["speedup"] = speedups
        from repro.obs import MetricsRegistry

        reg = MetricsRegistry()
        reg.set_gauge("bench/r_o", r_o)
        for st in res.step_times:
            reg.inc("bench/steps")
            reg.observe("bench/compute_s", st.compute)
        measured["metrics"] = reg.section()
        meta = sess.report_meta()
        meta.update(benchmark="fig4_speedup",
                    run_config={"attn_impl": run_cfg.attn_impl,
                                "remat": run_cfg.remat})
        rep = Report(kind="bench", spec=spec.to_dict(),
                     plan=sess.resolved_plan.to_dict(), measured=measured,
                     predicted=sess.plan().predicted, meta=meta)
        reports.append(rep.validate().to_dict())
    out = Path("results/fig4_report.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"reports": reports}, indent=2, default=str))
    print(f"wrote {out}")
