"""Paper Fig. 4: estimated (Lemma 3.1) vs actual multi-device speedup for
four networks. 'Actual' here is the pipeline simulator driven by REAL
single-device step times measured on the reduced architectures — the same
role the paper's measured multi-GPU runs play, minus the GPUs.

``--pipe P`` adds a 1F1B column: the G devices arranged as a (P stages x
G/P shards) grid, priced as Lemma 3.1 over the shards times the pipeline's
``m/(m+P-1)`` steady-state share.  ``--quick`` runs one REAL measured cell
(tiny config, 2 stages on forced host devices) and asserts the traced 1F1B
bubble beats the serial no-overlap schedule — the executable counterpart
of the analytic column.
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

from repro.core import amdahl
from repro.core.pipeline import (StepTimes, multi_device_speedup,
                                 pipeline_bubble)
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train

ARCHS = ("granite-3-2b", "gemma2-27b", "mamba2-780m", "musicgen-large")


def pipelined_speedup(g: int, r_o: float, pipe: int, m: int) -> float:
    """Analytic Fig.-4 column for a (pipe x g/pipe) grid: Lemma 3.1 over
    the data shards, times the stage split, derated by the 1F1B bubble."""
    if pipe <= 1:
        return amdahl.speedup(g, r_o)
    return amdahl.speedup(g // pipe, r_o) * pipe * (1.0 - pipeline_bubble(pipe, m))


def run(csv_rows, pipe: int = 0, n_microbatch: int = 0):
    from repro.api import JobSpec, Report, Session

    print("\n== Fig. 4: estimated (Lemma 3.1) vs simulated actual speedup ==")
    reports = []
    for arch in ARCHS:
        spec = JobSpec(arch=arch, reduced=True, steps=6, batch=8, seq=64,
                       lr=1e-3, log_every=0)
        sess = Session(spec)
        cfg = sess.cfg
        # the one extent of this run is the spec; only the RunConfig differs
        # from Session defaults (dense/none keeps T_C comparable across archs)
        run_cfg = RunConfig(attn_impl="dense", remat="none")
        res = train(cfg, run_cfg, OptConfig(lr=spec.lr), batch=spec.batch,
                    seq=spec.seq, steps=spec.steps, log_every=0)
        med = lambda f: float(np.median([getattr(t, f) for t in res.step_times[2:]]))
        t = StepTimes(data_load=med("data_load"), data_prep=med("data_prep"),
                      h2d=med("h2d"), compute=med("compute"),
                      param_update=0.05 * med("compute"))
        r_o = t.r_o()
        m = n_microbatch or 4 * max(pipe, 1)
        print(f"{arch}: T_C={t.compute*1e3:.0f}ms R_O={r_o:.3f}")
        head = f"  {'G':>3s} {'estimated':>10s} {'actual(sim)':>12s}"
        if pipe > 1:
            head += f" {'1F1B(p=%d)' % pipe:>12s}"
        print(head)
        speedups = {}
        for g in (1, 2, 4, 8):
            est = amdahl.speedup(g, r_o)
            act = multi_device_speedup(t, g)
            row = f"  {g:3d} {est:10.2f} {act:12.2f}"
            cell = {"estimated": est, "actual_sim": act}
            if pipe > 1:
                if g % pipe == 0:
                    pipelined = pipelined_speedup(g, r_o, pipe, m)
                    row += f" {pipelined:12.2f}"
                    cell["pipelined_1f1b"] = pipelined
                    csv_rows.append((f"fig4/{arch}/G{g}/pipe{pipe}",
                                     pipelined, f"m={m}"))
                else:
                    row += f" {'-':>12s}"
            print(row)
            csv_rows.append((f"fig4/{arch}/G{g}", act, f"est={est:.2f}"))
            speedups[str(g)] = cell
        measured = res.summary()
        measured["speedup"] = speedups
        from repro.obs import MetricsRegistry

        reg = MetricsRegistry()
        reg.set_gauge("bench/r_o", r_o)
        for st in res.step_times:
            reg.inc("bench/steps")
            reg.observe("bench/compute_s", st.compute)
        measured["metrics"] = reg.section()
        meta = sess.report_meta()
        meta.update(benchmark="fig4_speedup",
                    run_config={"attn_impl": run_cfg.attn_impl,
                                "remat": run_cfg.remat})
        rep = Report(kind="bench", spec=spec.to_dict(),
                     plan=sess.resolved_plan.to_dict(), measured=measured,
                     predicted=sess.plan().predicted, meta=meta)
        reports.append(rep.validate().to_dict())
    out = Path("results/fig4_report.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"reports": reports}, indent=2, default=str))
    print(f"wrote {out}")


def quick_pipeline_cell(pipe: int = 2, n_microbatch: int = 4, steps: int = 3):
    """One REAL 1F1B cell on forced host devices: train a tiny config,
    replay the traced spans, and assert the measured bubble beats the
    serial no-overlap schedule (the claim behind the analytic column)."""
    import jax

    from repro.configs.base import get_config
    from repro.distributed.pipeline import PipelineTrainer

    cfg = get_config("granite-3-2b").reduced().replace(
        vocab_size=256, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, dtype="float32")
    cfg = cfg.replace(num_layers=cfg.first_k_dense + 8 * len(cfg.pattern))
    devs = jax.devices()
    if len(devs) % pipe:
        devs = devs[:len(devs) - len(devs) % pipe]
    tr = PipelineTrainer(cfg, RunConfig(attn_impl="dense", remat="none"),
                         OptConfig(lr=1e-3, warmup_steps=0), pipe=pipe,
                         n_microbatch=n_microbatch, devices=devs)
    tr.train(batch=2 * len(devs) * n_microbatch // pipe, seq=32,
             steps=steps, log_every=0)
    rep = tr.pipeline_report()
    print(f"quick 1F1B cell: pipe={rep.pipe} m={rep.n_microbatch} "
          f"bubble measured {rep.bubble_measured:.3f} vs model "
          f"{rep.bubble_model:.3f} (serial {rep.bubble_serial:.3f})")
    assert rep.bubble_measured < rep.bubble_serial, (
        f"1F1B did not beat the serial schedule: "
        f"{rep.bubble_measured:.3f} >= {rep.bubble_serial:.3f}")
    out = Path("results/fig4_pipeline_quick.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rep.as_dict(), indent=2, default=str))
    print(f"wrote {out}")
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipe", type=int, default=0,
                    help="add the 1F1B column: G devices as (pipe x "
                         "G/pipe), derated by the (p-1)/(m+p-1) bubble")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="1F1B microbatches for the --pipe column "
                         "(0 = 4*pipe)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: skip the arch sweep, run one real "
                         "measured 1F1B cell and assert it beats the "
                         "serial schedule")
    args = ap.parse_args(argv)
    # pin the backend before jax initializes (libtpu probe stall) and force
    # a host device axis for the measured cell
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    if args.quick:
        quick_pipeline_cell(pipe=max(args.pipe, 2),
                            n_microbatch=args.microbatch or 4)
        return
    csv_rows = []
    run(csv_rows, pipe=args.pipe, n_microbatch=args.microbatch)


if __name__ == "__main__":
    main()
