"""Sync-strategy × compression benchmark — Lemma 3.2 measured vs predicted.

Runs the explicit data-parallel trainer (repro.distributed) on 8 simulated
host devices for every sync strategy and compressor, checks each variant's
parameter updates against the single-device baseline, and emits the unified
``repro.api.Report`` JSON (spec + plan + measured + predicted; the grid
lives under ``measured.runs``) with the measured comm time next to the
Lemma 3.2 prediction:

    PYTHONPATH=src python -m benchmarks.sync_strategies \
        [--steps 6] [--batch 16] [--seq 64] [--devices 8] [--quick] \
        [--overlap [--bucket-mb 4]] [--out results/sync_strategies.json]

``--quick`` is the CI smoke setting: 2 devices, 2 steps, tiny batch, no
compression grid — just enough to prove the public surface end to end.
``--overlap`` additionally runs every kept combination with bucketed
comm/compute overlap (repro.distributed.overlap), so the report carries
serial vs overlapped side by side with per-bucket timings.

Also callable from the harness (``python -m benchmarks.run --only sync``),
where it re-execs itself in a subprocess so the forced device count applies
before jax initializes.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

# strategy -> tolerance (see repro/distributed/trainer.py numerics note);
# compression variants are documented-looser (quantization error feeds back)
TOLERANCES = {"none": (5e-3, 3e-3), "bf16": (5e-2, 2e-2),
              "int8": (1e-1, 5e-2), "topk": (5e-1, 2e-1)}


def _bench(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.api import JobSpec, Report, Session
    from repro.configs.base import get_config
    from repro.core import ps as ps_lib
    from repro.distributed import DataParallelTrainer
    from repro.distributed.collectives import STRATEGIES, get_strategy
    from repro.distributed.compression import COMPRESSORS
    from repro.launch.steps import build_train_step
    from repro.models import model as M
    from repro.models.blocks import RunConfig
    from repro.models.common import materialize
    from repro.optim.adamw import OptConfig, init_state
    from repro.train.loop import train

    spec = JobSpec(arch=args.arch, reduced=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, dp=args.devices,
                   sync="auto", log_every=0,
                   sync_overlap=bool(args.overlap),
                   bucket_mb=max(args.bucket_mb, 0.0))
    sess = Session(spec)
    cfg = get_config(args.arch).reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=args.steps)
    run = RunConfig(attn_impl="dense", remat="none")
    dp = args.devices

    # single-device baseline for numerics + the T_C reference
    base = train(cfg, run, opt, batch=args.batch, seq=args.seq,
                 steps=args.steps, seed=0, log_every=0)
    base_params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    base_state = init_state(opt, base_params)
    step = jax.jit(build_train_step(cfg, run, opt))
    # one deterministic batch for the update-equivalence check
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)
    batch1 = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    p_ref, _, m_ref = step(base_params, base_state, batch1)
    p_ref = jax.tree_util.tree_map(np.asarray, p_ref)

    # unified-Report measured block: the single-device baseline is the
    # headline measurement; the strategy grid lives under "runs"
    measured = base.summary()
    measured["baseline_tokens_per_s"] = base.tokens_per_s
    measured["devices"] = dp
    measured["runs"] = []

    from repro.core.hardware import get_cluster

    overlap_variants = [False] + ([True] if args.overlap else [])
    for strat_name in STRATEGIES:
        for comp_name in COMPRESSORS:
            if comp_name != "none" and (args.quick or strat_name != "all_reduce"
                                        and not args.full_grid):
                continue  # compression is strategy-independent; sample once
            # the hierarchical strategy gets a real 2-node topology when the
            # device count allows one (else it degenerates to RS+AG)
            topo = (get_cluster("2x4")
                    if strat_name == "hier_all_reduce" and dp == 8 else None)
            for overlapped in overlap_variants:
                # the fused path only engages after the calibration steps,
                # so overlapped runs need a few extra of them
                steps = max(args.steps, 6) if overlapped else args.steps
                tr = DataParallelTrainer(cfg, run, opt, strategy=strat_name,
                                         compression=comp_name,
                                         devices=jax.devices()[:dp],
                                         topology=topo,
                                         sync_overlap=overlapped,
                                         bucket_mb=args.bucket_mb or 4.0)
                res = tr.train(batch=args.batch, seq=args.seq, steps=steps,
                               seed=0, log_every=0)
                rep = tr.report()

                # update-equivalence vs baseline on the deterministic batch
                # (an overlapped trainer's first step runs the serial-
                # bucketed calibration path — numerically the same step)
                p0, st0 = tr.init(0)
                b_sh = {k: jax.device_put(v, NamedSharding(tr.mesh, P("data")))
                        for k, v in batch1.items()}
                p1, _, m1 = tr.step_fn()(p0, st0, b_sh)
                rtol, atol = TOLERANCES[comp_name]
                max_diff = max(
                    float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                    jax.tree_util.tree_leaves(p1)))
                ok = all(
                    np.allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                                atol=atol)
                    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                    jax.tree_util.tree_leaves(p1)))

                entry = rep.as_dict()
                entry.update(
                    matches_baseline=bool(ok), max_param_diff=max_diff,
                    tolerance={"rtol": rtol, "atol": atol},
                    loss_first=float(res.losses[0]),
                    loss_last=float(res.losses[-1]),
                    tokens_per_s=res.tokens_per_s, r_o=res.mean_r_o)
                measured["runs"].append(entry)
                tag = "overlap" if overlapped else "serial "
                extra = (f" exposed {rep.exposed_comm_time*1e3:7.1f}ms "
                         f"hid {rep.overlap_fraction:4.0%} "
                         f"[{rep.n_buckets} buckets]" if overlapped else "")
                print(f"{strat_name:26s} {comp_name:5s} {tag} "
                      f"comm {rep.measured_comm_s*1e3:7.1f}ms "
                      f"(lemma {rep.predicted_comm_s*1e3:7.1f}ms) "
                      f"T_C {rep.measured_compute_s*1e3:7.1f}ms "
                      f"masked={rep.masked_measured} match={ok} "
                      f"maxdiff={max_diff:.2e}{extra}", flush=True)

    # the lemma's sizing view for this payload on the emulated link
    s_p = 4.0 * sum(int(np.prod(a.shape))
                    for a in jax.tree_util.tree_leaves(base_params))
    t_c = (measured["runs"][0]["measured_compute_s"]
           if measured["runs"] else 1.0)
    from repro.distributed.trainer import DEFAULT_LINK_BW
    predicted = sess.plan().predicted
    predicted["lemma32_emulated"] = {
        "s_p_bytes": s_p, "t_c_s": t_c, "link_bw": DEFAULT_LINK_BW,
        "n_parameter_servers": ps_lib.n_parameter_servers(
            s_p, dp, DEFAULT_LINK_BW, max(t_c, 1e-6)),
        "predicted_comm_s": {
            name: get_strategy(name).predicted_comm_time(s_p, dp,
                                                         DEFAULT_LINK_BW)
            for name in STRATEGIES},
    }
    # the bench artifact's metrics/v1 section: grid-level distributions of
    # the measured comm/compute phases (validate_report requires it)
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    reg.set_gauge("bench/baseline_tokens_per_s", base.tokens_per_s)
    reg.set_gauge("bench/devices", dp)
    for r_ in measured["runs"]:
        reg.inc("bench/runs")
        reg.observe("bench/measured_comm_s", r_["measured_comm_s"])
        reg.observe("bench/measured_compute_s", r_["measured_compute_s"])
        reg.observe("bench/tokens_per_s", r_["tokens_per_s"])
    measured["metrics"] = reg.section()

    meta = sess.report_meta()
    meta.update(benchmark="sync_strategies", quick=bool(args.quick),
                overlap=bool(args.overlap),
                run_config={"attn_impl": run.attn_impl, "remat": run.remat})
    return Report(kind="bench", spec=spec.to_dict(),
                  plan=sess.resolved_plan.to_dict(),
                  measured=measured, predicted=predicted,
                  meta=meta).validate().to_dict()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--full-grid", action="store_true",
                    help="run every strategy x compression combination")
    ap.add_argument("--overlap", action="store_true",
                    help="also run every kept combination with bucketed "
                         "comm/compute overlap, so the report shows serial "
                         "vs overlapped side by side (incl. per-bucket "
                         "timings)")
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="sync-bucket size target in MiB for --overlap "
                         "(0 = default)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 devices, 2 steps, tiny batch, "
                         "no compression grid")
    ap.add_argument("--out", default="results/sync_strategies.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.devices, args.steps, args.batch, args.seq = 2, 2, 4, 32
        if args.overlap and not args.bucket_mb:
            # reduced-config gradients are a few MiB: smaller buckets keep
            # the bucketed path visible in the CI artifact
            args.bucket_mb = 0.5

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    # without the cpu pin, jax probes the TPU backend (libtpu is installed)
    # and stalls ~8 min in GCP-metadata retries on non-TPU hosts
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    report = _bench(args)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, default=str))
    print(f"wrote {out}")
    return report


def run(csv_rows):
    """Harness entry: re-exec so the forced device count beats jax init."""
    print("\n== sync strategies: measured vs Lemma 3.2 (8 sim devices) ==")
    out = Path("results/sync_strategies.json")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(Path(__file__).resolve().parent.parent / "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.sync_strategies",
                        "--out", str(out)],
                       env=env, cwd=str(Path(__file__).resolve().parent.parent))
    if r.returncode != 0:
        print("sync benchmark failed", file=sys.stderr)
        return
    rep = json.loads(out.read_text())
    for run_ in rep["measured"]["runs"]:
        key = f"sync/{run_['strategy']}/{run_['compression']}"
        csv_rows.append((f"{key}/measured_comm_s", run_["measured_comm_s"],
                         f"predicted={run_['predicted_comm_s']:.4f}"))
        csv_rows.append((f"{key}/matches_baseline",
                         float(run_["matches_baseline"]),
                         f"maxdiff={run_['max_param_diff']:.2e}"))


if __name__ == "__main__":
    main()
