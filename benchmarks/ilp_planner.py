"""§3.1.3: the X_mini/algorithm ILP solved per assigned arch — per-layer-type
algorithm choices under the HBM bound, and the planner's end-to-end pick."""
from __future__ import annotations

from repro.configs.base import ARCH_IDS, get_config, get_shape
from repro.core import ilp, memory_model as mm
from repro.core.hardware import SINGLE_POD
from repro.core.planner import plan


def _layer_choices(cfg, shape, mb: int):
    """Choices per layer-type: attention {dense, chunked} x remat {no, yes}.
    Times are napkin (relative); memory from the transformer model terms."""
    S = shape.seq_len
    B = mb
    H = max(cfg.num_heads, 1)
    tp = SINGLE_POD.tp
    heads_shard = tp if (H % tp == 0) else 1
    choices = []
    dense_mem = 2 * B * (H / heads_shard) * S * S * 4 / tp
    flash_mem = 2 * B * (H / heads_shard) * S * 1024 * 4 / tp
    act_save = B * S * cfg.d_model * 2 / tp
    # (name, time-units, memory): dense is ~10% faster (no rescaling pass),
    # remat=no saves the backward recompute (~25% of step) but keeps 4x acts
    for attn_t, attn_m, aname in ((1.0, dense_mem, "dense"),
                                  (1.1, flash_mem, "flash")):
        for remat_t, remat_m, rname in ((1.25, act_save, "remat"),
                                        (1.0, 4 * act_save, "save")):
            choices.append(ilp.Choice(f"{aname}+{rname}", attn_t * remat_t,
                                      attn_m + remat_m))
    return choices


def run(csv_rows):
    shape = get_shape("train_4k")
    hbm = SINGLE_POD.chip.hbm_bytes
    print("\n== Eq. 6 ILP: per-layer algorithm choice under M_bound ==")
    print(f"{'arch':24s} {'mb':>3s} {'choice':16s} {'mem(GB)':>8s} {'feasible':>8s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not cfg.has_attention:
            print(f"{arch:24s}   - (attention-free: algorithm axis degenerate,"
                  " ILP selects remat only)")
        # M_bound = HBM minus params/opt/grads (the paper's Eq. 5 analogue)
        static = mm.train_memory(cfg, shape, dp=SINGLE_POD.dp, tp=SINGLE_POD.tp,
                                 fsdp=True, microbatch=1, attn_impl="chunked",
                                 remat="block", seq_parallel=True)
        bound = hbm - (static.params + static.grads + static.opt_state)
        mb = 1
        layers = [_layer_choices(cfg, shape, mb)] * len(cfg.pattern)
        sol = ilp.solve_ilp(layers, bound / max(len(cfg.pattern), 1) *
                            len(cfg.pattern))
        names = {layers[k][sol.choices[k]].name for k in range(len(layers))}
        print(f"{arch:24s} {mb:3d} {'/'.join(sorted(names)):16s} "
              f"{sol.memory/2**30:8.2f} {str(sol.feasible):>8s}")
        csv_rows.append((f"ilp/{arch}/choice", float(sol.feasible),
                         "/".join(sorted(names))))

    print("\n== end-to-end planner picks (train_4k, single pod) ==")
    for arch in ARCH_IDS:
        p = plan(get_config(arch), shape)
        print(f"{arch:24s} mb={p.microbatch} attn={p.attn_impl} "
              f"remat={p.remat} fsdp={p.fsdp} opt={p.opt_kind} "
              f"fits={p.fits}")
        csv_rows.append((f"planner/{arch}/fits", float(p.fits),
                         f"mb={p.microbatch},{p.attn_impl},{p.remat}"))
