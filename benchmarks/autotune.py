"""Closed-loop autotune benchmark — the paper's minibatch/algorithm
procedure measured, calibrated, and checked against itself.

Runs ``Session.tune()`` on simulated host devices and verifies the two
acceptance properties of the loop:

1. the chosen minibatch equals the largest batch satisfying Eq. 5's
   ``m_bound`` (brute-force check against the binary search), and
2. the re-planned ``estimate_step_time`` on the calibrated constants lands
   closer to the measured step time than the datasheet prediction.

Emits the unified ``repro.api.Report`` (kind ``tune``, with the
``repro.api/tuning/v1`` section under ``measured.tuning``):

    PYTHONPATH=src python -m benchmarks.autotune \
        [--arch granite-3-2b] [--devices 2] [--steps 4] [--quick] \
        [--out results/autotune.json]

``--quick`` is the CI smoke cell: 2 devices, 3 steps, tiny batch.  Also
callable from the harness (``python -m benchmarks.run --only autotune``),
where it re-execs itself so the forced device count beats jax init.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path


def _bench(args) -> dict:
    from repro.api import JobSpec, Session, validate_report
    from repro.core import memory_model as mm

    spec = JobSpec(arch=args.arch, reduced=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, dp=args.devices,
                   log_every=0, tune=True, tune_steps=args.steps,
                   tune_cache=args.cache)
    sess = Session(spec)
    rep = sess.tune()
    d = json.loads(rep.to_json())
    validate_report(d)
    t = d["measured"]["tuning"]

    # acceptance 1: chosen minibatch == the largest X_mini with m_bound >= 0
    hbm = t["minibatch"]["m_gpu_bytes"]
    chosen = t["minibatch"]["chosen"]
    assert mm.m_bound(mm.ALEXNET, chosen, hbm) >= 0, "chosen infeasible"
    assert mm.m_bound(mm.ALEXNET, chosen + 1, hbm) < 0, \
        f"X_mini={chosen + 1} still feasible: {chosen} is not the largest"

    # acceptance 2: calibrated prediction beats the datasheet one
    r = t["replan"]
    assert r["calibrated_closer"], (
        f"calibrated err {r['abs_err_calibrated_s']:.4g}s not closer than "
        f"datasheet err {r['abs_err_uncalibrated_s']:.4g}s")

    print(f"minibatch* (m_bound)      : {chosen}  "
          f"[bound at chosen {t['minibatch']['m_bound_at_chosen']/2**20:.1f} "
          f"MiB, at next {t['minibatch']['m_bound_at_next']/2**20:.1f} MiB]")
    print(f"microbatch* (train_memory): {t['minibatch']['microbatch']['chosen']}")
    for op, entry in t["kernels"].items():
        times = ", ".join(f"{n}={v*1e3:.1f}ms"
                          for n, v in sorted(entry["times_s"].items(),
                                             key=lambda kv: kv[1]))
        print(f"{op:18s} -> {entry['chosen']:14s} ({times})")
    cal = t["calibration"]
    print(f"calibration [{cal['backend']}/{cal['cluster']}]: "
          f"achieved {cal['achieved_flops']:.3g} FLOP/s "
          f"(matmul ceiling {cal['matmul_flops']:.3g}), "
          f"triad {cal['hbm_bw']:.3g} B/s, link {cal['link_bw']:.3g} B/s")
    print(f"step time: measured {r['measured_step_s']*1e3:.1f}ms | "
          f"calibrated {r['est_step_time_calibrated_s']*1e3:.1f}ms | "
          f"datasheet {r['est_step_time_uncalibrated_s']*1e3:.4g}ms "
          f"-> calibrated closer: {r['calibrated_closer']}")
    prod = r["production"]
    print(f"production re-plan: est {prod['uncalibrated']['est_step_time']:.3g}s "
          f"(datasheet) -> {prod['calibrated']['est_step_time']:.3g}s "
          f"(measured constants), sync {prod['calibrated']['sync_schedule']}")
    return d


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=2,
                    help=">= 2 calibrates the data-axis link bandwidth from "
                         "a measured SyncReport; 0 = single-process loop")
    ap.add_argument("--cache", default="results/calibration_cache.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 devices, 3 steps, tiny batch")
    ap.add_argument("--out", default="results/autotune.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.devices, args.steps, args.batch, args.seq = 2, 3, 4, 32

    if args.devices:
        # append rather than setdefault: a pre-existing XLA_FLAGS (e.g. a
        # fast-math toggle) must not silently drop the forced device count
        cur = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in cur:
            os.environ["XLA_FLAGS"] = (
                f"{cur} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    # without the cpu pin, jax probes the TPU backend (libtpu is installed)
    # and stalls ~8 min in GCP-metadata retries on non-TPU hosts
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    report = _bench(args)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, default=str))
    print(f"wrote {out}")
    return report


def run(csv_rows):
    """Harness entry: re-exec so the forced device count beats jax init."""
    print("\n== autotune: measured calibration + the paper's procedure ==")
    out = Path("results/autotune.json")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=str(Path(__file__).resolve().parent.parent / "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.autotune",
                        "--quick", "--out", str(out)],
                       env=env, cwd=str(Path(__file__).resolve().parent.parent))
    if r.returncode != 0:
        print("autotune benchmark failed", file=sys.stderr)
        return
    rep = json.loads(out.read_text())
    t = rep["measured"]["tuning"]
    csv_rows.append(("autotune/minibatch_chosen",
                     t["minibatch"]["chosen"], "largest m_bound-feasible"))
    r_ = t["replan"]
    csv_rows.append(("autotune/abs_err_calibrated_s",
                     r_["abs_err_calibrated_s"],
                     f"datasheet={r_['abs_err_uncalibrated_s']:.4g}"))
    csv_rows.append(("autotune/flops_efficiency", r_["flops_efficiency"], ""))


if __name__ == "__main__":
    main()
