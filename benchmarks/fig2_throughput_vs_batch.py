"""Paper Fig. 2: throughput vs mini-batch size, with the knee where the
memory bound forces a slower algorithm.

Measured on CPU with a small model; the 'memory bound' is imposed
analytically (as on a 12 GB K80): once the dense-attention working set
exceeds the bound, the runtime must fall back to the chunked (flash)
algorithm — the paper's FFT->GEMM fallback, inverted to the attention
world. The planner's ILP predicts the same knee."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.optim import adamw as opt_lib
from repro.launch.steps import build_train_step

SEQ = 256
BOUND_BYTES = 48 * 2**20  # synthetic "GPU memory" bound for the demo model


def _throughput(cfg, run, batch: int, iters: int = 3) -> float:
    params = materialize(M.model_specs(cfg), jax.random.PRNGKey(0))
    opt = opt_lib.OptConfig(lr=1e-3)
    state = opt_lib.init_state(opt, params)
    step = jax.jit(build_train_step(cfg, run, opt), donate_argnums=(0, 1))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (batch, SEQ)).astype(np.int32)
    b = {"tokens": jax.numpy.asarray(toks), "labels": jax.numpy.asarray(toks)}
    params, state, m = step(params, state, b)  # compile+warm
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        params, state, m = step(params, state, b)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / iters
    return batch * SEQ / dt


def run(csv_rows):
    from repro.api import JobSpec, Report, Session

    cfg = get_config("granite-3-2b").reduced().replace(vocab_size=1024)
    spec = JobSpec(arch="granite-3-2b", reduced=True, steps=3, batch=32,
                   seq=SEQ, log_every=0)
    sess = Session(spec, config=cfg)
    print("\n== Fig. 2: throughput vs mini-batch size ==")
    print(f"{'batch':>6s} {'algorithm':>10s} {'tok/s':>10s}")
    points = []
    for batch in (1, 2, 4, 8, 16, 32):
        # algorithm choice under the synthetic memory bound (ILP degenerate
        # case: one layer type, two algorithms)
        dense_bytes = 2 * batch * cfg.num_heads * SEQ * SEQ * 4 * cfg.num_layers
        impl = "dense" if dense_bytes <= BOUND_BYTES else "chunked"
        tput = _throughput(cfg, RunConfig(attn_impl=impl, remat="none"), batch)
        print(f"{batch:6d} {impl:>10s} {tput:10,.0f}")
        csv_rows.append((f"fig2/batch{batch}", tput, impl))
        points.append({"batch": batch, "algorithm": impl,
                       "tokens_per_s": tput})
    print("(knee where the bound forces dense->chunked, as in the paper's "
          "FFT->GEMM fallback)")
    meta = sess.report_meta()  # records the vocab-1024 override actually run
    meta.update(benchmark="fig2_throughput_vs_batch",
                run_config={"remat": "none",
                            "attn_impl": "per-point (see measured.points)"})
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    for p in points:
        reg.inc("bench/points")
        reg.observe("bench/tokens_per_s", p["tokens_per_s"])
    rep = Report(kind="bench", spec=spec.to_dict(),
                 plan=sess.resolved_plan.to_dict(),
                 measured={"tokens_per_s": max(p["tokens_per_s"]
                                               for p in points),
                           "points": points,
                           "bound_bytes": BOUND_BYTES,
                           "metrics": reg.section()},
                 predicted=sess.plan().predicted, meta=meta)
    print(f"wrote {rep.validate().save('results/fig2_report.json')}")
