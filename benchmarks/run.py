"""Benchmark harness — one module per paper table/figure plus the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig2,...] [--fast]

Each benchmark prints its own table and appends (name, value, derived) rows;
the run ends with the consolidated ``name,value,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import time

ALL = ("table2", "fig2", "fig3", "fig4", "lemma32", "sync", "sweep",
       "autotune", "ilp", "dryrun", "roofline", "telemetry",
       "serve_continuous")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(ALL))
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow measured benchmarks (fig2-4)")
    args = ap.parse_args()
    which = [w.strip() for w in args.only.split(",") if w.strip()]
    if args.fast:
        which = [w for w in which if w not in ("fig2", "fig3", "fig4", "sync",
                                               "autotune", "telemetry",
                                               "serve_continuous")]

    csv_rows = []
    t0 = time.time()
    for name in which:
        if name == "table2":
            from benchmarks import table2_conv_memory as m
        elif name == "fig2":
            from benchmarks import fig2_throughput_vs_batch as m
        elif name == "fig3":
            from benchmarks import fig3_convergence as m
        elif name == "fig4":
            from benchmarks import fig4_speedup as m
        elif name == "lemma32":
            from benchmarks import lemma32_ps_sizing as m
        elif name == "sync":
            from benchmarks import sync_strategies as m
        elif name == "sweep":
            from benchmarks import sweep as m
        elif name == "autotune":
            from benchmarks import autotune as m
        elif name == "ilp":
            from benchmarks import ilp_planner as m
        elif name == "dryrun":
            from benchmarks import dryrun_summary as m
        elif name == "roofline":
            from benchmarks import roofline as m
        elif name == "telemetry":
            from benchmarks import telemetry as m
        elif name == "serve_continuous":
            from benchmarks import serve_continuous as m
        else:
            print(f"unknown benchmark {name!r}", file=sys.stderr)
            continue
        m.run(csv_rows)

    print(f"\n== consolidated CSV ({time.time()-t0:.0f}s total) ==")
    print("name,value,derived")
    for name, value, derived in csv_rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
