"""§Dry-run: consolidated table over results/dryrun/*.json (both meshes) —
proof that every (arch × shape × mesh) lowers + compiles, with per-chip
memory and collective mix. Writes results/dryrun_summary.md plus
results/dryrun_report.json, one unified ``repro.api.Report`` per compiled
combination (spec + analytic plan/predictions + the XLA measurements)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import ARCH_IDS, SHAPES


def _unified_reports(records):
    """One kind="dryrun" Report per compiled combo: the analytic planner
    prediction next to what XLA actually measured at compile time."""
    from repro.api import Session, JobSpec

    reports = []
    for (arch, shape, mesh_kind), r in records:
        rep = Session(JobSpec(arch=arch, reduced=False, shape=shape,
                              mesh=mesh_kind)).dryrun()
        f = r.get("full", {})
        rep.measured = {
            "ok": bool(r.get("ok")),
            "variant": r.get("variant", ""),
            "compile_s": f.get("compile_s", 0.0),
            "memory": f.get("memory", {}),
            "derived": r.get("derived", {}),
        }
        rep.meta["benchmark"] = "dryrun_summary"
        reports.append(rep.validate().to_dict())
    return reports


def run(csv_rows=None, write_md=True):
    lines = [
        "# Multi-pod dry-run — every (arch × shape × mesh) lower+compile",
        "",
        "| arch | shape | mesh | ok | variant | compile s | args GiB/chip |"
        " temp GiB/chip | per-chip FLOPs | wire GiB/chip | top collective |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_all = 0
    records = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                p = Path("results/dryrun") / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    continue
                r = json.loads(p.read_text())
                records.append(((arch, shape, mesh), r))
                n_all += 1
                if not r.get("ok"):
                    lines.append(f"| {arch} | {shape} | {mesh} | **FAIL** | "
                                 f"{r.get('error','')[:60]} | | | | | | |")
                    continue
                n_ok += 1
                f = r.get("full", {})
                m = f.get("memory", {})
                d = r.get("derived", {})
                cols = f.get("collectives", {})
                top = max(cols, key=lambda k: cols[k]["wire_bytes"]) if cols else "-"
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r.get('variant','')} | "
                    f"{f.get('compile_s', 0):.0f} | "
                    f"{m.get('argument_bytes', 0)/2**30:.1f} | "
                    f"{m.get('temp_bytes', 0)/2**30:.1f} | "
                    f"{d.get('flops', 0):.2e} | "
                    f"{d.get('wire_bytes', 0)/2**30:.1f} | {top} |")
    lines.insert(2, f"**{n_ok}/{n_all} combinations compile.**")
    lines.insert(3, "")
    if write_md:
        Path("results/dryrun_summary.md").write_text("\n".join(lines) + "\n")
    print(f"dry-run summary: {n_ok}/{n_all} ok -> results/dryrun_summary.md")
    if records:
        out = Path("results/dryrun_report.json")
        out.write_text(json.dumps({"reports": _unified_reports(records)},
                                  indent=2, default=str))
        print(f"unified reports -> {out}")
    if csv_rows is not None:
        csv_rows.append(("dryrun/ok_fraction", n_ok / max(n_all, 1), f"{n_ok}/{n_all}"))


if __name__ == "__main__":
    run()
