"""Paper Lemma 3.2: parameter-server sizing across the assigned archs and
bandwidths, plus the TPU mapping (grad-sync schedule masked behind compute)
validated against the dry-run collective bytes when available."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import ARCH_IDS, get_config, get_shape
from repro.core import memory_model as mm, ps
from repro.core.hardware import SINGLE_POD
from repro.core.planner import estimate_step_time


def run(csv_rows):
    print("\n== Lemma 3.2: N_ps for the assigned archs (paper-era PS view) ==")
    print(f"{'arch':24s} {'S_p(GB)':>8s} {'1Gbit':>6s} {'10Gbit':>7s} {'100Gbit':>8s}")
    shape = get_shape("train_4k")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        s_p = 4.0 * mm.n_params(cfg)  # fp32 params, the PS payload
        t_c = estimate_step_time(cfg, shape, SINGLE_POD, "block", 1)["compute"]
        row = [
            ps.n_parameter_servers(s_p, n_w=16, b_ps=bw, t_c=max(t_c, 1e-3))
            for bw in (1e9 / 8, 10e9 / 8, 100e9 / 8)
        ]
        print(f"{arch:24s} {s_p/2**30:8.1f} {row[0]:6d} {row[1]:7d} {row[2]:8d}")
        csv_rows.append((f"lemma32/{arch}/nps_10gbit", row[1],
                         f"s_p={s_p/2**30:.1f}GB t_c={t_c:.3f}s"))

    print("\n== TPU mapping: grad-sync masked behind compute? ==")
    print(f"{'arch':24s} {'sched':26s} {'comm(s)':>8s} {'T_C(s)':>7s} {'masked':>7s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        t_c = estimate_step_time(cfg, shape, SINGLE_POD, "block", 1)["compute"]
        plan = ps.tpu_grad_sync_plan(2.0 * mm.n_params(cfg) / SINGLE_POD.tp,
                                     SINGLE_POD.dp, SINGLE_POD.chip.link_bw, t_c)
        print(f"{arch:24s} {plan.schedule:26s} {plan.comm_time:8.3f} "
              f"{t_c:7.3f} {str(plan.masked):>7s}")
        csv_rows.append((f"lemma32_tpu/{arch}/masked", float(plan.masked),
                         plan.schedule))

    # cross-check against dry-run wire bytes (if the sweep has run)
    d = Path("results/dryrun")
    if d.exists():
        print("\n== validation vs dry-run collective bytes (train_4k single) ==")
        for arch in ARCH_IDS:
            f = d / f"{arch}__train_4k__single.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            if not rec.get("ok") or "derived" not in rec:
                continue
            wire = rec["derived"]["wire_bytes"]
            t_wire = wire / SINGLE_POD.chip.link_bw
            print(f"{arch:24s} dry-run wire/chip "
                  f"{wire/2**30:6.2f} GiB -> {t_wire:6.3f}s on ICI")
            csv_rows.append((f"lemma32_dryrun/{arch}/wire_gib", wire / 2**30, ""))
