"""Paper Lemma 3.2: parameter-server sizing across the assigned archs —
now priced against the *tiered* cluster model: the PS-count curve splits
into an in-node regime (servers colocated with their workers, B_ps = the
fast intra-node tier) and a cross-node regime (the paper's dedicated PS
deployment, B_ps = the narrowest spanning tier).  Plus the TPU mapping
(grad-sync schedule masked behind compute) on both the flat pod and the
hierarchical 2-pod DCN topology, validated against the dry-run collective
bytes when available."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import ARCH_IDS, get_config, get_shape
from repro.core import memory_model as mm, ps
from repro.core.hardware import MULTI_POD, SINGLE_POD, get_cluster
from repro.core.planner import estimate_step_time


def run(csv_rows):
    shape = get_shape("train_4k")

    print("\n== Lemma 3.2: N_ps regimes on the tiered cluster "
          "(paper-era 2x8-GPU P2 deployment, N_w=16) ==")
    p2 = get_cluster("p2-2x8")
    print(f"{'arch':24s} {'S_p(GB)':>8s} {'in-node':>8s} {'cross':>6s} "
          f"{'rec':>11s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        s_p = 4.0 * mm.n_params(cfg)  # fp32 params, the PS payload
        t_c = estimate_step_time(cfg, shape, SINGLE_POD, "block", 1)["compute"]
        placement = ps.ps_placement_plan(s_p, 16, p2, max(t_c, 1e-3))
        n_in = placement["in_node"]["n_ps"]
        n_x = placement["cross_node"]["n_ps"]
        print(f"{arch:24s} {s_p/2**30:8.1f} {n_in:8d} {n_x:6d} "
              f"{placement['recommended']:>11s}")
        csv_rows.append((f"lemma32/{arch}/nps_in_node", n_in,
                         f"b_ps={placement['in_node']['b_ps']:.2e}"))
        csv_rows.append((f"lemma32/{arch}/nps_cross_node", n_x,
                         f"b_ps={placement['cross_node']['b_ps']:.2e}"))

    print("\n== PS-count curve vs B_ps (granite-3-2b, the two regimes) ==")
    cfg = get_config("granite-3-2b")
    s_p = 4.0 * mm.n_params(cfg)
    t_c = max(estimate_step_time(cfg, shape, SINGLE_POD, "block", 1)["compute"],
              1e-3)
    print(f"{'B_ps':>12s} {'N_ps':>6s}  regime")
    for bw, regime in ((1e9 / 8, "cross-node 1GbE"),
                       (10e9 / 8, "cross-node 10GbE"),
                       (100e9 / 8, "cross-node 100Gb IB"),
                       (10e9, "in-node PCIe3"),
                       (50e9, "in-node ICI/NVLink")):
        n = ps.n_parameter_servers(s_p, 16, bw, t_c)
        print(f"{bw:12.2e} {n:6d}  {regime}")
        csv_rows.append((f"lemma32_curve/{regime.replace(' ', '_')}/nps", n,
                         f"b_ps={bw:.2e}"))

    print("\n== TPU mapping: grad-sync schedule per topology ==")
    print(f"{'arch':24s} {'mesh':8s} {'sched':26s} {'comm(s)':>8s} "
          f"{'T_C(s)':>7s} {'masked':>7s} {'bottleneck':>10s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for mesh, label in ((SINGLE_POD, "pod"), (MULTI_POD, "2pod")):
            t_c = estimate_step_time(cfg, shape, mesh, "block", 1)["compute"]
            plan = ps.grad_sync_plan(2.0 * mm.n_params(cfg) / mesh.tp,
                                     mesh.cluster.dp_view(mesh.dp, mesh.tp),
                                     t_c=max(t_c, 1e-9))
            print(f"{arch:24s} {label:8s} {plan.schedule:26s} "
                  f"{plan.comm_time:8.3f} {t_c:7.3f} {str(plan.masked):>7s} "
                  f"{plan.bottleneck_tier:>10s}")
            csv_rows.append((f"lemma32_tpu/{arch}/{label}/masked",
                             float(plan.masked),
                             f"{plan.schedule}@{plan.bottleneck_tier}"))

    # cross-check against dry-run wire bytes (if the sweep has run)
    d = Path("results/dryrun")
    if d.exists():
        print("\n== validation vs dry-run collective bytes (train_4k single) ==")
        for arch in ARCH_IDS:
            f = d / f"{arch}__train_4k__single.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            if not rec.get("ok") or "derived" not in rec:
                continue
            wire = rec["derived"]["wire_bytes"]
            t_wire = wire / SINGLE_POD.cluster.min_bw
            print(f"{arch:24s} dry-run wire/chip "
                  f"{wire/2**30:6.2f} GiB -> {t_wire:6.3f}s on ICI")
            csv_rows.append((f"lemma32_dryrun/{arch}/wire_gib", wire / 2**30, ""))
