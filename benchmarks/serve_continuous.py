"""Continuous-vs-static serving benchmark cell — the PR's headline claim,
measured and asserted.

Runs the same seeded ragged workload through both serving runtimes
(``Session.serve`` with ``serve_mode="continuous"`` and ``"static"``) and
checks, hard:

1. token streams are bit-identical between the runtimes (the paged KV
   round-trip changes the schedule, never the numbers),
2. the continuous scheduler computes exactly ``sum(n_new)`` decode-token
   steps (zero waste) while the static one computes
   ``sum(len(batch) * max(n_new))`` — strictly more on a ragged workload,
3. continuous measured tokens/s strictly exceeds static on the same
   workload (the wall-clock consequence of 2).

The continuous Report lands in ``results/serve_continuous_report.json``
and one record per run is appended to ``BENCH_serve.json`` via
``tools/bench_trajectory.py`` (this cell owns the serve ledger; the
telemetry cell owns ``BENCH_train.json``).

    PYTHONPATH=src python -m benchmarks.serve_continuous [--quick] \
        [--no-bench-append]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _bench(args) -> dict:
    from repro.api import JobSpec, Session

    base = JobSpec(arch=args.arch, reduced=True, shape="decode_32k",
                   requests=args.requests, n_new=args.n_new,
                   s_max=args.s_max, max_batch=args.max_batch,
                   seed=args.seed, arrival=args.arrival)
    runs = {}
    for mode in ("static", "continuous"):
        rep = Session(base.replace(serve_mode=mode)).serve()
        sv = rep.measured["serving"]
        runs[mode] = (rep, sv)
        print(f"{mode:>10}: {rep.measured['n_tokens']} tokens "
              f"{rep.measured['tokens_per_s']:8.1f} tok/s  "
              f"decode-steps {sv['throughput']['decode_token_steps']:4d} "
              f"(wasted {sv['throughput']['wasted_decode_steps']}), "
              f"p99 {sv['latency_s']['p99'] * 1e3:.0f} ms")
    crep, csv_ = runs["continuous"]
    srep, ssv = runs["static"]

    # 1. same numbers, different schedule
    heads = [{r["rid"]: r["head"] for r in rep.measured["per_request"]}
             for rep, _ in runs.values()]
    assert heads[0] == heads[1], "token streams differ between runtimes"

    # 2. decode-work accounting: continuous == sum(n_new), static strictly
    # more (it decodes every row for the batch max)
    c_steps = csv_["throughput"]["decode_token_steps"]
    s_steps = ssv["throughput"]["decode_token_steps"]
    delivered = crep.measured["n_tokens"]
    assert c_steps == delivered, \
        f"continuous computed {c_steps} != delivered {delivered}"
    assert csv_["throughput"]["wasted_decode_steps"] == 0
    assert c_steps < s_steps, \
        f"continuous {c_steps} decode steps not < static {s_steps}"

    # 3. the wall-clock consequence
    c_tps = crep.measured["tokens_per_s"]
    s_tps = srep.measured["tokens_per_s"]
    assert c_tps > s_tps, \
        f"continuous {c_tps:.1f} tok/s not > static {s_tps:.1f}"

    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    report_path = outdir / "serve_continuous_report.json"
    crep.save(report_path)
    summary = {
        "continuous_tokens_per_s": c_tps,
        "static_tokens_per_s": s_tps,
        "speedup": c_tps / s_tps,
        "decode_steps_saved": s_steps - c_steps,
        "kv_peak_occupancy": csv_["kv_cache"]["peak_occupancy"],
        "latency_p99_s": csv_["latency_s"]["p99"],
        "replicas_predicted": csv_["replica_lemma"]["predicted"]["replicas"],
        "report": str(report_path),
    }
    (outdir / "serve_continuous_summary.json").write_text(
        json.dumps(summary, indent=2))
    print(f"continuous/static speedup {summary['speedup']:.2f}x, "
          f"{summary['decode_steps_saved']} decode steps saved, "
          f"report {report_path}")

    if args.bench_append:
        tool = str(REPO / "tools" / "bench_trajectory.py")
        for cmd in (["append", "--area", "serve", "--report",
                     str(report_path)],
                    ["compare", "--area", "serve", "--warn-only"]):
            r = subprocess.run([sys.executable, tool] + cmd, cwd=str(REPO),
                               env=dict(os.environ,
                                        PYTHONPATH=str(REPO / "src")))
            if r.returncode != 0:
                raise SystemExit(f"bench_trajectory {cmd} failed")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-new", type=int, default=24)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival", default="",
                    help="arrival trace spec for the continuous run")
    ap.add_argument("--outdir", default="results")
    ap.add_argument("--quick", action="store_true",
                    help="CI setting: fewer requests, shorter generations")
    ap.add_argument("--no-bench-append", dest="bench_append",
                    action="store_false", default=True,
                    help="skip appending to BENCH_serve.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.requests, args.n_new, args.s_max = 5, 16, 96

    # without the cpu pin, jax probes the TPU backend (libtpu is installed)
    # and stalls in GCP-metadata retries on non-TPU hosts
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    return _bench(args)


def run(csv_rows):
    """Harness entry (``python -m benchmarks.run --only serve_continuous``):
    re-exec so the env pins apply before jax initializes."""
    print("\n== serve_continuous: in-flight batching vs FIFO batches ==")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.serve_continuous",
                        "--quick"], env=env, cwd=str(REPO))
    if r.returncode != 0:
        print("serve_continuous benchmark failed", file=sys.stderr)
        return
    summary = json.loads((REPO / "results" /
                          "serve_continuous_summary.json").read_text())
    csv_rows.append(("serve_continuous/tokens_per_s",
                     summary["continuous_tokens_per_s"],
                     f"{summary['speedup']:.2f}x over static"))
    csv_rows.append(("serve_continuous/decode_steps_saved",
                     summary["decode_steps_saved"],
                     f"p99 {summary['latency_p99_s'] * 1e3:.0f} ms"))


if __name__ == "__main__":
    main()
