"""§Roofline: per (arch × shape) on the single-pod mesh, derive the three
roofline terms from the dry-run artifacts:

  compute    = HLO_FLOPs / peak_FLOP/s      (per-chip FLOPs from counting lowers)
  memory     = HLO_bytes / HBM_bw
  collective = wire_bytes / link_bw          (per-chip, HLO-parsed; see hlo.py)

plus MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (prefill/decode) and
the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips). Writes
results/roofline.md (the EXPERIMENTS.md §Roofline table is generated here).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.core import memory_model as mm
from repro.core.hardware import TPU_V5E

HBM_BUDGET = TPU_V5E.hbm_bytes


def model_flops(cfg, shape) -> float:
    n_act = mm.n_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: one token per example


def suggestion(dominant: str, cfg, shape) -> str:
    if dominant == "collective":
        if shape.kind == "train":
            return ("reduce FSDP all-gather volume (larger microbatch / "
                    "param prefetch overlap) or move grad sync to "
                    "reduce-scatter")
        return "shard params less aggressively (no FSDP at decode) / cache layout"
    if dominant == "memory":
        if shape.kind == "decode":
            return "quantize KV cache / ring-buffer SWA slots to cut cache reads"
        return "increase arithmetic intensity: bigger microbatch, fuse norms"
    return "compute-bound — raise MFU via MXU-aligned tiles; already healthy"


def load_record(arch: str, shape: str, mesh: str = "single"):
    p = Path("results/dryrun") / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else rec


def run(csv_rows, write_md: bool = True):
    print("\n== Roofline (single-pod 256 chips, per-chip terms in seconds) ==")
    hdr = (f"{'arch':24s} {'shape':12s} {'var':7s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dominant':>9s} {'useful':>7s} {'mem/chip':>9s} {'fit':>4s}")
    print(hdr)
    lines = ["# Roofline — single-pod (16×16, 256 chips), baseline dry-runs",
             "",
             "| arch | shape | variant | compute s | memory s | collective s |"
             " dominant | MODEL/HLO | bytes/chip GiB | fits 16G | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        cfg0 = get_config(arch)
        for shape_name, shape in SHAPES.items():
            rec = load_record(arch, shape_name)
            if rec is None:
                continue
            if not rec.get("ok"):
                lines.append(f"| {arch} | {shape_name} | - | FAILED: "
                             f"{rec.get('error','?')[:60]} | | | | | | | |")
                continue
            d = rec["derived"]
            t_comp = d["flops"] / TPU_V5E.peak_flops
            t_mem = d["bytes_accessed"] / TPU_V5E.hbm_bw
            t_coll = d["wire_bytes"] / TPU_V5E.link_bw
            terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
            dom = max(terms, key=terms.get)
            mf = model_flops(cfg0, shape)
            useful = mf / max(d["flops"] * rec["num_devices"], 1.0)
            memo = rec.get("full", {}).get("memory", {})
            per_chip = (memo.get("argument_bytes", 0) + memo.get("temp_bytes", 0)
                        + memo.get("output_bytes", 0))
            fits = per_chip <= HBM_BUDGET
            var = rec.get("variant", "native")[:7]
            print(f"{arch:24s} {shape_name:12s} {var:7s} {t_comp:9.3f} "
                  f"{t_mem:9.3f} {t_coll:9.3f} {dom:>9s} {useful:7.2f} "
                  f"{per_chip/2**30:9.2f} {'Y' if fits else 'N':>4s}")
            lines.append(
                f"| {arch} | {shape_name} | {rec.get('variant','native')} | "
                f"{t_comp:.3f} | {t_mem:.3f} | {t_coll:.3f} | **{dom}** | "
                f"{useful:.2f} | {per_chip/2**30:.2f} | "
                f"{'yes' if fits else 'NO'} | {suggestion(dom, cfg0, shape)} |")
            csv_rows.append((f"roofline/{arch}/{shape_name}/{dom}",
                             terms[dom], f"useful={useful:.2f}"))
    if write_md:
        Path("results").mkdir(exist_ok=True)
        Path("results/roofline.md").write_text("\n".join(lines) + "\n")
        print("wrote results/roofline.md")
