"""Campaign — a declarative scenario grid run through the Session facade.

The paper's configuration guidelines answer one question at a time: given
an architecture, a batch size, a sync schedule, a topology — how fast, how
efficient?  ``Session.sweep`` asks them all at once: a grid over JobSpec
fields (arch x dp x sync x compress x batch x topology x ...) fans out into
one :class:`repro.api.Report` per cell, and the :class:`Campaign` collects
them with a Pareto summary of throughput vs efficiency — the guidelines as
one queryable artifact.

    from repro.api import JobSpec, Session

    camp = Session.sweep(
        JobSpec(arch="granite-3-2b", steps=2, batch=4, seq=32),
        {"arch": ["granite-3-2b", "mamba2-780m"],
         "topology": ["flat8", "2x4"]},
        kind="plan")
    camp.summary()["pareto"]         # the non-dominated cells
    camp.save("results/campaign.json")

Grid values map onto ``JobSpec.replace`` kwargs; cells whose combination is
invalid (e.g. ``batch`` not divisible by ``dp``) are recorded under
``skipped`` instead of aborting the campaign.  Predictive (plan/dryrun)
campaigns only differentiate plan-affecting fields (arch/shape/mesh/
topology); sweep execution knobs (batch/compress/dp/sync) with
``kind="train"``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Sequence, Union

from repro.api.report import Report, validate_report
from repro.configs.base import get_shape

CAMPAIGN_SCHEMA_ID = "repro.api/campaign/v1"


def _cell_metrics(rep: Report) -> Dict[str, Any]:
    """Throughput (tokens/s) and Lemma-3.1 efficiency for one cell —
    measured when the cell ran, planner-predicted for plan/dryrun cells."""
    measured_tps = rep.measured.get("tokens_per_s")
    if measured_tps is not None:
        tps = float(measured_tps)
        source = "measured"
    else:
        est = float(rep.plan.get("est_step_time") or 0.0)
        shape = get_shape(rep.plan["shape"])
        tokens = shape.global_batch * shape.seq_len
        tps = tokens / est if 0.0 < est < float("inf") else 0.0
        source = "predicted"
    return {
        "tokens_per_s": tps,
        "efficiency": float(rep.plan.get("efficiency") or 0.0),
        "source": source,
        "schedule": rep.plan.get("sync_schedule", ""),
        "bottleneck_tier": rep.plan.get("bottleneck_tier", ""),
        "fits": bool(rep.plan.get("fits", True)),
    }


def pareto_front(points: Sequence[Dict[str, float]]) -> List[int]:
    """Indices of the cells not dominated on (tokens_per_s, efficiency):
    no other cell is >= on both axes and > on at least one."""
    idx = []
    for i, p in enumerate(points):
        dominated = any(
            q["tokens_per_s"] >= p["tokens_per_s"]
            and q["efficiency"] >= p["efficiency"]
            and (q["tokens_per_s"] > p["tokens_per_s"]
                 or q["efficiency"] > p["efficiency"])
            for j, q in enumerate(points) if j != i)
        if not dominated:
            idx.append(i)
    return idx


@dataclass
class Campaign:
    """All reports of one sweep plus the grid that produced them."""

    kind: str                      # Session method run per cell
    grid: Dict[str, List[Any]]     # field -> values swept
    cells: List[Dict[str, Any]]    # per-report {overrides} in report order
    reports: List[Report] = field(default_factory=list)
    skipped: List[Dict[str, Any]] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.reports)

    # ------------------------------------------------------------------
    def metrics(self) -> List[Dict[str, Any]]:
        return [dict(cell, **_cell_metrics(rep))
                for cell, rep in zip(self.cells, self.reports)]

    def pareto(self) -> List[int]:
        return pareto_front(self.metrics())

    def summary(self) -> Dict[str, Any]:
        m = self.metrics()
        front = pareto_front(m)
        best_tps = max(range(len(m)), key=lambda i: m[i]["tokens_per_s"],
                       default=None) if m else None
        best_eff = max(range(len(m)), key=lambda i: m[i]["efficiency"],
                       default=None) if m else None
        return {
            "kind": self.kind,
            "n_cells": len(self.reports) + len(self.skipped),
            "n_ok": len(self.reports),
            "n_skipped": len(self.skipped),
            "cells": m,
            "pareto": [m[i] for i in front],
            "pareto_indices": front,
            "best_throughput": m[best_tps] if best_tps is not None else None,
            "best_efficiency": m[best_eff] if best_eff is not None else None,
        }

    # ------------------------------------------------------------------
    def validate(self) -> "Campaign":
        for rep in self.reports:
            validate_report(rep.to_dict())
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": CAMPAIGN_SCHEMA_ID,
            "kind": self.kind,
            "grid": self.grid,
            "summary": self.summary(),
            "reports": [r.to_dict() for r in self.reports],
            "skipped": self.skipped,
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Campaign":
        if d.get("schema") != CAMPAIGN_SCHEMA_ID:
            raise ValueError(f"campaign schema {d.get('schema')!r} != "
                             f"{CAMPAIGN_SCHEMA_ID!r}")
        reports = [Report.from_dict(r) for r in d["reports"]]
        cells = [c for c in d.get("summary", {}).get("cells", [])]
        grid_keys = set(d.get("grid", {}))
        cells = [{k: v for k, v in c.items() if k in grid_keys} for c in cells]
        return cls(kind=d["kind"], grid=dict(d.get("grid", {})), cells=cells,
                   reports=reports, skipped=list(d.get("skipped", [])))

    @classmethod
    def from_json(cls, s: str) -> "Campaign":
        return cls.from_dict(json.loads(s))
