"""JobSpec — the one declarative object that names a job end to end.

The paper's procedure is: pick the minibatch size and per-layer algorithms,
size the mesh and the parameter servers, then run.  A :class:`JobSpec` is
that procedure written down once: architecture + input shape + mesh, the
data-parallel degree and gradient-sync/compression choice, and the run
extent (steps/batch/seq/seed).  ``Session`` resolves it through the planner
and executes it; every entry point (launchers, benchmarks, examples) builds
one of these instead of hand-plumbing ``get_config -> plan -> RunConfig``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Dict

from repro.configs.base import ARCH_IDS, SHAPES
from repro.core import ps as ps_lib
from repro.core.hardware import CLUSTERS

MESHES = ("single", "multi")
SYNCS = ("auto",) + ps_lib.SCHEDULES
# named cluster topologies ("" = the mesh's flat single-tier equivalent)
TOPOLOGIES = ("",) + tuple(sorted(CLUSTERS))
# names mirror repro.distributed.compression.COMPRESSORS (kept import-light
# here: the registry pulls in jax, and a spec must be constructible without
# touching a backend)
COMPRESSIONS = ("none", "bf16", "int8", "topk")


@dataclass(frozen=True)
class JobSpec:
    """Declarative description of one job (train / serve / bench / dryrun)."""

    arch: str
    reduced: bool = True          # reduced family member vs FULL config
    shape: str = "train_4k"       # planner ShapeConfig name
    mesh: str = "single"          # planner mesh: single | multi pod
    topology: str = ""            # named ClusterSpec (hardware.CLUSTERS);
                                  # "" = flat cluster equivalent to `mesh`
    steps: int = 100
    batch: int = 8
    seq: int = 128
    lr: float = 1e-3
    seed: int = 0
    use_planner: bool = False     # adopt planner knobs (microbatch/attn/remat/opt)
    dp: int = 0                   # >0: explicit data-parallel trainer on dp devices
    pipe: int = 0                 # >0: 1F1B pipeline trainer with this many
                                  # stages (devices split pipe x data);
                                  # 0 = planner-resolved / no pipelining
    n_microbatch: int = 0         # 1F1B microbatches per step; 0 = pipe
    sync: str = "auto"            # gradient-sync schedule, or planner-resolved
    compress: str = "none"        # gradient compression
    sync_overlap: bool = False    # bucketed comm/compute overlap (trainer +
                                  # overlap-aware cost model)
    bucket_mb: float = 0.0        # sync-bucket size target [MiB]; 0 = the
                                  # shared default (core.ps.DEFAULT_BUCKET_MB)
    staleness: int = 0            # bounded-staleness async PS: max worker
                                  # params age in steps (0 = synchronous)
    backup_workers: int = 0       # drop the slowest k of dp gradients per
                                  # step (0 = wait for every worker)
    ckpt_dir: str = ""
    ckpt_every: int = 0
    log_every: int = 10
    trace_dir: str = ""           # write a Chrome-trace JSON per run here
                                  # ("" = tracing stays in-memory only)
    # autotuning (repro.core.autotune via Session.tune):
    tune: bool = False            # run the autotuner; train/bench adopt its
                                  # measured kernel + microbatch choices
    tune_steps: int = 3           # measured trainer steps per calibration
    tune_cache: str = ""          # JSON calibration-cache path ("" = no
                                  # persistence across sessions)
    # serving knobs
    s_max: int = 256              # decode cache length
    max_batch: int = 4            # scheduler batch size
    n_new: int = 16               # tokens generated per request
    requests: int = 6             # synthetic request count
    serve_mode: str = "continuous"  # continuous (in-flight batching, paged
                                  # KV) | static (FIFO BatchScheduler)
    kv_block: int = 16            # paged-KV block size [tokens]
    max_kv_blocks: int = 0        # KV pool cap; 0 = derive from the Eq. 5
                                  # analogue (memory_model.max_kv_blocks)
    prefill_chunk: int = 0        # chunked prefill size; 0 = whole-prompt
    arrival: str = ""             # arrival trace spec ("" | poisson:RATE |
                                  # burst:NxGAP), see repro.serve.arrivals
    slo_ms: float = 0.0           # per-request latency SLO for the replica
                                  # lemma; 0 = 2x the measured mean latency
    arrival_rate: float = 0.0     # offered load [req/s] for the lemma;
                                  # 0 = 2x one replica's capacity

    def __post_init__(self):
        if self.arch not in ARCH_IDS:
            raise ValueError(f"unknown arch {self.arch!r}; known: {ARCH_IDS}")
        if self.shape not in SHAPES:
            raise ValueError(f"unknown shape {self.shape!r}; "
                             f"known: {sorted(SHAPES)}")
        if self.mesh not in MESHES:
            raise ValueError(f"mesh must be one of {MESHES}, got {self.mesh!r}")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"known: {TOPOLOGIES}")
        if self.sync not in SYNCS:
            raise ValueError(f"sync must be one of {SYNCS}, got {self.sync!r}")
        if self.compress not in COMPRESSIONS:
            raise ValueError(f"compress must be one of {COMPRESSIONS}, "
                             f"got {self.compress!r}")
        for name in ("steps", "batch", "seq", "s_max", "max_batch", "n_new",
                     "requests", "tune_steps", "kv_block"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        if self.serve_mode not in ("continuous", "static"):
            raise ValueError(f"serve_mode must be 'continuous' or 'static', "
                             f"got {self.serve_mode!r}")
        for name in ("max_kv_blocks", "prefill_chunk"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if self.slo_ms < 0 or self.arrival_rate < 0:
            raise ValueError("slo_ms and arrival_rate must be >= 0")
        if self.arrival:
            # numpy-only module: safe to import from a backend-free spec
            from repro.serve.arrivals import parse_trace
            parse_trace(self.arrival)  # raises ValueError on a bad spec
        if self.dp < 0:
            raise ValueError("dp must be >= 0 (0 = single-process loop)")
        if self.pipe < 0 or self.n_microbatch < 0:
            raise ValueError("pipe and n_microbatch must be >= 0")
        if self.pipe > 1 and self.n_microbatch and self.n_microbatch < self.pipe:
            raise ValueError(f"n_microbatch {self.n_microbatch} must be >= "
                             f"pipe {self.pipe} (1F1B needs a full warmup)")
        if self.bucket_mb < 0:
            raise ValueError("bucket_mb must be >= 0 (0 = default bucket size)")
        if self.dp and self.batch % self.dp:
            raise ValueError(f"batch {self.batch} not divisible by dp={self.dp}")
        if self.staleness < 0 or self.backup_workers < 0:
            raise ValueError("staleness and backup_workers must be >= 0")
        if self.staleness or self.backup_workers:
            if not self.dp:
                raise ValueError("staleness/backup_workers need an explicit "
                                 "data-parallel trainer: set dp > 0")
            if self.pipe > 1:
                raise ValueError("async PS assumes one flat data axis; "
                                 "incompatible with pipe > 1")
            if self.sync_overlap:
                raise ValueError("staleness already amortizes the pull "
                                 "traffic; incompatible with sync_overlap")
            if self.backup_workers >= self.dp:
                raise ValueError(f"backup_workers {self.backup_workers} must "
                                 f"be < dp {self.dp}")

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "JobSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "JobSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def from_json(cls, s: str) -> "JobSpec":
        return cls.from_dict(json.loads(s))
