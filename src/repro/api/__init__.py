"""repro.api — one Experiment/Session facade from plan to train/serve/bench.

The paper's procedure (pick minibatch + algorithms, size the mesh and the
parameter servers, run) as a single declarative API:

    from repro.api import JobSpec, Session

    sess = Session(JobSpec(arch="granite-3-2b", reduced=True, steps=60))
    print(sess.plan().predicted["lemma32"])     # sized before running
    rep = sess.train()                          # measured Report
    rep.save("results/train_report.json")       # one schema everywhere

Every entry point — ``repro.launch.train``/``serve``, ``benchmarks/*``,
``examples/*`` — goes through this facade, and every artifact is a
:class:`Report` validated by :func:`validate_report`.

Campaigns sweep the whole guideline space in one call (one Report per grid
cell plus a throughput-vs-efficiency Pareto summary):

    camp = Session.sweep(spec, {"topology": ["flat8", "2x4"],
                                "batch": [4, 8]}, kind="plan")
    camp.summary()["pareto"]
"""
from repro.api.campaign import CAMPAIGN_SCHEMA_ID, Campaign, pareto_front
from repro.api.report import (KINDS, Report, SCHEMA_ID, TUNING_SCHEMA_ID,
                              validate_report)
from repro.api.session import Session
from repro.api.spec import COMPRESSIONS, JobSpec, MESHES, SYNCS, TOPOLOGIES

__all__ = [
    "JobSpec", "Session", "Report", "Campaign", "validate_report",
    "pareto_front", "SCHEMA_ID", "CAMPAIGN_SCHEMA_ID", "TUNING_SCHEMA_ID",
    "KINDS", "MESHES", "SYNCS", "COMPRESSIONS", "TOPOLOGIES",
]
