"""repro.api — one Experiment/Session facade from plan to train/serve/bench.

The paper's procedure (pick minibatch + algorithms, size the mesh and the
parameter servers, run) as a single declarative API:

    from repro.api import JobSpec, Session

    sess = Session(JobSpec(arch="granite-3-2b", reduced=True, steps=60))
    print(sess.plan().predicted["lemma32"])     # sized before running
    rep = sess.train()                          # measured Report
    rep.save("results/train_report.json")       # one schema everywhere

Every entry point — ``repro.launch.train``/``serve``, ``benchmarks/*``,
``examples/*`` — goes through this facade, and every artifact is a
:class:`Report` validated by :func:`validate_report`.
"""
from repro.api.report import KINDS, Report, SCHEMA_ID, validate_report
from repro.api.session import Session
from repro.api.spec import COMPRESSIONS, JobSpec, MESHES, SYNCS

__all__ = [
    "JobSpec", "Session", "Report", "validate_report",
    "SCHEMA_ID", "KINDS", "MESHES", "SYNCS", "COMPRESSIONS",
]
