"""Session — resolve a :class:`JobSpec` through the planner and execute it.

One object owns the whole Fig.-1 procedure: the planner sizes the job
(microbatch, algorithms, sync schedule — Lemmas 3.1/3.2), then ``train`` /
``serve`` / ``bench`` run it and ``dryrun`` / ``plan`` stop at the
prediction.  Every method returns the same :class:`repro.api.Report`, so a
planner prediction and a measured run are directly comparable artifacts.

The planner always runs on the FULL architecture and the spec's production
shape/mesh — the paper's procedure sizes the real job; with
``spec.reduced`` the *execution* uses the smoke-scale family member.

``tune`` closes the loop on measurements (``repro.core.autotune``): it
times kernel variants, calibrates the hardware constants, runs the paper's
minibatch procedure, and re-plans — a session built with ``calibration=``
(or a ``Session.sweep(calibration=...)`` campaign) prices every prediction
on those measured constants.  See ``docs/tuning_guide.md``.
"""
from __future__ import annotations

import itertools
import math
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # import-light: autotune pulls kernels/jax lazily anyway
    from repro.core.autotune import Calibration, TuneResult

import numpy as np

from repro.api.campaign import Campaign
from repro.api.report import Report
from repro.api.spec import JobSpec
from repro.obs import MetricsRegistry, Tracer
from repro.obs.trace import monotonic
from repro.configs.base import ModelConfig, get_config, get_shape
from repro.core import amdahl, memory_model as mm, ps as ps_lib
from repro.core.hardware import (ClusterSpec, MeshSpec, MULTI_POD, SINGLE_POD,
                                 get_cluster)
from repro.core.planner import (Plan, estimate_step_time, plan as plan_fn,
                                r_o_from_terms)

# Lemma 3.1 efficiency/speedup are reported for these device counts (the
# paper's Fig. 4 sweep)
LEMMA31_G = (2, 4, 8, 16)


class Session:
    """Execute one JobSpec; every method returns a validated Report."""

    def __init__(self, spec: JobSpec, *, config: Optional[ModelConfig] = None,
                 calibration: Optional["Calibration"] = None):
        self.spec = spec
        self.cfg_full = get_config(spec.arch)
        self.cfg = config if config is not None else (
            self.cfg_full.reduced() if spec.reduced else self.cfg_full)
        if (spec.pipe > 1 and config is None and spec.reduced):
            # reduced() keeps one layer cycle — nothing to cut into stages.
            # Deepen to two cycles per stage: the minimum that both cuts
            # and keeps every stage's scan a real loop (trip-count-1 scans
            # get inlined/re-fused by XLA, breaking bit-identity with the
            # single-stage trainer — see repro.distributed.pipeline).
            from repro.models.model import main_cycles

            need = 2 * spec.pipe
            if main_cycles(self.cfg) < need:
                self.cfg = self.cfg.replace(
                    num_layers=self.cfg.first_k_dense
                    + need * len(self.cfg.pattern))
        self.shape = get_shape(spec.shape)
        if spec.topology:
            # a named cluster pins the mesh geometry to its chip count
            # (dp = chips, tp = 1: sweeps compare gradient-sync topologies)
            self.cluster: Optional[ClusterSpec] = get_cluster(spec.topology)
            self.mesh_spec = MeshSpec.from_cluster(self.cluster)
        else:
            self.mesh_spec = SINGLE_POD if spec.mesh == "single" else MULTI_POD
            self.cluster = self.mesh_spec.topology
        # a Calibration (repro.core.autotune) re-prices the mesh on measured
        # constants: every plan/prediction this session emits uses them
        self.calibration = calibration
        if calibration is not None:
            self.mesh_spec = calibration.apply(self.mesh_spec)
            self.cluster = self.mesh_spec.topology
        self._config_override = config is not None
        self._plan: Optional[Plan] = None
        self._tuned: Optional["TuneResult"] = None
        # telemetry of the last measured run (repro.obs) — set by
        # train/bench/serve/tune, inspectable after the Report comes back
        self.last_tracer: Optional[Tracer] = None
        self.last_metrics: Optional[MetricsRegistry] = None

    # ------------------------------------------------------------------
    def _make_obs(self) -> Tuple[Tracer, MetricsRegistry]:
        """Fresh telemetry for one measured run.  The tracer is always
        enabled inside a Session: span wall clocks ARE the measurements
        (StepTimes / GenResult), and the ``metrics/v1`` section every
        measured Report must carry is rendered from the registry."""
        tracer = Tracer(enabled=True)
        metrics = MetricsRegistry()
        self.last_tracer, self.last_metrics = tracer, metrics
        return tracer, metrics

    def _save_trace(self, kind: str, tracer: Tracer) -> Dict[str, Any]:
        """Chrome-trace export to ``spec.trace_dir`` (when set); returns the
        meta fragment recording where it landed."""
        if not self.spec.trace_dir:
            return {}
        path = Path(self.spec.trace_dir) / f"trace_{kind}.json"
        tracer.save(path)
        return {"trace_file": str(path), "trace_events": len(tracer)}

    # ------------------------------------------------------------------
    def _overlap_kwargs(self) -> Dict[str, Any]:
        """Overlap knobs every planner/pricing call shares: the spec's
        ``sync_overlap``/``bucket_mb``, with the hideable window derated to
        the *measured* overlap fraction when a calibration carries one."""
        eff = 1.0
        if self.calibration is not None \
                and getattr(self.calibration, "bucket_mb", 0.0) > 0:
            # bucket_mb > 0 marks a *ran* overlap sweep; its fraction is
            # the measurement even when it measured 0.0 (no hiding
            # achieved) — do not fall back to the ideal window then
            eff = self.calibration.overlap_fraction
        return dict(sync_overlap=self.spec.sync_overlap,
                    bucket_mb=self.spec.bucket_mb, overlap_efficiency=eff)

    @property
    def resolved_plan(self) -> Plan:
        if self._plan is None:
            self._plan = plan_fn(self.cfg_full, self.shape, self.mesh_spec,
                                 pipe=self.spec.pipe or None,
                                 n_microbatch=self.spec.n_microbatch,
                                 staleness=self.spec.staleness,
                                 backup_workers=self.spec.backup_workers,
                                 **self._overlap_kwargs())
        return self._plan

    @property
    def tuned(self) -> "TuneResult":
        """The autotuner's result for this spec (runs the microbenchmarks +
        calibration on first access; cached for the session)."""
        if self._tuned is None:
            from repro.core import autotune

            spec = self.spec
            tracer, metrics = self._make_obs()
            self._tuned = autotune.autotune(
                self.cfg, self.cfg_full, self.shape, self.mesh_spec,
                batch=spec.batch, seq=spec.seq, steps=spec.tune_steps,
                dp=spec.dp, seed=spec.seed, cache_path=spec.tune_cache,
                tracer=tracer, metrics=metrics)
        return self._tuned

    def build_run_opt(self):
        """RunConfig/OptConfig for this spec — planner-adopted knobs when
        ``use_planner`` (exactly what ``launch/train.py --plan`` did), then
        measured-knob overrides (attention algorithm, feasible microbatch)
        when ``spec.tune``."""
        import dataclasses as _dc

        from repro.models.blocks import RunConfig
        from repro.optim.adamw import OptConfig

        spec = self.spec
        warmup = max(spec.steps // 10, 1)
        if spec.use_planner:
            p = self.resolved_plan
            run = RunConfig(
                attn_impl="dense" if p.attn_impl == "dense" else "auto",
                remat=p.remat, microbatch=min(p.microbatch, spec.batch))
            opt = OptConfig(kind=p.opt_kind, lr=spec.lr, warmup_steps=warmup,
                            total_steps=spec.steps)
        else:
            run = RunConfig(attn_impl="auto", remat="block")
            opt = OptConfig(lr=spec.lr, warmup_steps=warmup,
                            total_steps=spec.steps)
        if spec.tune:
            t = self.tuned
            # chosen_microbatch == 0 means the production job fits at no
            # microbatch — fall back to the most frugal setting (1), never
            # to 0 (RunConfig's "no accumulation", the *maximal* footprint)
            run = _dc.replace(
                run, attn_impl=t.attn_impl(),
                microbatch=max(min(t.chosen_microbatch, spec.batch), 1))
        return run, opt

    # ------------------------------------------------------------------
    # Predictive kinds
    # ------------------------------------------------------------------
    def plan(self) -> Report:
        """Resolve the planner only: spec + plan + Lemma predictions."""
        return self._report("plan", {}, self._predicted())

    def dryrun(self) -> Report:
        """Analytic dry run — plan plus the step-time roofline terms and
        the memory-model breakdown, no compile and no training.  (The
        heavyweight lower+compile sweep stays in ``repro.launch.dryrun``.)"""
        p = self.resolved_plan
        pred = self._predicted()
        dp, tp = self.mesh_spec.dp, self.mesh_spec.tp
        if self.shape.kind in ("train", "prefill"):
            mem = mm.train_memory(
                self.cfg_full, self.shape, dp=dp, tp=tp, fsdp=p.fsdp,
                microbatch=p.microbatch, attn_impl=p.attn_impl, remat=p.remat,
                seq_parallel=p.seq_parallel, opt_kind=p.opt_kind)
        else:
            mem = mm.decode_memory(self.cfg_full, self.shape, dp=dp, tp=tp,
                                   fsdp=p.fsdp)
        pred["memory_bytes"] = {
            k: float(getattr(mem, k))
            for k in ("params", "grads", "opt_state", "activations",
                      "logits", "kv_cache")}
        pred["memory_bytes"]["total"] = float(mem.total)
        pred["fits"] = p.fits
        return self._report("dryrun", {}, pred)

    # ------------------------------------------------------------------
    # Measured kinds
    # ------------------------------------------------------------------
    def tune(self) -> Report:
        """Run the closed-loop autotuner (repro.core.autotune): time the
        kernel algorithm variants, measure short trainer steps, calibrate
        the cluster constants, run the paper's minibatch/algorithm
        procedure, and re-plan on the measured numbers.  Returns a Report
        of kind ``tune`` whose ``measured["tuning"]`` section carries the
        ``repro.api/tuning/v1`` schema."""
        res = self.tuned
        measured: Dict[str, Any] = dict(res.measured)
        measured["tuning"] = res.section()
        if self.last_metrics is not None:
            measured["metrics"] = self.last_metrics.section()
        meta_extra = (self._save_trace("tune", self.last_tracer)
                      if self.last_tracer is not None else {})
        return self._report("tune", measured, self._predicted(),
                            meta_extra=meta_extra)

    def train(self) -> Report:
        """Run the training loop (single-process GSPMD, or the explicit
        data-parallel trainer when ``spec.dp > 0``)."""
        return self._run_train("train")

    def bench(self) -> Report:
        """A measured run reported as a benchmark artifact: identical
        execution to :meth:`train`, kind ``bench`` (no logging by default
        conventions is up to the spec)."""
        return self._run_train("bench")

    def _run_train(self, kind: str) -> Report:
        spec = self.spec
        run, opt = self.build_run_opt()  # may touch self.tuned (own obs)
        tracer, metrics = self._make_obs()
        loop_kw = dict(batch=spec.batch, seq=spec.seq, steps=spec.steps,
                       seed=spec.seed, log_every=spec.log_every,
                       ckpt_dir=spec.ckpt_dir or None,
                       ckpt_every=spec.ckpt_every)
        sync_rep = pipe_rep = async_rep = None
        if spec.pipe > 1:
            import dataclasses as _dc

            import jax

            from repro.distributed import PipelineTrainer

            devs = jax.devices()
            world = spec.dp or len(devs)
            if len(devs) < world:
                raise RuntimeError(
                    f"pipe={spec.pipe} on {world} devices but only "
                    f"{len(devs)} visible; set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={world}")
            # the 1F1B schedule owns microbatching — the planner's
            # accumulation knob must not nest another scan inside a stage
            run = _dc.replace(run, microbatch=0)
            strategy = (self.resolved_plan.resolve_sync()
                        if spec.sync == "auto" else spec.sync)
            trainer = PipelineTrainer(
                self.cfg, run, opt, pipe=spec.pipe,
                n_microbatch=spec.n_microbatch, strategy=strategy,
                compression=spec.compress, devices=devs[:world],
                tracer=tracer, metrics=metrics)
            res = trainer.train(**loop_kw)
            sync_rep = trainer.report()
            pipe_rep = trainer.pipeline_report()
        elif spec.dp and (spec.staleness or spec.backup_workers):
            import jax

            from repro.distributed import AsyncPSTrainer

            devs = jax.devices()
            if len(devs) < spec.dp:
                raise RuntimeError(
                    f"dp={spec.dp} but only {len(devs)} devices visible; set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{spec.dp}")
            # bounded staleness is a parameter-server schedule by
            # construction; "auto" resolves to it rather than the planner's
            # all-reduce pick
            strategy = ("parameter_server" if spec.sync == "auto"
                        else spec.sync)
            trainer = AsyncPSTrainer(
                self.cfg, run, opt, staleness=spec.staleness,
                backup_workers=spec.backup_workers, strategy=strategy,
                compression=spec.compress, devices=devs[:spec.dp],
                tracer=tracer, metrics=metrics)
            res = trainer.train(**loop_kw)
            sync_rep = trainer.report()
            async_rep = trainer.async_report()
        elif spec.dp:
            import jax

            from repro.distributed import DataParallelTrainer

            devs = jax.devices()
            if len(devs) < spec.dp:
                raise RuntimeError(
                    f"dp={spec.dp} but only {len(devs)} devices visible; set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{spec.dp}")
            from repro.core.ps import DEFAULT_BUCKET_MB

            kw = dict(compression=spec.compress, devices=devs[:spec.dp],
                      topology=self.cluster,
                      sync_overlap=spec.sync_overlap,
                      bucket_mb=spec.bucket_mb or DEFAULT_BUCKET_MB,
                      tracer=tracer, metrics=metrics)
            if spec.sync == "auto":
                trainer = DataParallelTrainer.from_plan(
                    self.resolved_plan, self.cfg, run, opt, **kw)
            else:
                trainer = DataParallelTrainer(self.cfg, run, opt,
                                              strategy=spec.sync, **kw)
            res = trainer.train(**loop_kw)
            sync_rep = trainer.report()
        else:
            from repro.train.loop import train as train_loop

            res = train_loop(self.cfg, run, opt, tracer=tracer, **loop_kw)
            # the single-process loop has no phase-publishing step_fn, so
            # the session publishes its StepTimes into the registry
            for t in res.step_times:
                metrics.inc("train/steps")
                metrics.observe("train/compute_s", t.compute)
                metrics.observe("train/dist_update_s", t.dist_update)
                metrics.observe("train/param_update_s", t.param_update)
                metrics.observe("train/step_s",
                                t.compute + t.dist_update + t.param_update)
        measured = res.summary()
        metrics.set_gauge("train/tokens_per_s", measured["tokens_per_s"])
        metrics.set_gauge("train/r_o", measured["r_o"])
        if sync_rep is not None:
            measured["sync"] = sync_rep.as_dict()
        if pipe_rep is not None:
            measured["pipeline"] = pipe_rep.as_dict()
        if async_rep is not None:
            measured["async_ps"] = async_rep.as_dict()
        if spec.tune:  # the run adopted tuned knobs: record what they were
            measured["tuning"] = self.tuned.section()
        measured["metrics"] = metrics.section()
        predicted = self._predicted(measured_r_o=measured["r_o"])
        return self._report(kind, measured, predicted,
                            meta_extra=self._save_trace(kind, tracer))

    def serve(self) -> Report:
        """Batched generation, measured end to end.  ``spec.serve_mode``
        picks the runtime: ``continuous`` (in-flight batching over the
        paged KV cache — ``repro.serve.continuous``) or ``static`` (the
        FIFO Engine/BatchScheduler).  Both emit the same measured keys
        plus the ``repro.api/serving/v1`` section, so the two runtimes
        are directly comparable artifacts."""
        if self.spec.serve_mode == "continuous":
            return self._serve_continuous()
        return self._serve_static()

    def _serve_workload(self):
        """The seeded synthetic workload both serve modes share: ragged
        prompt lengths in [8, 48) and ragged ``n_new`` in
        [max(1, n_new/4), n_new] — raggedness is what separates the two
        schedulers, so it is the spec, not an option."""
        spec, cfg = self.spec, self.cfg
        rng = np.random.default_rng(spec.seed)
        k = cfg.num_codebooks
        reqs = []
        for _ in range(spec.requests):
            n = int(rng.integers(8, 48))
            n_new = int(rng.integers(max(1, spec.n_new // 4),
                                     spec.n_new + 1))
            shape = (n, k) if k else (n,)
            prompt = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
            reqs.append((prompt, n, n_new))
        return reqs

    def kv_pool_blocks(self) -> int:
        """KV pool size: ``spec.max_kv_blocks`` when pinned, else the
        Eq. 5 analogue (``memory_model.max_kv_blocks`` on this mesh's
        chip, calibration-overlaid) capped at this run's working set
        (``max_batch`` full-length rows — reduced smoke configs would
        otherwise derive pools of millions of blocks)."""
        spec = self.spec
        if spec.max_kv_blocks:
            return spec.max_kv_blocks
        cap = spec.max_batch * math.ceil(spec.s_max / spec.kv_block)
        derived = mm.max_kv_blocks(self.cfg, self.mesh_spec.chip.hbm_bytes,
                                   block_size=spec.kv_block,
                                   max_batch=spec.max_batch)
        return min(derived, cap) if derived > 0 else cap

    @staticmethod
    def _latency_stats(latencies) -> Dict[str, float]:
        xs = np.asarray(sorted(latencies), float)
        return {"p50": float(np.percentile(xs, 50)),
                "p95": float(np.percentile(xs, 95)),
                "p99": float(np.percentile(xs, 99)),
                "mean": float(xs.mean()), "max": float(xs.max())}

    def _serving_section(self, *, mode: str, kv_stats: Dict[str, Any],
                         latencies, stats: Dict[str, Any], wall: float,
                         n_tokens: int, n_news, lengths,
                         metrics) -> Dict[str, Any]:
        """The ``repro.api/serving/v1`` block: measured distribution +
        the inference replica lemma's prediction next to it."""
        from repro.api.report import SERVING_SCHEMA_ID

        spec = self.spec
        lat = self._latency_stats(latencies)
        tps = n_tokens / max(wall, 1e-9)
        # measured per-step decode time (the lemma's t_step, observed)
        dh = metrics.histogram("serve/decode_s")
        t_step_meas = dh.sum / dh.count if dh.count else 0.0
        ph = metrics.histogram("serve/prefill_s")
        t_pre_meas = ph.sum / ph.count if ph.count else 0.0
        # predicted t_step from the cost model: decode is HBM-bound —
        # stream bf16 weights + the resident KV once per step (priced on
        # this session's chip, calibration-overlaid when present)
        chip = self.mesh_spec.chip
        param_bytes = 2.0 * mm.n_params(self.cfg)
        kv_bytes = spec.max_batch * spec.s_max * mm.kv_token_bytes(self.cfg)
        t_step_pred = ps_lib.decode_step_time(param_bytes, kv_bytes,
                                              chip.hbm_bw)
        mean_prompt = float(np.mean(list(lengths)))
        mean_n_new = float(np.mean(list(n_news)))
        # prefill prediction: per-token memory-bound like decode (crude
        # but unit-consistent; the measured column sits right next to it)
        t_pre_pred = mean_prompt * t_step_pred / max(spec.max_batch, 1)
        slo_s = spec.slo_ms / 1e3 if spec.slo_ms else 2.0 * lat["mean"]
        t_svc_pred = ps_lib.service_time(t_pre_pred, int(round(mean_n_new)),
                                         t_step_pred)
        # offered load for the lemma: spec-pinned, else 2x one replica
        rate = spec.arrival_rate or 2.0 * spec.max_batch / max(t_svc_pred,
                                                               1e-9)
        predicted = ps_lib.serve_replica_plan(
            arrival_rate=rate, t_prefill_s=t_pre_pred,
            t_step_s=t_step_pred, n_new=int(round(mean_n_new)),
            batch=spec.max_batch, slo_s=slo_s)
        return {
            "schema": SERVING_SCHEMA_ID,
            "mode": mode,
            "scheduler": {
                "max_batch": spec.max_batch,
                "requests": spec.requests,
                "arrival": spec.arrival,
                "prefill_chunk": spec.prefill_chunk,
            },
            "kv_cache": kv_stats,
            "latency_s": lat,
            "throughput": {
                "tokens_per_s": tps,
                "decode_token_steps": int(stats.get("decode_token_steps", 0)),
                "wasted_decode_steps": int(stats.get("wasted_decode_steps", 0)),
                "engine_steps": int(stats.get("engine_steps", 0)),
                "delivered_tokens": int(stats.get("delivered_tokens",
                                                  n_tokens)),
            },
            "slo": {"slo_s": slo_s, "attained": bool(lat["p99"] <= slo_s)},
            "replica_lemma": {
                "predicted": predicted,
                "measured": {
                    "t_step_s": t_step_meas,
                    "t_prefill_s": t_pre_meas,
                    "t_service_s": lat["mean"],
                    "tokens_per_s": tps,
                },
            },
        }

    @staticmethod
    def _per_request(results, latencies) -> List[Dict[str, Any]]:
        out = []
        for rid in sorted(results):
            toks = np.asarray(results[rid])
            head = toks[:8].tolist() if toks.ndim == 1 else toks[:2].tolist()
            out.append({"rid": rid, "tokens": int(toks.shape[0]),
                        "head": head,
                        "latency_s": float(latencies.get(rid, 0.0))})
        return out

    _STATIC_KV_STATS = {"block_size": 0, "n_blocks": 0, "used_blocks": 0,
                        "peak_blocks": 0, "peak_occupancy": 0.0,
                        "shared_block_hits": 0, "block_bytes": 0.0}

    def _serve_static(self) -> Report:
        """The FIFO Engine/BatchScheduler runtime (linear cache)."""
        from repro.models.blocks import RunConfig
        from repro.serve.engine import BatchScheduler, Engine

        spec, cfg = self.spec, self.cfg
        run = RunConfig(attn_impl="dense", remat="none")
        tracer, metrics = self._make_obs()
        eng = Engine(cfg, run, s_max=spec.s_max, seed=spec.seed,
                     tracer=tracer, metrics=metrics)
        sched = BatchScheduler(eng, max_batch=spec.max_batch)
        lengths, n_news = [], []
        for prompt, n, n_new in self._serve_workload():
            sched.submit(prompt, n_new)
            lengths.append(n)
            n_news.append(n_new)
        t0 = monotonic()
        results = sched.run()
        wall = monotonic() - t0
        per_request = self._per_request(results, sched.latencies)
        n_tokens = sum(r["tokens"] for r in per_request)
        metrics.set_gauge("serve/wall_s", wall)
        metrics.set_gauge("serve/delivered_tokens_per_s",
                          n_tokens / max(wall, 1e-9))
        serving = self._serving_section(
            mode="static", kv_stats=dict(self._STATIC_KV_STATS),
            latencies=list(sched.latencies.values()), stats=sched.stats,
            wall=wall, n_tokens=n_tokens, n_news=n_news, lengths=lengths,
            metrics=metrics)
        measured = {
            "requests": spec.requests,
            "n_new": spec.n_new,
            "prompt_lengths": lengths,
            "n_tokens": n_tokens,
            "wall_s": wall,
            "tokens_per_s": n_tokens / max(wall, 1e-9),
            "batches": [g.stats() for g in sched.history],
            "per_request": per_request,
            "serving": serving,
            "metrics": metrics.section(),
        }
        return self._report("serve", measured, self._predicted(),
                            meta_extra=self._save_trace("serve", tracer))

    def _serve_continuous(self) -> Report:
        """In-flight batching over the paged KV cache, admission gated by
        the Eq. 5 block bound (``repro.serve.continuous``)."""
        from repro.models.blocks import RunConfig
        from repro.serve.arrivals import make_trace
        from repro.serve.continuous import (ContinuousEngine,
                                            ContinuousScheduler)
        from repro.serve.kvcache import PagedKVCache

        spec, cfg = self.spec, self.cfg
        run = RunConfig(attn_impl="dense", remat="none")
        tracer, metrics = self._make_obs()
        eng = ContinuousEngine(cfg, run, s_max=spec.s_max,
                               max_batch=spec.max_batch,
                               prefill_chunk=spec.prefill_chunk,
                               seed=spec.seed, tracer=tracer,
                               metrics=metrics)
        n_blocks = self.kv_pool_blocks()
        kv = PagedKVCache(cfg, block_size=spec.kv_block, n_blocks=n_blocks,
                          s_max=spec.s_max)
        sched = ContinuousScheduler(eng, kv)
        arrivals = make_trace(spec.arrival, spec.requests, seed=spec.seed)
        lengths, n_news = [], []
        for (prompt, n, n_new), step in zip(self._serve_workload(),
                                            arrivals):
            sched.submit(prompt, n_new, arrival_step=step)
            lengths.append(n)
            n_news.append(n_new)
        t0 = monotonic()
        results = sched.run()
        wall = monotonic() - t0
        per_request = self._per_request(results, sched.latencies)
        n_tokens = sum(r["tokens"] for r in per_request)
        metrics.set_gauge("serve/wall_s", wall)
        metrics.set_gauge("serve/delivered_tokens_per_s",
                          n_tokens / max(wall, 1e-9))
        serving = self._serving_section(
            mode="continuous", kv_stats=kv.stats(),
            latencies=list(sched.latencies.values()), stats=sched.stats,
            wall=wall, n_tokens=n_tokens, n_news=n_news, lengths=lengths,
            metrics=metrics)
        measured = {
            "requests": spec.requests,
            "n_new": spec.n_new,
            "prompt_lengths": lengths,
            "n_tokens": n_tokens,
            "wall_s": wall,
            "tokens_per_s": n_tokens / max(wall, 1e-9),
            "per_request": per_request,
            "serving": serving,
            "metrics": metrics.section(),
        }
        return self._report("serve", measured, self._predicted(),
                            meta_extra=self._save_trace("serve", tracer))

    # ------------------------------------------------------------------
    # Campaigns: the paper's guidelines as one queryable sweep
    # ------------------------------------------------------------------
    SWEEP_KINDS = ("plan", "dryrun", "train", "bench", "serve", "tune")

    @classmethod
    def sweep(cls, base: JobSpec, grid: Dict[str, Sequence[Any]], *,
              kind: str = "plan", progress: bool = False,
              calibration: Optional["Calibration"] = None) -> Campaign:
        """Fan the cartesian product of ``grid`` out over ``base`` and run
        one Session method per cell.

        ``grid`` maps JobSpec field names to the values to sweep (arch x
        dp x sync x compress x batch x topology x ...); each cell is
        ``base.replace(**overrides)``.  ``kind`` picks what runs per cell:
        ``plan``/``dryrun`` stay predictive (fast), ``train``/``bench``/
        ``serve`` execute.  Cells whose spec is invalid (e.g. batch not
        divisible by dp) or whose run fails land in ``Campaign.skipped``
        with the error, so one bad cell cannot sink the campaign.

        ``calibration`` (a measured ``repro.core.autotune.Calibration``,
        e.g. ``Session(spec).tuned.calibration``) re-prices every cell on
        measured constants instead of datasheet numbers, so the campaign's
        predictive cells are comparable to wall-clock measurements.

        Note: predictive kinds only differentiate plan-affecting fields
        (``arch``/``shape``/``mesh``/``topology``) — the planner prices the
        production job, so sweeping execution knobs (batch/compress/dp/
        sync) under ``kind="plan"`` yields cells with identical metrics;
        run those grids with ``kind="train"`` to measure them.
        """
        if kind not in cls.SWEEP_KINDS:
            raise ValueError(f"sweep kind must be one of {cls.SWEEP_KINDS}, "
                             f"got {kind!r}")
        if not grid:
            raise ValueError("sweep needs a non-empty grid")
        keys = sorted(grid)
        values = [list(grid[k]) for k in keys]
        reports: List[Report] = []
        cells: List[Dict[str, Any]] = []
        skipped: List[Dict[str, Any]] = []
        for combo in itertools.product(*values):
            overrides = dict(zip(keys, combo))
            try:
                spec = base.replace(**overrides)
                rep = getattr(cls(spec, calibration=calibration), kind)()
            except Exception as e:  # record, keep sweeping
                skipped.append({"cell": overrides, "error": f"{type(e).__name__}: {e}"})
                if progress:
                    print(f"sweep[{kind}] {overrides} SKIPPED: {e}")
                continue
            reports.append(rep)
            cells.append(overrides)
            if progress:
                print(f"sweep[{kind}] {overrides} ok")
        return Campaign(kind=kind, grid={k: list(grid[k]) for k in keys},
                        cells=cells, reports=reports,
                        skipped=skipped).validate()

    # ------------------------------------------------------------------
    # Shared prediction / report assembly
    # ------------------------------------------------------------------
    def _predicted(self, *, measured_r_o: Optional[float] = None) -> Dict:
        p = self.resolved_plan
        out: Dict[str, Any] = {
            "est_step_time_s": p.est_step_time,
            "est_memory_gb": p.est_memory_gb,
            "efficiency_planned": p.efficiency,
        }
        # roofline terms (train-kind shapes only; decode is memory-bound)
        r_o_model = 0.0
        if self.shape.kind in ("train", "prefill"):
            terms = estimate_step_time(self.cfg_full, self.shape,
                                       self.mesh_spec, p.remat,
                                       max(p.microbatch, 1),
                                       pipe=getattr(p, "pipe", 1),
                                       n_microbatch=getattr(
                                           p, "n_microbatch", 0),
                                       **self._overlap_kwargs())
            out["step_time_terms"] = terms
            # with overlap on, only the exposed collective share is overhead
            r_o_model = r_o_from_terms(terms)
        if getattr(p, "pipe", 1) > 1:
            from repro.core.pipeline import pipeline_bubble

            out["pipeline"] = {
                "pipe": p.pipe,
                "n_microbatch": p.n_microbatch,
                "stage_cut": list(p.stage_cut or ()),
                "bubble_model": pipeline_bubble(p.pipe, p.n_microbatch),
            }
        # Lemma 3.1: efficiency/speedup curve from the best available R_O
        r_o = measured_r_o if measured_r_o is not None else r_o_model
        out["lemma31"] = {
            "r_o": r_o,
            "source": "measured" if measured_r_o is not None else "model",
            "per_device": {
                str(g): {"efficiency": amdahl.efficiency(g, r_o),
                         "speedup": amdahl.speedup(g, r_o)}
                for g in LEMMA31_G},
        }
        # Lemma 3.2: comm-time prediction for the planned schedule, priced
        # on the plan's topology tiers
        if p.sync_schedule in ("-", "") or not p.grad_bytes or p.link_bw <= 0:
            out["lemma32"] = {"schedule": p.sync_schedule or "-"}
        else:
            dp = p.mesh[0]
            t_c = (p.est_step_time if math.isfinite(p.est_step_time) else 1.0)
            tiers = p.dp_tiers()
            n_ps = ps_lib.n_parameter_servers(p.grad_bytes, dp, p.link_bw,
                                              max(t_c, 1e-9))
            comm = ps_lib.predicted_comm_time(
                p.sync_schedule, p.grad_bytes, dp, p.link_bw, n_ps=n_ps,
                tiers=tiers)
            out["lemma32"] = {
                "schedule": p.sync_schedule,
                "dp": dp,
                "grad_bytes": p.grad_bytes,
                "link_bw": p.link_bw,
                "n_parameter_servers": n_ps,
                "predicted_comm_s": comm,
                "t_c_s": t_c,
                "masked": comm <= t_c,
                "bottleneck_tier": p.bottleneck_tier,
            }
            if p.sync_overlap:
                # the overlapped refinement of the same lemma: comm that
                # stays exposed after hiding under the backward pass
                n_buckets = ps_lib.bucket_count(p.grad_bytes, p.bucket_mb)
                eff = self._overlap_kwargs()["overlap_efficiency"]
                exposed = ps_lib.overlap_exposed_comm(
                    comm, (1.0 - ps_lib.FWD_FRACTION) * t_c, n_buckets,
                    overlap_efficiency=eff)
                out["lemma32"]["overlap"] = {
                    "n_buckets": n_buckets,
                    "bucket_mb": p.bucket_mb or ps_lib.DEFAULT_BUCKET_MB,
                    "overlap_efficiency": eff,
                    "exposed_comm_s": exposed,
                    "hidden_comm_s": comm - exposed,
                    "masked_after_overlap": exposed <= t_c,
                }
            cluster = p.cluster
            if cluster is not None and not cluster.uniform:
                # tier-aware PS placement: B_ps in-node vs cross-node
                out["lemma32"]["ps_placement"] = ps_lib.ps_placement_plan(
                    p.grad_bytes, dp, cluster, max(t_c, 1e-9))
            if self.spec.staleness or self.spec.backup_workers:
                # bounded-staleness refinement: pull traffic amortized over
                # s+1 steps, straggler wait bought back by backup workers
                out["lemma32"]["async_ps"] = ps_lib.async_step_time(
                    p.grad_bytes, dp, n_ps, p.link_bw, max(t_c, 1e-9),
                    staleness=self.spec.staleness,
                    backup_workers=self.spec.backup_workers)
        return out

    def report_meta(self) -> Dict[str, Any]:
        """Provenance block shared by every Report this session emits —
        benchmarks that hand-build a Report must attach it too, so the
        artifact records the config that actually executed (which, with a
        ``config=`` override or ``reduced=True``, differs from the arch the
        spec/plan name)."""
        meta: Dict[str, Any] = {
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "executed_config": {
                "name": self.cfg.name,
                "d_model": self.cfg.d_model,
                "num_layers": self.cfg.num_layers,
                "vocab_size": self.cfg.vocab_size,
                "n_params": int(mm.n_params(self.cfg)),
            },
            "config_override": self._config_override,
        }
        if self.calibration is not None:
            meta["calibration"] = {
                "key": self.calibration.key,
                "achieved_flops": self.calibration.achieved_flops,
                "link_bw": self.calibration.link_bw,
            }
        if (self.spec.topology and self.spec.dp
                and self.cluster is not None
                and self.spec.dp != self.cluster.n_chips):
            meta["topology_note"] = (
                f"spec.dp={self.spec.dp} != topology "
                f"{self.spec.topology!r} chips={self.cluster.n_chips}: "
                "predicted blocks are priced on the full topology; the "
                "measured run executes on spec.dp devices, where the sync "
                "strategy may degenerate (see measured.sync.tiers)")
        return meta

    def _report(self, kind: str, measured: Dict, predicted: Dict, *,
                meta_extra: Optional[Dict[str, Any]] = None) -> Report:
        meta = self.report_meta()
        if meta_extra:
            meta.update(meta_extra)
        return Report(kind=kind, spec=self.spec.to_dict(),
                      plan=self.resolved_plan.to_dict(),
                      measured=measured, predicted=predicted,
                      meta=meta).validate()
