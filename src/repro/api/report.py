"""Report — the one JSON artifact every entry point emits.

FireCaffe and the Shi et al. performance-modeling line treat *configuration
-> predicted cost -> measured run* as a single pipeline whose predictions and
measurements must land in one comparable record.  ``Report`` is that record:

    {"schema": "repro.api/report/v1",
     "kind":   plan | dryrun | train | serve | bench,
     "spec":      the JobSpec that produced it,
     "plan":      the planner's Plan (runtime knobs + Lemma 3.1/3.2 inputs),
     "measured":  StepTimes means / SyncReport / serving stats (empty for
                  the purely predictive kinds),
     "predicted": Lemma 3.1 efficiency/speedup + Lemma 3.2 comm time +
                  the napkin step-time model,
     "meta":      free-form provenance}

``validate_report`` is the shared schema check used by the tests and CI —
every benchmark's JSON must pass it.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Union

from repro.obs.metrics import validate_metrics

SCHEMA_ID = "repro.api/report/v1"
# the autotuner's section under measured["tuning"] (Session.tune emits it;
# repro.core.autotune.TUNING_SCHEMA_ID mirrors this literal — layering keeps
# core from importing api)
TUNING_SCHEMA_ID = "repro.api/tuning/v1"
# the serving runtime's section under measured["serving"] (Session.serve
# emits it; repro.serve mirrors nothing — the literal lives here and the
# serve layer stays unimported, same layering rule as TUNING_SCHEMA_ID)
SERVING_SCHEMA_ID = "repro.api/serving/v1"
KINDS = ("plan", "dryrun", "train", "serve", "bench", "tune")

# kinds whose `measured` section must be populated, and the keys that make a
# measurement comparable across entry points (bench artifacts range from a
# full trajectory to a throughput sweep, so only the headline is required)
_MEASURED_REQUIRED = {
    "train": ("steps", "loss_last", "tokens_per_s", "r_o", "step_times_mean",
              "metrics"),
    "bench": ("tokens_per_s", "metrics"),
    "serve": ("requests", "tokens_per_s", "metrics", "serving"),
    "tune": ("tuning",),
}
# any report carrying a tuning section (kind "tune", or a train run that
# adopted tuned knobs) must carry a complete one
_TUNING_REQUIRED = ("minibatch", "kernels", "calibration", "replan")
_SPEC_REQUIRED = ("arch", "shape", "reduced", "steps", "batch", "seq", "seed")
_PLAN_REQUIRED = ("arch", "mesh", "microbatch", "attn_impl", "remat",
                  "sync_schedule", "est_step_time")
_PREDICTED_REQUIRED = ("lemma31", "lemma32")


@dataclass
class Report:
    kind: str
    spec: Dict[str, Any]
    plan: Dict[str, Any]
    measured: Dict[str, Any] = field(default_factory=dict)
    predicted: Dict[str, Any] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {"schema": SCHEMA_ID, **d}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    def validate(self) -> "Report":
        validate_report(self.to_dict())
        return self

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Report":
        validate_report(d)
        return cls(kind=d["kind"], spec=d["spec"], plan=d["plan"],
                   measured=d.get("measured", {}),
                   predicted=d.get("predicted", {}), meta=d.get("meta", {}))

    @classmethod
    def from_json(cls, s: str) -> "Report":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Shared schema check (hand-rolled: no jsonschema dependency in the image)
# ---------------------------------------------------------------------------


def _require(cond: bool, msg: str):
    if not cond:
        raise ValueError(f"invalid Report: {msg}")


def validate_report(d: Dict[str, Any]) -> Dict[str, Any]:
    """Raise ValueError unless ``d`` is a valid v1 Report dict; returns it."""
    _require(isinstance(d, dict), f"expected dict, got {type(d).__name__}")
    for key in ("schema", "kind", "spec", "plan", "measured", "predicted"):
        _require(key in d, f"missing top-level key {key!r}")
    _require(d["schema"] == SCHEMA_ID,
             f"schema {d['schema']!r} != {SCHEMA_ID!r}")
    _require(d["kind"] in KINDS, f"kind {d['kind']!r} not in {KINDS}")
    for sect in ("spec", "plan", "measured", "predicted"):
        _require(isinstance(d[sect], dict), f"{sect} must be a dict")
    for key in _SPEC_REQUIRED:
        _require(key in d["spec"], f"spec missing {key!r}")
    for key in _PLAN_REQUIRED:
        _require(key in d["plan"], f"plan missing {key!r}")
    for key in _PREDICTED_REQUIRED:
        _require(key in d["predicted"], f"predicted missing {key!r}")
    need = _MEASURED_REQUIRED.get(d["kind"], ())
    for key in need:
        _require(key in d["measured"],
                 f"measured missing {key!r} for kind {d['kind']!r}")
    if "pipe" in d["plan"]:
        _validate_pipe(d["plan"])
    if "tuning" in d["measured"]:
        _validate_tuning(d["measured"]["tuning"])
    if "serving" in d["measured"]:
        _validate_serving(d["measured"]["serving"])
    if "sync" in d["measured"]:
        _validate_sync(d["measured"]["sync"])
    if "async_ps" in d["measured"]:
        _validate_async(d["measured"]["async_ps"])
    spec = d["spec"]
    if (d["kind"] in ("train", "bench")
            and (spec.get("staleness") or spec.get("backup_workers"))):
        _require("async_ps" in d["measured"],
                 f"kind {d['kind']!r} with spec.staleness/backup_workers "
                 "must carry a measured.async_ps section")
    if "metrics" in d["measured"]:
        # any report may carry telemetry; delegate to repro.obs.metrics
        validate_metrics(d["measured"]["metrics"])
    return d


def _validate_pipe(plan: Dict[str, Any]):
    """Pipeline-shape invariants, checked whenever a plan declares a
    ``pipe`` field (legacy plan dicts without one skip this — ``Plan``'s
    from_dict migration fills the no-pipelining defaults): the stage count
    must be a positive divisor of the world the topology names
    (``pipe * dp * tp == world``), and 1F1B needs at least ``pipe``
    microbatches to fill its warmup."""
    pipe = plan["pipe"]
    _require(isinstance(pipe, int) and pipe >= 1,
             f"plan.pipe must be an int >= 1, got {pipe!r}")
    if pipe <= 1:
        return
    _require("n_microbatch" in plan,
             "pipelined plan (pipe > 1) missing 'n_microbatch'")
    m = plan["n_microbatch"]
    _require(isinstance(m, int) and m >= pipe,
             f"plan.n_microbatch {m!r} must be an int >= pipe {pipe} "
             "(1F1B needs a full warmup)")
    topo = plan.get("topology")
    if isinstance(topo, dict) and topo.get("tiers"):
        world = 1
        for t in topo["tiers"]:
            world *= int(t["size"])
        dp, tp = plan["mesh"]
        _require(pipe * int(dp) * int(tp) == world,
                 f"plan.pipe * dp * tp = {pipe}*{dp}*{tp} != world {world} "
                 "(topology tier-size product)")


# keys an overlapped SyncReport must carry in measured["sync"] (see
# repro.distributed.trainer.SyncReport's bucketed-overlap block and
# docs/schemas.md)
_SYNC_OVERLAP_REQUIRED = ("n_buckets", "overlap_fraction",
                          "exposed_comm_time", "measured_comm_s",
                          "bucket_sizes_bytes", "per_bucket_comm_s",
                          "overlapped_step_s")


def _validate_sync(s: Any):
    """Schema check for a measured SyncReport dict; the overlap fields are
    required — and bounded — whenever the run declared ``sync_overlap``."""
    _require(isinstance(s, dict),
             f"measured.sync must be a dict, got {type(s).__name__}")
    for key in ("strategy", "dp", "measured_comm_s", "predicted_comm_s"):
        _require(key in s, f"measured.sync missing {key!r}")
    if not s.get("sync_overlap"):
        return
    for key in _SYNC_OVERLAP_REQUIRED:
        _require(key in s, f"overlapped measured.sync missing {key!r}")
    frac = s["overlap_fraction"]
    _require(isinstance(frac, (int, float)) and 0.0 <= frac <= 1.0,
             f"sync.overlap_fraction must be in [0, 1], got {frac!r}")
    _require(int(s["n_buckets"]) >= 1,
             f"sync.n_buckets must be >= 1, got {s['n_buckets']!r}")
    _require(float(s["exposed_comm_time"])
             <= float(s["measured_comm_s"]) + 1e-12,
             "sync.exposed_comm_time exceeds the serial measured_comm_s")


# the bounded-staleness async-PS section under measured["async_ps"] (see
# repro.distributed.async_ps.AsyncPSReport and docs/checkpointing.md)
_ASYNC_REQUIRED = ("staleness", "backup_workers", "dp", "steps", "refreshes",
                   "mean_age", "max_age", "drops", "t_step_model")


def _validate_async(a: Any):
    """Schema check for a measured AsyncPSReport dict: staleness bounds the
    measured worker-param ages (the trainer's core invariant), drops are
    consistent with the backup-worker count, and the cost-model terms from
    :func:`repro.core.ps.async_step_time` ride along."""
    _require(isinstance(a, dict),
             f"measured.async_ps must be a dict, got {type(a).__name__}")
    for key in _ASYNC_REQUIRED:
        _require(key in a, f"measured.async_ps missing {key!r}")
    s = a["staleness"]
    _require(isinstance(s, int) and s >= 0,
             f"async_ps.staleness must be an int >= 0, got {s!r}")
    _require(float(a["max_age"]) <= s + 1e-12,
             f"async_ps.max_age {a['max_age']!r} exceeds the staleness "
             f"bound {s} — the trainer's invariant is broken")
    _require(0.0 <= float(a["mean_age"]) <= float(a["max_age"]) + 1e-12,
             "async_ps.mean_age must be in [0, max_age]")
    k = a["backup_workers"]
    _require(isinstance(k, int) and 0 <= k < int(a["dp"]),
             f"async_ps.backup_workers must be in [0, dp), got {k!r}")
    _require(int(a["drops"]) == k * int(a["steps"]),
             f"async_ps.drops {a['drops']!r} != backup_workers * steps "
             f"({k} * {a['steps']!r})")
    model = a["t_step_model"]
    _require(isinstance(model, dict),
             f"async_ps.t_step_model must be a dict, "
             f"got {type(model).__name__}")
    for key in ("push", "pull", "straggler_wait", "efficiency", "wall_step"):
        _require(key in model, f"async_ps.t_step_model missing {key!r}")


# the ``repro.api/serving/v1`` section: scheduler configuration, KV-block
# occupancy, the latency distribution, throughput accounting, the SLO
# verdict, and the replica lemma's prediction next to the measurement it
# came from (see docs/serving.md and docs/schemas.md)
_SERVING_REQUIRED = ("schema", "mode", "scheduler", "kv_cache", "latency_s",
                     "throughput", "slo", "replica_lemma")
_SERVING_SUBKEYS = {
    "scheduler": ("max_batch", "requests", "arrival"),
    "kv_cache": ("block_size", "n_blocks", "peak_blocks", "peak_occupancy",
                 "block_bytes"),
    "latency_s": ("p50", "p95", "p99", "mean", "max"),
    "throughput": ("tokens_per_s", "decode_token_steps",
                   "wasted_decode_steps", "engine_steps"),
    "slo": ("slo_s", "attained"),
    "replica_lemma": ("predicted", "measured"),
}
_SERVING_MODES = ("continuous", "static")


def _validate_serving(s: Any):
    """Schema check for the ``repro.api/serving/v1`` section."""
    _require(isinstance(s, dict),
             f"measured.serving must be a dict, got {type(s).__name__}")
    _require(s.get("schema") == SERVING_SCHEMA_ID,
             f"serving schema {s.get('schema')!r} != {SERVING_SCHEMA_ID!r}")
    for key in _SERVING_REQUIRED:
        _require(key in s, f"serving missing {key!r}")
    for sect, keys in _SERVING_SUBKEYS.items():
        _require(isinstance(s[sect], dict), f"serving.{sect} must be a dict, "
                 f"got {type(s[sect]).__name__}")
        for key in keys:
            _require(key in s[sect], f"serving.{sect} missing {key!r}")
    _require(s["mode"] in _SERVING_MODES,
             f"serving.mode {s['mode']!r} not in {_SERVING_MODES}")
    occ = s["kv_cache"]["peak_occupancy"]
    _require(isinstance(occ, (int, float)) and 0.0 <= occ <= 1.0,
             f"serving.kv_cache.peak_occupancy must be in [0, 1], got {occ!r}")
    lat = s["latency_s"]
    _require(float(lat["p50"]) <= float(lat["p99"]) + 1e-12,
             "serving.latency_s p50 exceeds p99")
    _require(float(lat["p99"]) <= float(lat["max"]) + 1e-12,
             "serving.latency_s p99 exceeds max")
    _require("replicas" in s["replica_lemma"]["predicted"],
             "serving.replica_lemma.predicted missing 'replicas'")


def _validate_tuning(t: Any):
    """Schema check for the ``repro.api/tuning/v1`` section."""
    _require(isinstance(t, dict),
             f"measured.tuning must be a dict, got {type(t).__name__}")
    _require(t.get("schema") == TUNING_SCHEMA_ID,
             f"tuning schema {t.get('schema')!r} != {TUNING_SCHEMA_ID!r}")
    for key in _TUNING_REQUIRED:
        _require(key in t, f"tuning missing {key!r}")
    for key in _TUNING_REQUIRED:
        _require(isinstance(t[key], dict), f"tuning.{key} must be a dict, "
                 f"got {type(t[key]).__name__}")
    _require("chosen" in t["minibatch"], "tuning.minibatch missing 'chosen'")
    for op, entry in t["kernels"].items():
        _require(isinstance(entry, dict) and "chosen" in entry,
                 f"tuning.kernels[{op!r}] missing 'chosen'")
    for key in ("measured_step_s", "est_step_time_calibrated_s",
                "est_step_time_uncalibrated_s"):
        _require(key in t["replan"], f"tuning.replan missing {key!r}")
    if "overlap" in t and isinstance(t["overlap"], dict) \
            and t["overlap"].get("measured"):
        ov = t["overlap"]
        _require("chosen_bucket_mb" in ov,
                 "measured tuning.overlap missing 'chosen_bucket_mb'")
        frac = ov.get("overlap_fraction")
        _require(isinstance(frac, (int, float)) and 0.0 <= frac <= 1.0,
                 f"tuning.overlap.overlap_fraction must be in [0, 1], "
                 f"got {frac!r}")
