import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). 512 placeholder host devices back both the
# single-pod (16,16) and multi-pod (2,16,16) production meshes.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh, and extract the roofline terms.

Per combination this produces
  * a FULL-depth compile — proves the sharding config is coherent, yields
    ``memory_analysis()`` (per-device bytes) and compile wall time;
  * two COUNTING compiles at 1 and 2 pattern-cycles (attention inner loops
    physically unrolled) — XLA's cost_analysis does not multiply while-body
    costs by trip count, so full-depth FLOPs / HBM bytes / collective wire
    bytes are derived by linear extrapolation:
        total = base(1 cycle) + (num_cycles - 1) × [cost(2 cycles) - cost(1)]
    (everything outside the layer scan — embedding, LM head, loss, optimizer
    scalars — lives in the base term; per-cycle costs, including remat
    recompute and FSDP all-gathers, live in the delta).

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k \
      --mesh single --out results/dryrun [--skip-full] [--skip-count]
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.compat import set_mesh
from repro.obs.trace import monotonic


def _planner_defaults(cfg, shape):
    """Runtime knobs for the baseline dry-run (full planner in repro.core)."""
    from repro.optim.adamw import OptConfig
    param_bytes = None  # filled lazily
    big = cfg.name in (
        "qwen2-72b", "jamba-1.5-large-398b", "arctic-480b",
        "deepseek-v2-236b", "llava-next-34b",
    )
    fsdp = big
    opt_kind = "momentum" if cfg.name == "arctic-480b" else "adamw"
    return fsdp, OptConfig(kind=opt_kind)


def variant_config(cfg, shape):
    """Arch variant actually lowered for this input shape (long-context SWA
    override for full-attention archs, per DESIGN.md §long_500k policy)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return cfg.replace(attn_window_override=8192), "swa8192-variant"
    return cfg, "native"


def _reduced_cycles(cfg, n_cycles):
    return cfg.replace(num_layers=cfg.first_k_dense + n_cycles * len(cfg.pattern))


def build_step_and_args(cfg, shape, mesh, run, *, counting=False,
                        optimized=False):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import steps as S
    from repro.launch import mesh as mesh_lib
    from repro.models.blocks import RunConfig

    fsdp, opt = _planner_defaults(cfg, shape)
    rules = mesh_lib.sharding_rules(mesh, cfg, shape, fsdp=fsdp)

    donate = ()
    if shape.kind in ("train", "prefill"):
        runc = RunConfig(
            attn_impl="counting" if counting else "chunked",
            remat="block",  # kept in counting mode so recompute FLOPs show up
            act_sharding=mesh_lib.act_sharding(mesh, shape, seq_parallel=True),
            unroll_layers=counting,
        )
        if optimized:
            # §Perf levers: seq-sharded CE path, shard_map expert parallelism,
            # buffer donation (params/opt aliasing)
            dp = mesh_lib.dp_axes(mesh)
            runc.logit_sharding = NamedSharding(mesh, P(dp, "model", None))
            if cfg.has_moe:
                runc.moe_mesh = mesh
            if shape.kind == "train":
                from repro.models import model as M
                from repro.models.common import partition_specs
                zrules = dict(rules)
                zrules["embed"] = dp
                pspecs = partition_specs(M.model_specs(cfg), zrules)
                runc.grad_shardings = jax.tree_util.tree_map(
                    lambda ps: NamedSharding(mesh, ps), pspecs)
                runc.bf16_grads = True
                donate = (0, 1)
    else:
        runc = RunConfig(attn_impl="dense", remat="none", act_sharding=None,
                         unroll_layers=counting)
        if optimized:
            runc.cache_scatter = True
            donate = (3,)  # caches updated in place

    inputs = S.input_specs(cfg, shape, mesh, rules,
                           kv_quant=(optimized and shape.kind == "decode"))
    if shape.kind == "train":
        params = S.abstract_params(cfg, mesh, rules)
        opt_state = S.abstract_opt_state(cfg, mesh, rules, opt)
        step = S.build_train_step(cfg, runc, opt)
        args = (params, opt_state, inputs)
        fn = lambda p, o, b: step(p, o, b)
    elif shape.kind == "prefill":
        params = S.abstract_params(cfg, mesh, rules, dtype="bfloat16")
        step = S.build_prefill_step(cfg, runc)
        args = (params, inputs)
        fn = step
    else:  # decode
        params = S.abstract_params(cfg, mesh, rules, dtype="bfloat16")
        step = S.build_decode_step(cfg, runc)
        args = (params, inputs["tokens"], inputs["pos"], inputs["caches"])
        fn = step
    return fn, args, donate


def lower_compile(fn, args, mesh, donate=()):
    t0 = monotonic()
    with set_mesh(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = monotonic() - t0
        t0 = monotonic()
        compiled = lowered.compile()
        t_compile = monotonic() - t0
    return lowered, compiled, t_lower, t_compile


def analyze(compiled, mesh):
    from repro.launch import hlo as hlo_lib

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        txt = compiled.as_text()
        stats = hlo_lib.collective_bytes(txt)
        out["collectives"] = stats
        out["wire_bytes"] = hlo_lib.total_wire_bytes(stats)
    except Exception as e:  # pragma: no cover
        out["collectives"] = {"error": str(e)}
        out["wire_bytes"] = 0.0
    return out


def run_one(arch, shape_name, mesh_kind, outdir, skip_full=False,
            skip_count=False, optimized=False, mesh_shape=None):
    from repro.configs.base import get_config, get_shape
    from repro.launch.mesh import make_production_mesh

    cfg0 = get_config(arch)
    shape = get_shape(shape_name)
    cfg, variant = variant_config(cfg0, shape)
    if mesh_shape:  # §Perf lever: reinterpret the 256 chips, e.g. 32x8
        import jax as _jax
        dp_sz, tp_sz = mesh_shape
        mesh = _jax.make_mesh((dp_sz, tp_sz), ("data", "model"),
                              devices=_jax.devices()[: dp_sz * tp_sz])
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "optimized": optimized,
        "num_devices": int(np.prod(list(mesh.shape.values()))),
        "pattern_cycles": cfg.num_cycles if not cfg.first_k_dense else
        (cfg.num_layers - cfg.first_k_dense) // len(cfg.pattern),
        "ok": False,
    }
    try:
        if not skip_full:
            fn, args, donate = build_step_and_args(cfg, shape, mesh, None,
                                                   optimized=optimized)
            lowered, compiled, t_lo, t_co = lower_compile(fn, args, mesh, donate)
            rec["full"] = analyze(compiled, mesh)
            rec["full"]["lower_s"] = round(t_lo, 2)
            rec["full"]["compile_s"] = round(t_co, 2)
            del lowered, compiled

        if not skip_count:
            n_cycles = rec["pattern_cycles"]
            counts = {}
            for nc in (1, 2):
                cfg_r = _reduced_cycles(cfg, nc)
                fn, args, donate = build_step_and_args(cfg_r, shape, mesh, None,
                                                       counting=True,
                                                       optimized=optimized)
                _, compiled, _, _ = lower_compile(fn, args, mesh, donate)
                counts[nc] = analyze(compiled, mesh)
                del compiled
            extra = {}
            for key in ("flops", "bytes_accessed", "wire_bytes"):
                base, two = counts[1][key], counts[2][key]
                delta = max(two - base, 0.0)
                extra[key] = base + (n_cycles - 1) * delta
                extra[key + "_per_cycle"] = delta
                extra[key + "_base"] = base
            rec["derived"] = extra
            rec["count_details"] = counts
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    path = outdir / f"{arch}__{shape_name}__{mesh_kind}.json"
    path.write_text(json.dumps(rec, indent=1, default=float))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:120]})"
    print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: {status}", flush=True)
    return rec["ok"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-full", action="store_true")
    ap.add_argument("--skip-count", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable the beyond-paper optimizations (§Perf): "
                         "seq-sharded CE, shard_map MoE, buffer donation")
    ap.add_argument("--mesh-shape", default="",
                    help="override single-pod mesh as DPxTP, e.g. 32x8")
    args = ap.parse_args()

    from repro.configs.base import ARCH_IDS, SHAPES

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                p = Path(args.out) / f"{arch}__{shape}__{mesh_kind}.json"
                if args.skip_existing and p.exists():
                    if json.loads(p.read_text()).get("ok"):
                        continue
                ms = None
                if args.mesh_shape:
                    ms = tuple(int(x) for x in args.mesh_shape.split("x"))
                ok = run_one(arch, shape, mesh_kind, args.out,
                             args.skip_full, args.skip_count,
                             optimized=args.opt, mesh_shape=ms)
                n_fail += (not ok)
    print(f"[dryrun] done, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
