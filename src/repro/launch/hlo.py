"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective bytes, so we
parse the compiled HLO text. SPMD HLO shapes are PER-PARTITION, so the wire
model below yields per-chip traffic directly:

  all-gather        : result × (n-1)/n      (receive everyone else's shard)
  all-reduce        : 2 × operand × (n-1)/n (ring reduce-scatter + all-gather)
  reduce-scatter    : operand × (n-1)/n
  all-to-all        : operand × (n-1)/n
  collective-permute: operand              (one send + one receive)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return len(first.split(","))
    m = _GROUPS_ALT_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str, default_group: int = 2) -> Dict[str, Dict[str, float]]:
    """Per-collective-type {count, result_bytes, operand_bytes, wire_bytes}.

    Shapes are per-partition (SPMD), so wire_bytes is per-chip traffic.
    """
    # first pass: map instruction name -> result bytes
    result_bytes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs starts with the type, e.g. "bf16[8,128]{1,0} all-reduce(..."
        tm = re.match(r"^(\([^)]*\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)", rhs)
        if tm:
            result_bytes[name.lstrip("%")] = _shape_bytes(tm.group(1))

    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "operand_bytes": 0.0,
                 "wire_bytes": 0.0})

    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        op = None
        for c in COLLECTIVES:
            # opcode appears right after the result type
            if re.search(rf"\]\S*\s+{c}(?:-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if f"{op}-done(" in rhs:
            continue  # counted at -start
        name = m.group(1).lstrip("%")
        rbytes = result_bytes.get(name, 0)
        # operand bytes: resolve operand names
        args_m = re.search(rf"{op}(?:-start)?\(([^)]*)\)", rhs)
        obytes = 0
        if args_m:
            for a in args_m.group(1).split(","):
                a = a.strip()
                if not a:
                    continue
                # operands may be typed ("f32[128] %name") or bare ("%name")
                a = a.split()[-1].lstrip("%")
                obytes += result_bytes.get(a, 0)
        n = _group_size(line, default_group)
        frac = (n - 1) / n if n > 1 else 0.0
        if op == "all-gather":
            wire = rbytes * frac
        elif op == "all-reduce":
            wire = 2 * obytes * frac
        elif op in ("reduce-scatter", "all-to-all"):
            wire = obytes * frac
        else:  # collective-permute
            wire = obytes
        s = stats[op]
        s["count"] += 1
        s["result_bytes"] += rbytes
        s["operand_bytes"] += obytes
        s["wire_bytes"] += wire
    return dict(stats)


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["wire_bytes"] for s in stats.values())
