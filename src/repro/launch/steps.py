"""Step builders (train / prefill / decode) and abstract input specs for the
multi-pod dry-run. All functions are pure and jit-friendly; the dry-run
lowers them with ShapeDtypeStruct stand-ins (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import abstractify
from repro.optim import adamw as opt_lib


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def build_grad_fn(cfg: ModelConfig, run: RunConfig):
    """(params, batch) -> (loss, metrics, grads), with microbatch gradient
    accumulation under a scan when ``run.microbatch > 0`` (the paper's X_mini
    knob). Shared by :func:`build_train_step` and the explicit data-parallel
    trainer (repro.distributed.trainer), which calls it per device shard
    inside shard_map."""

    if run.bf16_grads:
        # mixed precision: differentiate wrt the bf16 compute params so the
        # data-axis gradient sync moves half the wire bytes; the optimizer
        # still applies them to the fp32 master (cast in apply_updates)
        def _loss_bf16(p, b):
            return M.loss_fn(M.cast_params(p, cfg), b, cfg, run)
        grad_fn = jax.value_and_grad(_loss_bf16, has_aux=True)
    else:
        grad_fn = jax.value_and_grad(
            lambda p, b: M.loss_fn(p, b, cfg, run), has_aux=True
        )

    def grads_of(params, batch):
        if run.microbatch:
            B = batch["tokens"].shape[0]
            n = max(B // run.microbatch, 1)

            def reshape(x):
                return x.reshape((n, B // n) + x.shape[1:])

            micro = jax.tree_util.tree_map(reshape, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, _), g = grad_fn(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
            return lsum / n, {}, grads
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    return grads_of


def build_train_step(cfg: ModelConfig, run: RunConfig, opt: opt_lib.OptConfig,
                     *, grad_sync=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_sync`` (optional) is applied to the gradient pytree between the
    backward pass and the optimizer update — the hook through which a
    resolved ``Plan.sync_schedule`` strategy (repro.distributed) runs its
    collectives when the step executes under shard_map."""

    grads_of = build_grad_fn(cfg, run)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if grad_sync is not None:
            grads = grad_sync(grads)
        if run.grad_shardings is not None:
            # land grads directly on the ZeRO-1 optimizer-state layout: the
            # data-axis gradient sum becomes a reduce-scatter (1x wire)
            # instead of an all-reduce (2x wire)
            grads = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads, run.grad_shardings)
        new_params, new_state, gnorm = opt_lib.apply_updates(
            opt, params, grads, opt_state)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return new_params, new_state, out_metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, run: RunConfig):
    def prefill_step(params, batch):
        logits, caches, _ = M.forward(params, batch, cfg, run, with_cache=True)
        return logits[:, -1:], caches

    return prefill_step


def build_decode_step(cfg: ModelConfig, run: RunConfig):
    def decode_step(params, tokens, pos, caches):
        return M.decode_step(params, tokens, pos, caches, cfg, run)

    return decode_step


# ---------------------------------------------------------------------------
# Abstract input specs (dry-run)
# ---------------------------------------------------------------------------


def token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.num_codebooks:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                rules: Optional[Dict[str, Any]] = None,
                kv_quant: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable) for every
    model input of the given (arch × input-shape) pair."""
    if rules is None:
        rules = mesh_lib.sharding_rules(mesh, cfg, shape)
    bsh = mesh_lib.batch_sharding(mesh, shape)
    bspec = bsh.spec

    def tok_struct(batch, seq):
        return jax.ShapeDtypeStruct(
            token_shape(cfg, batch, seq), jnp.int32,
            sharding=NamedSharding(mesh, P(*(tuple(bspec) + (None,) * (
                len(token_shape(cfg, batch, seq)) - 1)))),
        )

    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        text_len = S - (cfg.num_image_tokens or 0)
        specs: Dict[str, Any] = {"tokens": tok_struct(B, text_len)}
        if cfg.num_image_tokens:
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, P(*(tuple(bspec) + (None, None)))),
            )
        if shape.kind == "train":
            specs["labels"] = tok_struct(B, text_len)
        return specs

    # decode: one new token + caches of seq_len
    specs = {
        "tokens": tok_struct(B, 1),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32, sharding=bsh),
        "caches": abstractify(M.cache_specs(cfg, B, S, kv_quant=kv_quant),
                              mesh, rules),
    }
    return specs


def abstract_params(cfg: ModelConfig, mesh, rules, dtype: Optional[str] = None):
    return abstractify(M.model_specs(cfg), mesh, rules, dtype_override=dtype)


def abstract_opt_state(cfg: ModelConfig, mesh, rules, opt: opt_lib.OptConfig):
    """Optimizer state: ZeRO-1 — always FSDP-sharded over the data axes."""
    zrules = dict(rules)
    zrules["embed"] = mesh_lib.dp_axes(mesh)
    tree = abstractify(M.model_specs(cfg), mesh, zrules)
    state: Dict[str, Any] = {
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P())),
    }
    if opt.kind == "adamw":
        state["m"] = tree
        state["v"] = jax.tree_util.tree_map(lambda x: x, tree)
    elif opt.kind == "momentum":
        state["m"] = tree
    return state
