"""Production meshes and logical-axis sharding rules.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) > n:  # e.g. 512 placeholder devices, single-pod mesh
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def sharding_rules(mesh, cfg: ModelConfig, shape: Optional[ShapeConfig] = None,
                   *, fsdp: bool = False) -> Dict[str, object]:
    """Map logical parameter/cache axes onto mesh axes.

    TP ("model"): heads / ff / experts / d_inner / vocab.  FSDP adds the
    data-parallel axes on the ``embed`` dim (per-layer all-gather under the
    layer scan).  KV caches: batch on data axes, sequence on "model" — and on
    (data+model) when the batch cannot cover the data axes (long_500k, B=1).
    """
    dp = dp_axes(mesh)
    batch_rule: object = dp
    kv_seq_rule: object = ("model",)
    if shape is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if shape.global_batch < dp_size:
            batch_rule = None
            kv_seq_rule = dp + ("model",)
    tp = mesh.shape["model"]
    # Archs whose head count is not divisible by TP (llava/arctic: 56 heads,
    # minicpm3: 40) fall back to replicated attention projections — a known
    # baseline inefficiency; the head-padding optimization in §Perf fixes it.
    heads_ok = cfg.num_heads == 0 or cfg.num_heads % tp == 0
    rules: Dict[str, object] = {
        "vocab": "model",
        "q_heads": "model" if heads_ok else None,
        "kv_heads": None,  # kv_heads (<=16) replicated; Q/O carry the TP split
        "ff": "model",
        "experts": "model",
        "inner": "model",
        "ssm_heads": "model",
        "conv_ch": "model",
        "lora": None,
        "embed": dp if fsdp else None,
        "layers": None,
        "batch": batch_rule,
        "kv_seq": kv_seq_rule,
    }
    return rules


def act_sharding(mesh, shape: Optional[ShapeConfig] = None,
                 *, seq_parallel: bool = True):
    """Residual-stream (B, S, D) sharding constraint."""
    dp = dp_axes(mesh)
    batch: object = dp
    if shape is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if shape.global_batch < dp_size:
            batch = None
    return NamedSharding(mesh, P(batch, "model" if seq_parallel else None, None))


def batch_sharding(mesh, shape: Optional[ShapeConfig] = None):
    dp = dp_axes(mesh)
    batch: object = dp
    if shape is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if shape.global_batch < dp_size:
            batch = None
    return NamedSharding(mesh, P(batch))
