"""Serving launcher — a thin CLI over the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
        [--continuous | --static] [--requests 6] [--n-new 16] \
        [--s-max 256] [--kv-block 16] [--max-kv-blocks 0] \
        [--prefill-chunk 0] [--arrival-trace poisson:0.5] \
        [--slo-ms 0] [--report-out PATH]

Flags map onto a :class:`repro.api.JobSpec`; generation happens inside
:meth:`repro.api.Session.serve` — continuous (in-flight batching over the
paged KV cache, the default) or static (FIFO Engine/BatchScheduler).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.api import JobSpec, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--continuous", dest="mode", action="store_const",
                      const="continuous", default="continuous",
                      help="in-flight batching over the paged KV cache "
                           "(default)")
    mode.add_argument("--static", dest="mode", action="store_const",
                      const="static",
                      help="FIFO BatchScheduler with a linear cache")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="paged-KV block size [tokens]")
    ap.add_argument("--max-kv-blocks", type=int, default=0,
                    help="KV pool cap; 0 = derive from the Eq. 5 analogue")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size; 0 = whole-prompt")
    ap.add_argument("--arrival-trace", default="",
                    help="arrival spec: '' | poisson:RATE | burst:NxGAP "
                         "(repro.serve.arrivals)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="latency SLO for the replica lemma; 0 = 2x the "
                         "measured mean")
    ap.add_argument("--report-out", default="",
                    help="write the unified Report JSON here")
    ap.add_argument("--trace-dir", default="",
                    help="write a Chrome-trace JSON of the run here "
                         "(open in chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-json", default="",
                    help="write the run's metrics/v1 section (repro.obs) "
                         "to this path")
    args = ap.parse_args()

    spec = JobSpec(arch=args.arch, reduced=True, shape="decode_32k",
                   requests=args.requests, n_new=args.n_new,
                   s_max=args.s_max, max_batch=args.max_batch,
                   serve_mode=args.mode, kv_block=args.kv_block,
                   max_kv_blocks=args.max_kv_blocks,
                   prefill_chunk=args.prefill_chunk,
                   arrival=args.arrival_trace, slo_ms=args.slo_ms,
                   trace_dir=args.trace_dir)
    rep = Session(spec).serve()
    m = rep.measured
    for r in m["per_request"]:
        print(f"req {r['rid']}: {r['tokens']} tokens, head={r['head']}")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(m["metrics"], indent=2))
        print(f"wrote metrics {p}")
    if "trace_file" in rep.meta:
        print(f"wrote trace {rep.meta['trace_file']} "
              f"({rep.meta['trace_events']} events)")
    if args.report_out:
        print(f"wrote {rep.save(args.report_out)}")
    # machine-parseable summary line (tools/bench_trajectory.py reads it)
    hists = m["metrics"]["histograms"]
    sv = m["serving"]
    summary = {
        "kind": "serve",
        "mode": sv["mode"],
        "requests": m["requests"],
        "n_tokens": m["n_tokens"],
        "wall_s": m["wall_s"],
        "tokens_per_s": m["tokens_per_s"],
        "decode_p99_s": hists.get("serve/decode_s", {}).get("p99", 0.0),
        "prefill_p99_s": hists.get("serve/prefill_s", {}).get("p99", 0.0),
        "latency_p99_s": sv["latency_s"]["p99"],
        "queue_depth_p99": hists.get("serve/queue_depth", {}).get("p99", 0.0),
        "wasted_decode_steps": sv["throughput"]["wasted_decode_steps"],
        "kv_peak_occupancy": sv["kv_cache"]["peak_occupancy"],
        "slo_s": sv["slo"]["slo_s"],
        "slo_attained": sv["slo"]["attained"],
        "replicas_predicted": sv["replica_lemma"]["predicted"]["replicas"],
    }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
