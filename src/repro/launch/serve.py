"""Serving launcher: batched generation through the Engine/BatchScheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
        [--requests 6] [--n-new 16] [--s-max 256]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config
from repro.models.blocks import RunConfig
from repro.serve.engine import BatchScheduler, Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    run = RunConfig(attn_impl="dense", remat="none")
    eng = Engine(cfg, run, s_max=args.s_max)
    sched = BatchScheduler(eng, max_batch=args.max_batch)

    rng = np.random.default_rng(0)
    k = cfg.num_codebooks
    for i in range(args.requests):
        n = int(rng.integers(8, 48))
        shape = (n, k) if k else (n,)
        sched.submit(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                     args.n_new)
    results = sched.run()
    for rid in sorted(results):
        toks = results[rid]
        head = toks[:8].tolist() if toks.ndim == 1 else toks[:2].tolist()
        print(f"req {rid}: {len(toks)} tokens, head={head}")


if __name__ == "__main__":
    main()
