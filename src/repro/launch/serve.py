"""Serving launcher — a thin CLI over the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
        [--requests 6] [--n-new 16] [--s-max 256] [--report-out PATH]

Flags map onto a :class:`repro.api.JobSpec`; batched generation through the
Engine/BatchScheduler happens inside :meth:`repro.api.Session.serve`.
"""
from __future__ import annotations

import argparse

from repro.api import JobSpec, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--report-out", default="",
                    help="write the unified Report JSON here")
    args = ap.parse_args()

    spec = JobSpec(arch=args.arch, reduced=True, shape="decode_32k",
                   requests=args.requests, n_new=args.n_new,
                   s_max=args.s_max, max_batch=args.max_batch)
    rep = Session(spec).serve()
    for r in rep.measured["per_request"]:
        print(f"req {r['rid']}: {r['tokens']} tokens, head={r['head']}")
    print(f"{rep.measured['n_tokens']} tokens in "
          f"{rep.measured['wall_s']*1e3:.0f} ms "
          f"({rep.measured['tokens_per_s']:.1f} tok/s)")
    if args.report_out:
        print(f"wrote {rep.save(args.report_out)}")


if __name__ == "__main__":
    main()
