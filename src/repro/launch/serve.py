"""Serving launcher — a thin CLI over the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b \
        [--requests 6] [--n-new 16] [--s-max 256] [--report-out PATH]

Flags map onto a :class:`repro.api.JobSpec`; batched generation through the
Engine/BatchScheduler happens inside :meth:`repro.api.Session.serve`.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.api import JobSpec, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--report-out", default="",
                    help="write the unified Report JSON here")
    ap.add_argument("--trace-dir", default="",
                    help="write a Chrome-trace JSON of the run here "
                         "(open in chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-json", default="",
                    help="write the run's metrics/v1 section (repro.obs) "
                         "to this path")
    args = ap.parse_args()

    spec = JobSpec(arch=args.arch, reduced=True, shape="decode_32k",
                   requests=args.requests, n_new=args.n_new,
                   s_max=args.s_max, max_batch=args.max_batch,
                   trace_dir=args.trace_dir)
    rep = Session(spec).serve()
    m = rep.measured
    for r in m["per_request"]:
        print(f"req {r['rid']}: {r['tokens']} tokens, head={r['head']}")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(m["metrics"], indent=2))
        print(f"wrote metrics {p}")
    if "trace_file" in rep.meta:
        print(f"wrote trace {rep.meta['trace_file']} "
              f"({rep.meta['trace_events']} events)")
    if args.report_out:
        print(f"wrote {rep.save(args.report_out)}")
    # machine-parseable summary line (tools/bench_trajectory.py reads it)
    hists = m["metrics"]["histograms"]
    summary = {
        "kind": "serve",
        "requests": m["requests"],
        "n_tokens": m["n_tokens"],
        "wall_s": m["wall_s"],
        "tokens_per_s": m["tokens_per_s"],
        "decode_p99_s": hists.get("serve/decode_s", {}).get("p99", 0.0),
        "prefill_p99_s": hists.get("serve/prefill_s", {}).get("p99", 0.0),
    }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
