"""Training launcher — a thin CLI over the ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        [--reduced | --full] [--steps 100] [--batch 8] [--seq 128] [--plan] \
        [--dp 8 [--sync all_reduce|reduce_scatter_all_gather|parameter_server|auto]
               [--compress none|bf16|int8|topk]] [--report-out PATH]

Flags map 1:1 onto a :class:`repro.api.JobSpec`; the actual procedure
(planner resolution, strategy sizing, the loop) lives in
:class:`repro.api.Session`.  On this CPU container ``--reduced`` (the
smoke-scale family member, the default) is the realistic setting; disable it
with ``--full`` (or ``--no-reduced``).  With ``--plan`` the session adopts
the planner's runtime knobs (microbatch / attention impl / remat /
optimizer).  ``--dp N`` switches to the explicit data-parallel trainer: set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so the data axis has
real (simulated) devices; ``--sync auto`` resolves the planner's
``Plan.sync_schedule`` to a runnable strategy.  ``--autotune`` runs the
closed-loop autotuner first (``Session.tune``: measured kernel-variant
choice + hardware calibration, see ``docs/tuning_guide.md``) and adopts its
knobs; the calibration persists in ``--tune-cache``.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.api import JobSpec, Session


def build_spec(args) -> JobSpec:
    return JobSpec(
        arch=args.arch, reduced=args.reduced, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        use_planner=args.plan, dp=args.dp, pipe=args.pipe,
        n_microbatch=args.microbatch, sync=args.sync,
        compress=args.compress, topology=args.topology,
        sync_overlap=args.overlap, bucket_mb=args.bucket_mb,
        staleness=args.staleness, backup_workers=args.backup_workers,
        tune=args.autotune, tune_cache=args.tune_cache,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every or (50 if args.ckpt_dir else 0),
        trace_dir=getattr(args, "trace_dir", ""))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="train the reduced family member (default); "
                         "--full / --no-reduced for the full config")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="alias for --no-reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--plan", action="store_true",
                    help="consult the paper-planner for runtime knobs")
    ap.add_argument("--ckpt-dir", default="",
                    help="elastic checkpoint directory: async atomic saves "
                         "every --ckpt-every steps, auto-resume from the "
                         "latest complete step on restart")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint period in steps (0 = 50 when "
                         "--ckpt-dir is set)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="bounded-staleness async PS: max worker params age "
                         "in steps (0 = synchronous; needs --dp)")
    ap.add_argument("--backup-workers", type=int, default=0,
                    help="drop the slowest k of dp gradients per step "
                         "(0 = wait for every worker; needs --dp)")
    ap.add_argument("--dp", type=int, default=0,
                    help="run the explicit data-parallel trainer on this many "
                         "devices (0 = single-process GSPMD loop)")
    ap.add_argument("--pipe", type=int, default=0,
                    help="1F1B pipeline stages (devices split pipe x data; "
                         "0/1 = no pipelining). With --dp N, N is the total "
                         "device count of the (pipe, data) grid")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="1F1B microbatches per step (>= --pipe; 0 = pipe)")
    ap.add_argument("--sync", default="auto",
                    help="gradient-sync strategy, or 'auto' to resolve the "
                         "planner's sync_schedule")
    ap.add_argument("--compress", default="none",
                    help="gradient compression: none|bf16|int8|topk")
    ap.add_argument("--overlap", dest="overlap",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="bucketed comm/compute overlap: hide gradient sync "
                         "under the backward pass (repro.distributed.overlap)"
                         " and price the plan with the overlap-aware model")
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="sync-bucket size target in MiB for --overlap "
                         "(0 = default)")
    ap.add_argument("--topology", default="",
                    help="named cluster topology (repro.core.hardware."
                         "CLUSTERS, e.g. 2x4); empty = flat mesh")
    ap.add_argument("--autotune", action="store_true",
                    help="run the closed-loop autotuner first (measure "
                         "kernel variants + calibrate the hardware "
                         "constants) and adopt its knobs for the run")
    ap.add_argument("--tune-cache", default="results/calibration_cache.json",
                    help="calibration-cache JSON for --autotune "
                         "('' disables persistence)")
    ap.add_argument("--report-out", default="",
                    help="write the unified Report JSON here")
    ap.add_argument("--trace-dir", default="",
                    help="write a Chrome-trace JSON of the run here "
                         "(open in chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-json", default="",
                    help="write the run's metrics/v1 section (repro.obs) "
                         "to this path")
    return ap


def main():
    args = build_parser().parse_args()
    sess = Session(build_spec(args))
    if args.plan:
        print("planner:", sess.resolved_plan)
    cfg = sess.cfg
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'FULL'}) "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    if args.dp and args.sync == "auto":
        print(f"sync resolved from planner: "
              f"{sess.resolved_plan.sync_schedule}")

    if args.autotune:
        t = sess.tuned
        r = t.replan
        print(f"autotune: minibatch*={t.chosen_minibatch} (m_bound), "
              f"microbatch*={t.chosen_microbatch}, attn={t.attn_impl()}; "
              f"step predicted {r['est_step_time_calibrated_s']*1e3:.1f}ms "
              f"calibrated vs {r['est_step_time_uncalibrated_s']*1e3:.3g}ms "
              f"datasheet (measured {r['measured_step_s']*1e3:.1f}ms)")

    rep = sess.train()
    if "sync" in rep.measured:
        print("sync report:", json.dumps(rep.measured["sync"], indent=2,
                                         default=str))
        s = rep.measured["sync"]
        if s.get("sync_overlap"):
            print(f"overlap: {s['n_buckets']} buckets hide "
                  f"{s['overlap_fraction']:.0%} of sync "
                  f"(exposed {s['exposed_comm_time']*1e3:.1f}ms of "
                  f"{s['measured_comm_s']*1e3:.1f}ms serial)")
    if "async_ps" in rep.measured:
        a = rep.measured["async_ps"]
        print(f"async PS: staleness={a['staleness']} "
              f"(age mean {a['mean_age']:.2f} / max {a['max_age']}), "
              f"backup_workers={a['backup_workers']} "
              f"({a['drops']} grads dropped), "
              f"pull amortized 1/{a['staleness'] + 1}; model wall step "
              f"{a['t_step_model']['wall_step']*1e3:.3g}ms at "
              f"{a['t_step_model']['efficiency']:.0%} statistical "
              f"efficiency")
    if "pipeline" in rep.measured:
        pr = rep.measured["pipeline"]
        print(f"pipeline: {pr['pipe']} stages x {pr['n_microbatch']} "
              f"microbatches, bubble measured {pr['bubble_measured']:.3f} "
              f"vs model {pr['bubble_model']:.3f} "
              f"(serial {pr['bubble_serial']:.3f})")
    m = rep.measured
    losses = m["losses"]
    print(f"loss {np.mean(losses[:5]):.4f} -> {np.mean(losses[-5:]):.4f}; "
          f"{m['tokens_per_s']:,.0f} tok/s; R_O={m['r_o']:.4f}")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(m["metrics"], indent=2))
        print(f"wrote metrics {p}")
    if "trace_file" in rep.meta:
        print(f"wrote trace {rep.meta['trace_file']} "
              f"({rep.meta['trace_events']} events)")
    if args.report_out:
        path = rep.save(args.report_out)
        print(f"wrote {path}")
    # machine-parseable summary line (tools/bench_trajectory.py reads it)
    summary = {
        "kind": "train",
        "loss_first": float(np.mean(losses[:5])),
        "loss_last": float(np.mean(losses[-5:])),
        "tokens_per_s": m["tokens_per_s"],
        "r_o": m["r_o"],
        "step_time_s": m["step_times_mean"].get("compute", 0.0)
        + m["step_times_mean"].get("dist_update", 0.0)
        + m["step_times_mean"].get("param_update", 0.0),
    }
    if "sync" in m and m["sync"].get("sync_overlap"):
        summary["overlap_fraction"] = m["sync"]["overlap_fraction"]
    if "async_ps" in m:
        summary["staleness"] = m["async_ps"]["staleness"]
        summary["backup_workers"] = m["async_ps"]["backup_workers"]
        summary["mean_age"] = m["async_ps"]["mean_age"]
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
