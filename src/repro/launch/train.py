"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        [--reduced] [--steps 100] [--batch 8] [--seq 128] [--plan] \
        [--dp 8 [--sync all_reduce|reduce_scatter_all_gather|parameter_server|auto]
               [--compress none|bf16|int8|topk]]

On this CPU container ``--reduced`` (the smoke-scale family member) is the
realistic setting; the full configs are exercised through the dry-run. With
``--plan`` the launcher first prints the planner's recommendation and adopts
its runtime knobs (microbatch / attention impl / remat / optimizer).

``--dp N`` switches to the explicit data-parallel trainer
(repro.distributed): set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
so the data axis has real (simulated) devices, pick a sync strategy
(``--sync auto`` resolves the planner's ``Plan.sync_schedule`` to a runnable
strategy), and a measured-vs-Lemma-3.2 report is printed after training.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import get_config, get_shape, ShapeConfig
from repro.core.planner import plan as plan_fn
from repro.models.blocks import RunConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--plan", action="store_true",
                    help="consult the paper-planner for runtime knobs")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--dp", type=int, default=0,
                    help="run the explicit data-parallel trainer on this many "
                         "devices (0 = single-process GSPMD loop)")
    ap.add_argument("--sync", default="auto",
                    help="gradient-sync strategy, or 'auto' to resolve the "
                         "planner's sync_schedule")
    ap.add_argument("--compress", default="none",
                    help="gradient compression: none|bf16|int8|topk")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    run = RunConfig(attn_impl="auto", remat="block")
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps)
    if args.plan:
        p = plan_fn(cfg, get_shape("train_4k"))
        print("planner:", p)
        run = RunConfig(attn_impl="dense" if p.attn_impl == "dense" else "auto",
                        remat=p.remat, microbatch=min(p.microbatch, args.batch))
        opt = OptConfig(kind=p.opt_kind, lr=args.lr,
                        warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'FULL'}) "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")

    if args.dp:
        from repro.distributed import DataParallelTrainer

        import jax
        devs = jax.devices()
        if len(devs) < args.dp:
            raise SystemExit(
                f"--dp {args.dp} but only {len(devs)} devices; set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={args.dp}")
        if args.sync == "auto":
            strategy = plan_fn(cfg if not args.reduced else get_config(args.arch),
                               get_shape("train_4k")).resolve_sync()
            print(f"sync resolved from planner: {strategy.name}")
        else:
            strategy = args.sync
        trainer = DataParallelTrainer(
            cfg, run, opt, strategy=strategy, compression=args.compress,
            devices=devs[:args.dp])
        res = trainer.train(batch=args.batch, seq=args.seq, steps=args.steps,
                            ckpt_dir=args.ckpt_dir or None,
                            ckpt_every=50 if args.ckpt_dir else 0)
        rep = trainer.report()
        print("sync report:", json.dumps(rep.as_dict(), indent=2, default=str))
    else:
        res = train(cfg, run, opt, batch=args.batch, seq=args.seq,
                    steps=args.steps, ckpt_dir=args.ckpt_dir or None,
                    ckpt_every=50 if args.ckpt_dir else 0)
    print(f"loss {np.mean(res.losses[:5]):.4f} -> {np.mean(res.losses[-5:]):.4f}; "
          f"{res.tokens_per_s:,.0f} tok/s; R_O={res.mean_r_o:.4f}")


if __name__ == "__main__":
    main()
