"""Serving engine: batched prefill -> cached decode, with fixed-size cache
buffers (linear for full attention, ring for sliding-window slots) and a
simple continuous-batch scheduler.

Right-padded prompts + per-example ``pos`` masking means ragged batches
share one prefill; the decode loop is one jitted step per token across the
whole batch (the decode_32k / long_500k shapes lower exactly this step).

Telemetry (``repro.obs``): ``prefill`` and ``decode`` are tracer spans
whose wall clocks ARE the ``GenResult`` timings (no second clock), and the
engine/scheduler publish the serving family into a ``MetricsRegistry`` —
``serve/prefill_s`` / ``serve/decode_s`` / ``serve/decode_token_s``
latency histograms, ``serve/tokens`` counters, ``serve/queue_depth`` and
``serve/batch_size`` scheduler histograms — rendered by ``Session.serve``
into the Report's ``metrics/v1`` section.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SlotSpec
from repro.models import model as M
from repro.models.attention import _window_for
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.obs import MetricsRegistry, Tracer


def _pad_to(x, size: int, axis: int):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def place_prefill_cache(cfg: ModelConfig, caches, s_max: int, prompt_len: int,
                        *, ring: bool = True):
    """Fit the prefill caches (length = prompt_len) into the allocated
    buffers: pad linear caches to s_max; fold SWA caches into their ring.

    ``ring=False`` keeps every sequence cache linear (position i at slot i)
    even for sliding-window slots — the layout the paged KV cache pages in
    fixed-size blocks; window masking still bounds what decode attends to.
    """

    def place_slot(slot: SlotSpec, cache):
        if slot.mixer == "mamba":
            return {"state": cache["state"].astype(jnp.bfloat16),
                    "conv": cache["conv"].astype(jnp.bfloat16)}
        window = _window_for(cfg, slot.mixer)
        use_ring = ring and bool(window) and window < s_max
        out = {}
        for name, arr in cache.items():  # arr (cycles, B, S, ...)
            arr = arr.astype(jnp.bfloat16)
            if not use_ring:
                out[name] = _pad_to(arr, s_max, axis=2)
                continue
            size = min(s_max, window)
            buf = jnp.zeros(arr.shape[:2] + (size,) + arr.shape[3:], arr.dtype)
            n = min(prompt_len, size)
            positions = np.arange(prompt_len - n, prompt_len)
            slots = positions % size
            buf = buf.at[:, :, slots].set(arr[:, :, positions])
            out[name] = buf
        return out

    placed: Dict[str, Any] = {"slots": {}}
    for i, slot in enumerate(cfg.pattern):
        placed["slots"][f"slot{i}"] = place_slot(slot, caches["slots"][f"slot{i}"])
    if cfg.first_k_dense:
        pre = SlotSpec(cfg.pattern[0].mixer, "dense")
        placed["prelude"] = place_slot(pre, caches["prelude"])
    return placed


@dataclass
class GenResult:
    tokens: np.ndarray  # (B, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float

    def stats(self) -> Dict[str, float]:
        """Measured serving numbers for a ``repro.api.Report``."""
        return {"batch": int(self.tokens.shape[0]),
                "n_new": int(self.tokens.shape[1]),
                "prefill_s": float(self.prefill_s),
                "decode_s": float(self.decode_s),
                "tokens_per_s": float(self.tokens_per_s)}


class Engine:
    def __init__(self, cfg: ModelConfig, run: RunConfig, params=None, *,
                 s_max: int = 512, seed: int = 0,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.run = run
        self.s_max = s_max
        # GenResult timings come FROM the tracer's spans, so the engine
        # always times against an *enabled* tracer — a disabled one would
        # zero prefill_s/decode_s, so it is substituted by a private live
        # clock (events then go nowhere)
        self.tracer = (tracer if tracer is not None and tracer.enabled
                       else Tracer(enabled=True))
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if params is None:
            params = materialize(M.model_specs(cfg), jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(
            lambda p, b: M.forward(p, b, cfg, run, with_cache=True))
        self._decode = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))

    def _sample(self, logits, greedy: bool, key):
        lg = logits[:, -1]
        if self.cfg.num_codebooks:
            ids = jnp.argmax(lg, axis=-1)  # (B, K)
            return ids.astype(jnp.int32)
        if greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg.astype(jnp.float32)).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_new: int, *, greedy: bool = True,
                 lengths: Optional[np.ndarray] = None,
                 image_embeds: Optional[np.ndarray] = None,
                 seed: int = 0) -> GenResult:
        """prompts (B, S_prompt[, K]) right-padded; lengths (B,) true lens."""
        cfg = self.cfg
        B, S_prompt = prompts.shape[:2]
        if lengths is None:
            lengths = np.full((B,), S_prompt, np.int32)
        n_img = cfg.num_image_tokens if image_embeds is not None else 0

        with self.tracer.span("prefill", batch=B, prompt_len=S_prompt) as sp_p:
            batch = {"tokens": jnp.asarray(prompts)}
            if image_embeds is not None:
                batch["image_embeds"] = jnp.asarray(image_embeds)
            logits, caches, _ = self._prefill(self.params, batch)
            caches = place_prefill_cache(cfg, caches, self.s_max,
                                         S_prompt + n_img)
            # next-token logits at each example's true last position
            idx = jnp.asarray(lengths - 1 + n_img)
            last_logits = jnp.take_along_axis(
                logits, idx.reshape((B, 1) + (1,) * (logits.ndim - 2)), axis=1)
            jax.block_until_ready(last_logits)
        t_prefill = sp_p.elapsed_s

        key = jax.random.PRNGKey(seed)
        pos = jnp.asarray(lengths + n_img, jnp.int32)  # next position to write
        tok = self._sample(last_logits, greedy, key)
        out = [np.asarray(tok)]
        with self.tracer.span("decode", batch=B, n_new=n_new) as sp_d:
            for i in range(n_new - 1):
                key = jax.random.fold_in(key, i)
                tk = tok[:, None] if not cfg.num_codebooks else tok[:, None, :]
                logits, caches = self._decode(self.params, tk, pos, caches)
                tok = self._sample(logits, greedy, key)
                out.append(np.asarray(tok))
                pos = pos + 1
            jax.block_until_ready(tok)
        t_decode = sp_d.elapsed_s
        tokens = np.stack(out, axis=1)
        tps = B * n_new / max(t_prefill + t_decode, 1e-9)
        m = self.metrics
        m.observe("serve/prefill_s", t_prefill)
        m.observe("serve/decode_s", t_decode)
        if n_new > 1:
            m.observe("serve/decode_token_s", t_decode / (n_new - 1))
        m.inc("serve/tokens", B * n_new)
        # decode *work* performed: every row runs n_new token steps whether
        # the request wanted them or not — the continuous scheduler's
        # regression tests compare this against sum(n_new)
        m.inc("serve/decode_token_steps", B * n_new)
        m.inc("serve/generate_calls")
        m.set_gauge("serve/tokens_per_s", tps)
        return GenResult(tokens, t_prefill, t_decode, tps)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    n_new: int


class BatchScheduler:
    """Groups pending requests into fixed-size batches (padding ragged
    prompts) and runs them through one Engine — the paper's throughput-
    oriented batching guidance applied to serving."""

    def __init__(self, engine: Engine, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self.pending: List[Request] = []
        self._next_id = 0
        self.history: List[GenResult] = []  # per-batch stats of the last run()
        self.stats: Dict[str, Any] = {}  # decode-work accounting of last run()
        self.latencies: Dict[int, float] = {}  # rid -> completion latency [s]

    def submit(self, prompt: np.ndarray, n_new: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.pending.append(Request(rid, prompt, n_new))
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        results: Dict[int, np.ndarray] = {}
        self.history = []
        self.latencies = {}
        m = self.engine.metrics
        tracer = self.engine.tracer
        b_idx = 0
        t_run = 0.0  # cumulative batch wall — each batch waits on the prior
        computed = delivered = engine_steps = 0
        while self.pending:
            m.observe("serve/queue_depth", len(self.pending))
            batch = self.pending[: self.max_batch]
            self.pending = self.pending[self.max_batch :]
            max_len = max(r.prompt.shape[0] for r in batch)
            n_new = max(r.n_new for r in batch)
            k = self.engine.cfg.num_codebooks
            shape = (len(batch), max_len) + ((k,) if k else ())
            prompts = np.zeros(shape, np.int32)
            lengths = np.zeros((len(batch),), np.int32)
            for i, r in enumerate(batch):
                prompts[i, : r.prompt.shape[0]] = r.prompt
                lengths[i] = r.prompt.shape[0]
            with tracer.span("serve_batch", batch_index=b_idx,
                             size=len(batch)):
                res = self.engine.generate(prompts, n_new, lengths=lengths)
            b_idx += 1
            m.observe("serve/batch_size", len(batch))
            m.inc("serve/requests", len(batch))
            self.history.append(res)
            t_run += res.prefill_s + res.decode_s
            computed += len(batch) * n_new
            delivered += sum(r.n_new for r in batch)
            engine_steps += n_new
            for i, r in enumerate(batch):
                results[r.rid] = res.tokens[i, : r.n_new]
                self.latencies[r.rid] = t_run  # whole batch retires together
        wasted = computed - delivered
        m.inc("serve/wasted_decode_steps", wasted)
        self.stats = {"decode_token_steps": computed,
                      "delivered_tokens": delivered,
                      "wasted_decode_steps": wasted,
                      "engine_steps": engine_steps}
        return results
