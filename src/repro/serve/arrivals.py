"""Seeded arrival-trace generators for the serving scheduler.

A trace is a list of non-negative integer arrival times on the scheduler's
*virtual step clock* (one tick per engine decode step), so replayed load is
bit-for-bit deterministic in CI regardless of wall-clock jitter — the first
step toward the ROADMAP trace-driven-campaigns item.

Trace specs (the ``JobSpec.arrival`` / ``--arrival-trace`` mini-language):

* ``""``               — all requests queued at step 0 (the static case)
* ``"poisson:<rate>"`` — Poisson process with ``rate`` arrivals per step
* ``"burst:<n>x<gap>"``— bursts of ``n`` back-to-back, ``gap`` steps apart
"""
from __future__ import annotations

from typing import List

import numpy as np


def poisson_trace(n: int, rate: float, *, seed: int = 0) -> List[int]:
    """Arrival steps of a Poisson process with ``rate`` arrivals/step."""
    if rate <= 0:
        raise ValueError(f"poisson rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def burst_trace(n: int, burst: int, gap: int, *, seed: int = 0) -> List[int]:
    """``burst`` simultaneous arrivals every ``gap`` steps."""
    del seed  # deterministic by construction; kept for interface symmetry
    if burst <= 0 or gap < 0:
        raise ValueError(f"burst size must be > 0 and gap >= 0, "
                         f"got {burst}x{gap}")
    return [(i // burst) * gap for i in range(n)]


def parse_trace(spec: str):
    """Validate a trace spec; returns (kind, params). Raises ValueError."""
    if not spec:
        return ("static", ())
    kind, _, rest = spec.partition(":")
    try:
        if kind == "poisson":
            rate = float(rest)
            if rate <= 0:
                raise ValueError
            return ("poisson", (rate,))
        if kind == "burst":
            burst, _, gap = rest.partition("x")
            b, g = int(burst), int(gap)
            if b <= 0 or g < 0:
                raise ValueError
            return ("burst", (b, g))
    except ValueError:
        pass
    raise ValueError(
        f"bad arrival trace spec {spec!r}; expected '', 'poisson:<rate>' "
        f"or 'burst:<n>x<gap>'")


def make_trace(spec: str, n: int, *, seed: int = 0) -> List[int]:
    """Arrival steps for ``n`` requests per the trace spec mini-language."""
    kind, params = parse_trace(spec)
    if kind == "static":
        return [0] * n
    if kind == "poisson":
        return poisson_trace(n, params[0], seed=seed)
    return burst_trace(n, params[0], params[1], seed=seed)
