"""Paged KV cache: fixed-size blocks, per-request block tables, free-list
allocation, refcounted prefix sharing.

The paper sizes training minibatches from a memory bound (Eq. 5 /
``memory_model.max_x_mini``); serving gets the same treatment by making KV
memory *enumerable*: every sequence-cache leaf (``kv_seq`` axis in
``model.cache_specs``) is stored as fixed-size blocks in a preallocated
pool, one pool per leaf, and a request owns an ordered *block table* of
pool indices.  Admission control then reduces to a free-list check against
``memory_model.max_kv_blocks`` (the Eq. 5 analogue for decode).

Pools are host-side numpy (in-place block writes; the engine moves only the
slices it touches).  Leaves without a sequence axis — Mamba recurrent state
and conv tails — are per-request constants in size, stored wholesale.

Prefix sharing: a *full* block whose cumulative token prefix matches a
published block is reference-counted instead of copied.  Shared blocks are
never written — decode positions land past the prompt, and a block is only
published once every one of its ``block_size`` positions was written by the
prompt, so a block is either fully-written-and-shareable or private.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

SEQ_AXIS = 2  # (cycles, batch, kv_seq, *tail) in every sequence-cache leaf


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(getattr(k, "key", k) for k in path)


class BlockAllocator:
    """Free-list block allocator with refcounted prefix sharing.

    Invariants the property tests pin down: every block is free or
    allocated, never both; ``free`` of an unallocated block raises; a
    shared block survives until its last owner releases it; free + used
    always equals ``n_blocks``.
    """

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        self._key_to_bid: Dict[Any, int] = {}
        self._bid_to_key: Dict[int, Any] = {}
        self.peak_used = 0
        self.shared_hits = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        bid = self._free.pop()
        self._refs[bid] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return bid

    def share(self, key) -> Optional[int]:
        """Take another reference on the published block for ``key``."""
        bid = self._key_to_bid.get(key)
        if bid is None:
            return None
        self._refs[bid] += 1
        self.shared_hits += 1
        return bid

    def lookup(self, key) -> Optional[int]:
        return self._key_to_bid.get(key)

    def publish(self, bid: int, key) -> None:
        """Register a fully-written block under its token-prefix key."""
        if bid not in self._refs:
            raise RuntimeError(f"publish of unallocated block {bid}")
        if key in self._key_to_bid:
            return  # first writer wins; the copy stays private
        self._key_to_bid[key] = bid
        self._bid_to_key[bid] = key

    def free(self, bid: int) -> None:
        refs = self._refs.get(bid)
        if refs is None:
            raise RuntimeError(f"double free of block {bid}")
        if refs > 1:
            self._refs[bid] = refs - 1
            return
        del self._refs[bid]
        key = self._bid_to_key.pop(bid, None)
        if key is not None:
            del self._key_to_bid[key]
        self._free.append(bid)

    def refcount(self, bid: int) -> int:
        return self._refs.get(bid, 0)


class PagedKVCache:
    """Block-pooled storage for every cache leaf of one model config.

    Sequence leaves ((cycles, B, kv_seq, *tail), identified by the
    ``kv_seq`` axis label in ``model.cache_specs``) are paged: pool shape
    (n_blocks, cycles, block_size, *tail).  Non-sequence leaves (Mamba
    state/conv) are stored per request.  One BlockAllocator governs all
    pools — the leaves of one request's logical block i share a block id.
    """

    def __init__(self, cfg: ModelConfig, *, block_size: int, n_blocks: int,
                 s_max: int):
        self.cfg = cfg
        self.block_size = int(block_size)
        self.s_max = int(s_max)
        self.alloc = BlockAllocator(n_blocks, block_size)

        specs = M.cache_specs(cfg, batch=1, s_max=s_max)
        self._seq_paths: List[Tuple[str, ...]] = []
        self._state_paths: List[Tuple[str, ...]] = []
        self._pools: Dict[Tuple[str, ...], np.ndarray] = {}
        self._leaf_shapes: Dict[Tuple[str, ...], tuple] = {}
        for path, spec in jax.tree_util.tree_flatten_with_path(specs)[0]:
            keys = _path_keys(path)
            self._leaf_shapes[keys] = tuple(spec.shape)
            if len(spec.axes) > SEQ_AXIS and spec.axes[SEQ_AXIS] == "kv_seq":
                self._seq_paths.append(keys)
                cycles = spec.shape[0]
                tail = tuple(spec.shape[SEQ_AXIS + 1:])
                self._pools[keys] = np.zeros(
                    (n_blocks, cycles, block_size) + tail, dtype=jnp.bfloat16)
            else:
                self._state_paths.append(keys)

        self._tables: Dict[int, List[int]] = {}
        self._private: Dict[int, List[bool]] = {}
        self._tokens: Dict[int, Tuple[int, ...]] = {}
        self._lengths: Dict[int, int] = {}
        self._states: Dict[int, Dict[Tuple[str, ...], np.ndarray]] = {}

    # -- admission ----------------------------------------------------------

    def blocks_for(self, total_len: int) -> int:
        return -(-int(total_len) // self.block_size)

    def _share_keys(self, tokens: Tuple[int, ...], total_len: int):
        """Per logical block: the prefix key if the block is fully covered
        by the prompt (shareable), else None."""
        keys = []
        for i in range(self.blocks_for(total_len)):
            end = (i + 1) * self.block_size
            keys.append(tokens[:end] if end <= len(tokens) else None)
        return keys

    def can_admit(self, tokens: np.ndarray, total_len: int) -> bool:
        if not self._seq_paths:
            return True  # pure-SSM config: per-request state only
        toks = tuple(int(t) for t in np.asarray(tokens).reshape(-1))
        need = sum(1 for k in self._share_keys(toks, total_len)
                   if k is None or self.alloc.lookup(k) is None)
        return self.alloc.can_alloc(need)

    def admit(self, rid: int, tokens: np.ndarray, total_len: int) -> None:
        """Reserve the request's whole block table (prompt + all decode
        positions) up front — admitted requests can never OOM mid-flight."""
        toks = tuple(int(t) for t in np.asarray(tokens).reshape(-1))
        table: List[int] = []
        private: List[bool] = []
        try:
            for key in self._share_keys(toks, total_len) if self._seq_paths else []:
                bid = self.alloc.share(key) if key is not None else None
                if bid is None:
                    bid = self.alloc.alloc()
                    private.append(True)
                else:
                    private.append(False)
                table.append(bid)
        except RuntimeError:
            for bid in table:
                self.alloc.free(bid)
            raise
        self._tables[rid] = table
        self._private[rid] = private
        self._tokens[rid] = toks
        self._lengths[rid] = 0
        self._states[rid] = {}

    def release(self, rid: int) -> None:
        for bid in self._tables.pop(rid):
            self.alloc.free(bid)
        self._private.pop(rid)
        self._tokens.pop(rid)
        self._lengths.pop(rid)
        self._states.pop(rid)

    # -- writes -------------------------------------------------------------

    def write_prefill(self, rid: int, caches, prompt_len: int) -> None:
        """Copy a single-request (B=1, linear, length>=prompt_len) cache
        tree into the pools; publish full private prompt blocks for prefix
        sharing.  Shared blocks already hold identical content — skipped."""
        table, private = self._tables[rid], self._private[rid]
        leaves = {_path_keys(p): np.asarray(leaf) for p, leaf in
                  jax.tree_util.tree_flatten_with_path(caches)[0]}
        bs = self.block_size
        for path in self._seq_paths:
            arr = leaves[path]  # (cycles, 1, S, *tail)
            for i in range(self.blocks_for(prompt_len)):
                if not private[i]:
                    continue
                lo, hi = i * bs, min((i + 1) * bs, prompt_len)
                self._pools[path][table[i]][:, : hi - lo] = arr[:, 0, lo:hi]
        for path in self._state_paths:
            self._states[rid][path] = leaves[path][:, 0].copy()
        toks = self._tokens[rid]
        for i in range(prompt_len // bs):
            if private[i] and (i + 1) * bs <= len(toks):
                self.alloc.publish(table[i], toks[: (i + 1) * bs])
        self._lengths[rid] = prompt_len

    def commit_token(self, rids: List[int], rows: List[int], positions,
                     caches) -> None:
        """After one decode step, persist each live row's newly written
        cache entries (sequence position ``positions[j]``; full state for
        non-sequence leaves) from the working batch cache into the pools."""
        if not rids:
            return
        bs = self.block_size
        pos = np.asarray(positions, np.int64)
        leaves = {_path_keys(p): leaf for p, leaf in
                  jax.tree_util.tree_flatten_with_path(caches)[0]}
        for path in self._seq_paths:
            vals = np.asarray(leaves[path][:, np.asarray(rows), pos])
            for j, rid in enumerate(rids):
                p = int(pos[j])
                self._pools[path][self._tables[rid][p // bs]][:, p % bs] = \
                    vals[:, j]
        for path in self._state_paths:
            vals = np.asarray(leaves[path][:, np.asarray(rows)])
            for j, rid in enumerate(rids):
                self._states[rid][path] = vals[:, j]
        for j, rid in enumerate(rids):
            self._lengths[rid] = max(self._lengths[rid], int(pos[j]) + 1)

    # -- reads --------------------------------------------------------------

    def gather_batch(self, row_rids: List[Optional[int]]):
        """Reconstruct a (cycles, len(rows), s_max, *tail) working cache
        tree from the pools — rows with ``None`` zero-filled.  The pools are
        the source of truth: this is the only way cache state enters the
        decode step after an admission reshuffles rows."""
        B = len(row_rids)
        bs = self.block_size
        out: Dict[Tuple[str, ...], np.ndarray] = {}
        for path in self._seq_paths:
            pool = self._pools[path]
            cycles, tail = pool.shape[1], pool.shape[3:]
            buf = np.zeros((cycles, B, self.s_max) + tail, pool.dtype)
            for row, rid in enumerate(row_rids):
                if rid is None:
                    continue
                table, n = self._tables[rid], self._lengths[rid]
                for i in range(self.blocks_for(n)):
                    lo, hi = i * bs, min((i + 1) * bs, n)
                    buf[:, row, lo:hi] = pool[table[i]][:, : hi - lo]
            out[path] = buf
        for path in self._state_paths:
            shape = self._leaf_shapes[path]
            buf = np.zeros((shape[0], B) + shape[2:], jnp.bfloat16)
            for row, rid in enumerate(row_rids):
                if rid is not None and path in self._states[rid]:
                    buf[:, row] = self._states[rid][path]
            out[path] = buf
        tree: Dict[str, Any] = {}
        for path, arr in out.items():
            node = tree
            for k in path[:-1]:
                node = node.setdefault(k, {})
            node[path[-1]] = jnp.asarray(arr)
        return tree

    def block_table(self, rid: int) -> np.ndarray:
        return np.asarray(self._tables[rid], np.int32)

    def seq_pool(self, path: Tuple[str, ...]) -> np.ndarray:
        return self._pools[path]

    @property
    def seq_paths(self) -> List[Tuple[str, ...]]:
        return list(self._seq_paths)

    def stats(self) -> Dict[str, Any]:
        bytes_per_block = int(sum(
            p.shape[1] * np.prod(p.shape[2:], dtype=np.int64) * p.itemsize
            for p in self._pools.values()))
        return {"block_size": self.block_size,
                "n_blocks": self.alloc.n_blocks,
                "used_blocks": self.alloc.n_used,
                "peak_blocks": self.alloc.peak_used,
                "peak_occupancy": (self.alloc.peak_used / self.alloc.n_blocks
                                   if self.alloc.n_blocks else 0.0),
                "shared_block_hits": self.alloc.shared_hits,
                "block_bytes": bytes_per_block}
