"""Continuous (in-flight) batching over a paged KV cache.

The static ``BatchScheduler`` decodes every request in a batch for
``max(n_new)`` steps and truncates afterward — wasted decode that grows
with raggedness.  This scheduler keeps a fixed-width decode batch
(``max_batch`` rows) and admits/retires *per decode step*: a request
occupies a row for exactly its own ``n_new`` steps, new requests slot into
freed rows immediately, and admission is gated by the paged-KV free list —
the Eq. 5 memory bound (``memory_model.max_kv_blocks``) instead of a
hand-tuned queue depth.

Time is a *virtual step clock* (one tick per engine step) so arrival
traces (``serve.arrivals``) replay deterministically in CI; latencies are
still measured on the wall clock via tracer spans.

Design notes:

* The paged pools are the source of truth.  Decode runs on a dense
  working cache (cycles, max_batch, s_max, ...); each step commits the
  newly written position of every live row back to the pools, and any
  admission rebuilds the working cache *from* the pools
  (``PagedKVCache.gather_batch``) — so the paged store is load-bearing on
  every request, and bf16 round-trips keep the token streams bit-identical
  to the linear-cache engine (asserted in tests).
* Prefill runs per request at batch 1 — whole-prompt, or chunked
  (``model.extend_step``) so a long prompt costs one chunk per scheduler
  tick instead of stalling admitted rows for its whole length.  Chunked
  needs an attention-only stack (``model.supports_extend``); other
  configs fall back to whole-prompt.
* Dummy rows decode a masked token-0 at position 0; their garbage cache
  writes are never committed to the pools and vanish at the next
  admission's regather.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from repro.obs.trace import monotonic
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.obs import MetricsRegistry, Tracer
from repro.serve.engine import place_prefill_cache
from repro.serve.kvcache import PagedKVCache


def _bucket(n: int, cap: int) -> int:
    """Pad prompts to power-of-two buckets to bound jit recompiles."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray  # (L,) or (L, K) int32
    n_new: int
    arrival_step: int = 0
    # runtime state
    tokens: List[np.ndarray] = field(default_factory=list)
    prefill_done: int = 0
    caches: Any = None  # B=1 private cache during (chunked) prefill
    t_arrive: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0

    @property
    def length(self) -> int:
        return int(self.prompt.shape[0])


class ContinuousEngine:
    """Model-level primitives for the continuous scheduler: per-request
    prefill (whole or chunked, batch 1) and one fixed-width decode step."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params=None, *,
                 s_max: int = 512, max_batch: int = 4,
                 prefill_chunk: int = 0, seed: int = 0,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.run = run
        self.s_max = s_max
        self.max_batch = max_batch
        self.prefill_chunk = (prefill_chunk if prefill_chunk > 0
                              and M.supports_extend(cfg) else 0)
        self.tracer = (tracer if tracer is not None and tracer.enabled
                       else Tracer(enabled=True))
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if params is None:
            params = materialize(M.model_specs(cfg), jax.random.PRNGKey(seed))
        self.params = params
        self._prefill = jax.jit(
            lambda p, b: M.forward(p, b, cfg, run, with_cache=True))
        self._decode = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg, run))
        self._extend = jax.jit(
            lambda p, t, pos0, c: M.extend_step(p, t, pos0, c, cfg, run))

    def empty_caches(self, batch: int):
        specs = M.cache_specs(self.cfg, batch=batch, s_max=self.s_max)
        return jax.tree_util.tree_map(
            lambda sp: jnp.zeros(sp.shape, jnp.bfloat16), specs)

    def _greedy(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))

    def prefill_whole(self, req: ServeRequest):
        """Whole-prompt prefill at batch 1: fills req.caches (linear,
        s_max) and returns the first sampled token."""
        L = req.length
        pad = _bucket(L, self.s_max)
        shape = (1, pad) + req.prompt.shape[1:]
        toks = np.zeros(shape, np.int32)
        toks[0, :L] = req.prompt
        logits, caches, _ = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
        req.caches = place_prefill_cache(self.cfg, caches, self.s_max, L,
                                         ring=False)
        req.prefill_done = L
        return self._greedy(logits[:, L - 1])[0]

    def prefill_chunk_step(self, req: ServeRequest):
        """Advance a chunked prefill by one chunk.  Returns the first
        sampled token once the prompt is complete, else None."""
        C = self.prefill_chunk
        if req.caches is None:
            req.caches = self.empty_caches(1)
        L, done = req.length, req.prefill_done
        toks = np.zeros((1, C) + req.prompt.shape[1:], np.int32)
        n = min(C, L - done)
        toks[0, :n] = req.prompt[done:done + n]
        pos0 = jnp.full((1,), done, jnp.int32)
        logits, req.caches = self._extend(self.params, jnp.asarray(toks),
                                          pos0, req.caches)
        req.prefill_done = done + n
        if req.prefill_done >= L:
            return self._greedy(logits[:, n - 1])[0]
        return None

    def decode(self, tokens: np.ndarray, pos: np.ndarray, caches):
        """One step across all rows. tokens (B,[K]) pos (B,) — returns
        (sampled (B,[K]), new_caches)."""
        tk = jnp.asarray(tokens)[:, None]
        logits, caches = self._decode(self.params, tk,
                                      jnp.asarray(pos, jnp.int32), caches)
        return self._greedy(logits[:, -1]), caches


class ContinuousScheduler:
    """Admission, retirement and accounting around a ContinuousEngine."""

    def __init__(self, engine: ContinuousEngine, kv: PagedKVCache):
        self.engine = engine
        self.kv = kv
        self.queue: List[ServeRequest] = []
        self._next_id = 0
        self.stats: Dict[str, Any] = {}
        self.latencies: Dict[int, float] = {}
        self.first_token_s: Dict[int, float] = {}

    def submit(self, prompt: np.ndarray, n_new: int,
               arrival_step: int = 0) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(ServeRequest(rid, np.asarray(prompt, np.int32),
                                       int(n_new), int(arrival_step)))
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        eng, kv, m = self.engine, self.kv, self.engine.metrics
        B = eng.max_batch
        self.queue.sort(key=lambda r: (r.arrival_step, r.rid))
        pending = list(self.queue)
        total = len(pending)
        self.queue = []
        self.latencies = {}
        self.first_token_s = {}
        if not pending:
            self.stats = {"engine_steps": 0, "decode_token_steps": 0,
                          "wasted_decode_steps": 0, "idle_row_slots": 0,
                          "prefill_chunks": 0, "delivered_tokens": 0,
                          "virtual_steps": 0, "requests": 0}
            return {}

        rows: List[Optional[ServeRequest]] = [None] * B  # active rows
        prefilling: List[ServeRequest] = []  # admitted, prompt in flight
        ready: List[ServeRequest] = []
        results: Dict[int, np.ndarray] = {}
        tokens = np.zeros((B,) + pending[0].prompt.shape[1:], np.int32)
        pos = np.zeros((B,), np.int32)
        remaining = np.full((B,), -1, np.int64)  # -1 = row not decoding
        state = {"retired": 0, "dirty": False}
        clock = 0
        engine_steps = work_slots = prefill_chunks = 0

        def retire(req: ServeRequest, row: int) -> None:
            req.t_finish = monotonic()
            self.latencies[req.rid] = req.t_finish - req.t_arrive
            results[req.rid] = np.stack(req.tokens)
            kv.release(req.rid)
            m.inc("serve/requests")
            m.inc("serve/tokens", req.n_new)
            rows[row] = None
            remaining[row] = -1
            state["retired"] += 1
            state["dirty"] = True  # freed row: next admission regathers

        def activate(req: ServeRequest, row: int, first_token) -> None:
            """Prompt is in the pools; the row decodes from the next step."""
            kv.write_prefill(req.rid, req.caches, req.length)
            req.caches = None  # working cache now comes from the pools
            req.tokens = [np.asarray(first_token, np.int32)]
            req.t_first = monotonic()
            self.first_token_s[req.rid] = req.t_first - req.t_arrive
            tokens[row] = first_token
            pos[row] = req.length
            remaining[row] = req.n_new - 1
            state["dirty"] = True
            if remaining[row] == 0:  # single-token request: done already
                retire(req, row)

        while state["retired"] < total:
            while pending and pending[0].arrival_step <= clock:
                req = pending.pop(0)
                req.t_arrive = monotonic()
                ready.append(req)
            m.observe("serve/queue_depth", len(ready))

            # admit: free row + free KV blocks reserve the whole lifetime
            while ready and None in rows:
                req = ready[0]
                need = req.length + req.n_new
                if need > eng.s_max:
                    raise ValueError(
                        f"request {req.rid}: prompt+n_new={need} exceeds "
                        f"s_max={eng.s_max}")
                if not kv.can_admit(req.prompt, need):
                    if not any(rows) and not prefilling:
                        raise RuntimeError(
                            f"request {req.rid} cannot fit in an empty KV "
                            f"pool ({kv.alloc.n_blocks} blocks)")
                    break
                ready.pop(0)
                kv.admit(req.rid, req.prompt, need)
                row = rows.index(None)
                rows[row] = req
                remaining[row] = -1  # prefilling sentinel: not decoding yet
                if eng.prefill_chunk and req.length > eng.prefill_chunk:
                    prefilling.append(req)
                else:
                    with eng.tracer.span("prefill", rid=req.rid,
                                         prompt_len=req.length) as sp:
                        first = eng.prefill_whole(req)
                    m.observe("serve/prefill_s", sp.elapsed_s)
                    activate(req, row, first)

            # one prefill chunk per tick: long prompts interleave with decode
            if prefilling:
                req = prefilling[0]
                with eng.tracer.span("prefill_chunk", rid=req.rid,
                                     done=req.prefill_done) as sp:
                    first = eng.prefill_chunk_step(req)
                m.observe("serve/prefill_chunk_s", sp.elapsed_s)
                prefill_chunks += 1
                if first is not None:
                    prefilling.pop(0)
                    m.observe("serve/prefill_s", sp.elapsed_s)
                    activate(req, rows.index(req), first)

            active = [i for i in range(B) if remaining[i] > 0]
            if not active:
                if not prefilling and not ready and pending:
                    clock = pending[0].arrival_step  # idle fast-forward
                else:
                    clock += 1
                continue

            if state["dirty"]:
                caches = kv.gather_batch(
                    [rows[i].rid if i in active else None for i in range(B)])
                state["dirty"] = False

            m.observe("serve/batch_size", len(active))
            with eng.tracer.span("decode_step", step=clock,
                                 live=len(active)) as sp:
                sampled, caches = eng.decode(tokens, pos, caches)
            m.observe("serve/decode_s", sp.elapsed_s)
            m.observe("serve/decode_token_s", sp.elapsed_s / len(active))
            engine_steps += 1
            work_slots += len(active)
            m.inc("serve/decode_token_steps", len(active))

            kv.commit_token([rows[i].rid for i in active], active,
                            pos[active], caches)
            for i in active:
                req = rows[i]
                req.tokens.append(sampled[i])
                pos[i] += 1
                remaining[i] -= 1
                tokens[i] = sampled[i]
                if remaining[i] == 0:
                    retire(req, i)
            m.set_gauge("serve/kv_blocks_used", kv.alloc.n_used)
            clock += 1

        # tokens *computed*: one per live-row decode slot plus the
        # prefill-sampled first token of each request — equals sum(n_new)
        # by construction (nothing is truncated), the static scheduler's
        # analogue is len(batch) * max(n_new) per batch.
        delivered = sum(len(t) for t in results.values())
        self.stats = {"engine_steps": engine_steps,
                      "decode_token_steps": work_slots + total,
                      "wasted_decode_steps": work_slots + total - delivered,
                      "idle_row_slots": engine_steps * B - work_slots,
                      "prefill_chunks": prefill_chunks,
                      "delivered_tokens": delivered,
                      "virtual_steps": clock,
                      "requests": total}
        return results
