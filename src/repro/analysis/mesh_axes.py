"""Mesh/collective axis-consistency analyzer (MX1xx).

A ``jax.lax.psum(..., "data")`` with an axis name no mesh ever declares
fails only at trace time, inside a shard_map, usually three minutes into a
run.  This pass makes the binding statically checkable:

- Pass 1 collects every axis name the repo *declares* — string literals
  inside ``Mesh(...)``/``make_mesh(...)`` constructions, ``axis_names=``
  keyword tuples, and ``PartitionSpec``/``P`` literals.  The declared set
  is repo-global: ``launch/mesh.py`` builds the meshes whose axes
  ``distributed/collectives.py`` reduces over, and
  ``distributed/pipeline.py``'s ``(pipe, data)`` grid declares the
  ``pipe`` stage axis its per-stage flat meshes slice out of.
- Pass 2 audits every collective call (``psum``, ``psum_scatter``,
  ``all_gather``, ``ppermute``, ``pmean``, ``pmax``, ``pmin``,
  ``all_to_all``, ``axis_index``):

  - **MX101** — a *literal* axis name (or tuple member) not in the
    declared set: the collective can never bind.
  - **MX102** — no axis argument at all (neither positional nor
    ``axis_name=``): the call is malformed.

Axis names passed as variables are skipped — the strategy zoo in
``collectives.py`` takes the axis as a parameter, and resolving dataflow
is out of scope for a lint pass; the rule catches the literal typo case
the issue names (the common way this bug is written).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

MESH_CTORS = {"Mesh", "make_mesh", "AbstractMesh"}
SPEC_CTORS = {"PartitionSpec", "P"}
COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "psum_scatter",
               "all_gather", "ppermute", "all_to_all", "axis_index"}


def _last(name_node: ast.AST) -> Optional[str]:
    if isinstance(name_node, ast.Attribute):
        return name_node.attr
    if isinstance(name_node, ast.Name):
        return name_node.id
    return None


def _str_literals(node: ast.AST) -> Iterable[str]:
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            yield n.value


def declared_axes(src: str, path: str = "<src>") -> Set[str]:
    """Axis names bound by mesh/PartitionSpec declarations in one module."""
    axes: Set[str] = set()
    tree = ast.parse(src, filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = _last(node.func)
            if fn in MESH_CTORS | SPEC_CTORS:
                for s in _str_literals(node):
                    axes.add(s)
        if isinstance(node, ast.keyword) and node.arg == "axis_names":
            for s in _str_literals(node.value):
                axes.add(s)
    return axes


def _axis_arg(call: ast.Call) -> Tuple[bool, Optional[ast.AST]]:
    """(present, node) for a collective's axis argument.  Positional slot 1
    (after the operand; slot 0 for axis_index) or ``axis_name=``."""
    for kw in call.keywords:
        if kw.arg == "axis_name":
            return True, kw.value
    fn = _last(call.func)
    slot = 0 if fn == "axis_index" else 1
    if len(call.args) > slot:
        return True, call.args[slot]
    return False, None


class _CollectiveVisitor(ast.NodeVisitor):
    def __init__(self, path: str, axes: Set[str]):
        self.path = path
        self.axes = axes
        self.stack: List[str] = []
        self.findings: List[Finding] = []

    @property
    def context(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def _scoped(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node): self._scoped(node)
    def visit_AsyncFunctionDef(self, node): self._scoped(node)
    def visit_ClassDef(self, node): self._scoped(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = _last(node.func)
        if fn in COLLECTIVES:
            # only jax.lax-style call sites: require an attribute access
            # (lax.psum / jax.lax.psum) or a bare name imported from lax —
            # bare-name heuristic accepted; false negatives only.
            present, axis = _axis_arg(node)
            if not present:
                self.findings.append(Finding(
                    path=self.path, line=node.lineno, code="MX102",
                    message=f"{fn}() without an axis argument",
                    context=self.context))
            else:
                names: List[str] = []
                if isinstance(axis, ast.Constant) and isinstance(
                        axis.value, str):
                    names = [axis.value]
                elif isinstance(axis, (ast.Tuple, ast.List)) and all(
                        isinstance(e, ast.Constant)
                        and isinstance(e.value, str) for e in axis.elts):
                    names = [e.value for e in axis.elts]
                for name in names:
                    if name not in self.axes:
                        self.findings.append(Finding(
                            path=self.path, line=node.lineno, code="MX101",
                            message=f"{fn}(axis={name!r}): axis never "
                                    f"declared by any mesh (declared: "
                                    f"{sorted(self.axes) or 'none'})",
                            context=self.context))
        self.generic_visit(node)


def analyze_sources(pairs: Sequence[Tuple[str, str]]) -> List[Finding]:
    """Two-pass over (path, source) modules: collect the repo-global axis
    set, then audit every collective call against it."""
    axes: Set[str] = set()
    for path, src in pairs:
        axes |= declared_axes(src, path)
    out: List[Finding] = []
    for path, src in pairs:
        v = _CollectiveVisitor(path, axes)
        v.visit(ast.parse(src, filename=path))
        out.extend(v.findings)
    return sorted(out)


def analyze(root) -> List[Finding]:
    root = Path(root)
    pairs = [(p.relative_to(root).as_posix(), p.read_text())
             for p in sorted((root / "src" / "repro").rglob("*.py"))]
    return analyze_sources(pairs)
