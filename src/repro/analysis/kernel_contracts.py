"""Kernel contract checker (KC1xx) — symbolic BlockSpec/grid/VMEM audit.

Each Pallas kernel in ``repro.kernels`` commits to a *contract*: the grid,
the per-operand block shapes, and the VMEM scratch it allocates for given
logical shapes.  This module mirrors that blocking logic in pure math
(no jax import needed to *check*; only the registry driver imports
``repro.kernels.ops`` for ``TUNABLE_OPS`` drift detection) and audits every
contract against the TPU tiling rules and the Eq.-5 memory budget:

- **KC100** — a ``TUNABLE_OPS`` entry has no contract coverage (the
  checker and the tuning registry drifted apart).
- **KC101** — a block shape does not tile its (padded) array: some array
  dim is not a multiple of the block dim, so the grid either misses or
  double-covers elements.
- **KC102** — lane misalignment: a block's last dim is neither a multiple
  of the 128-wide vector lane nor the full (unsplit) 8-aligned array dim.
- **KC103** — sublane misalignment: a block's second-minor dim is not a
  multiple of the per-dtype sublane tile (f32 8, bf16 16, int8 32),
  not 1, and not the full array dim.
- **KC104** — ssd_scan chunk contract: ``L % chunk != 0`` (the kernel
  asserts this at trace time; here it fails at lint time).
- **KC105** — the working set (sum of all in/out/scratch blocks, the same
  single-counting convention as ``tests/test_kernel_vmem.py``) exceeds
  ``vmem_bytes / 2`` — half of VMEM, leaving Pallas double-buffering
  headroom.  This is the serving-side analogue of the paper's Eq. 5
  "does the working set fit the memory bound" feasibility check.
- **KC106** — GQA head-mapping contract: ``H % KV != 0`` breaks the
  ``h // (H // KV)`` index map shared by the attention kernels.
- **KC107** — 1F1B pipeline-stage contract: some stage's per-chip working
  set (its balanced-cut share of params/grads/optimizer state plus
  ``memory_model.stage_activation_bytes`` — saved activations times the
  stage's in-flight microbatch count) exceeds the Eq.-5 HBM budget.  The
  registry sweep prices each arch at the smallest feasible microbatch
  count and *skips* cells where no count fits (the planner would never
  pick them), so the repo self-run stays clean; the finding fires when a
  pinned pipeline shape is checked directly (``pipeline_stage_findings``).

The registry driver sweeps every arch in ``configs.ARCH_IDS`` against the
paper-scale ``SHAPES`` in bf16 and f32, so a new architecture config that
violates a kernel contract fails lint before it ever reaches a TPU.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.core.hardware import TPU_V5E, Chip

LANE = 128  # minor-dim vector lane width (all dtypes)
SUBLANE = {4: 8, 2: 16, 1: 32}  # dtype bytes -> second-minor tile multiple
DTYPE_NAMES = {4: "f32", 2: "bf16", 1: "int8"}

# op -> the file findings point at (line 0: contract-level, not one line)
KERNEL_FILES = {
    "flash_attention": "src/repro/kernels/flash_attention.py",
    "decode_attention": "src/repro/kernels/decode_attention.py",
    "paged_decode_attention": "src/repro/kernels/decode_attention.py",
    "ssd_scan": "src/repro/kernels/ssd_scan.py",
    "pipeline_stage": "src/repro/distributed/pipeline.py",
}


@dataclasses.dataclass(frozen=True)
class Block:
    """One BlockSpec (or scratch allocation) of a kernel contract."""
    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int
    kind: str  # "in" | "out" | "scratch"
    array_shape: Optional[Tuple[int, ...]] = None  # padded HBM array

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * self.dtype_bytes


@dataclasses.dataclass(frozen=True)
class KernelContract:
    op: str
    context: str  # "op:arch:shape:dtype" fingerprint context
    grid: Tuple[int, ...]
    blocks: Tuple[Block, ...]

    @property
    def working_set_bytes(self) -> int:
        return sum(b.nbytes for b in self.blocks)


def _finding(op: str, code: str, msg: str, context: str) -> Finding:
    return Finding(path=KERNEL_FILES[op], line=0, code=code, message=msg,
                   context=context)


# ---------------------------------------------------------------------------
# Contract builders — pure-math mirrors of the kernels' blocking logic
# ---------------------------------------------------------------------------


def flash_contract(*, B: int, H: int, KV: int, Sq: int, Sk: int, D: int,
                   dtype_bytes: int = 2, q_block: int = 512,
                   kv_block: int = 512, context: str = "flash_attention",
                   ) -> Tuple[Optional[KernelContract], List[Finding]]:
    """Mirror of ``kernels.flash_attention``: tq/tk clamped to the padded
    sequence, grid (B, H, nq, nk), f32 accumulator + running max/sum."""
    op = "flash_attention"
    if KV <= 0 or H % KV:
        return None, [_finding(op, "KC106",
                               f"H={H} not divisible by KV={KV}; the "
                               "h // (H // KV) GQA index map is undefined",
                               context)]
    tq = min(q_block, max(Sq, 8))
    tk = min(kv_block, max(Sk, 8))
    sq_p = Sq + (-Sq % tq)
    sk_p = Sk + (-Sk % tk)
    grid = (B, H, sq_p // tq, sk_p // tk)
    blocks = (
        Block("q", (1, 1, tq, D), dtype_bytes, "in", (B, H, sq_p, D)),
        Block("k", (1, 1, tk, D), dtype_bytes, "in", (B, KV, sk_p, D)),
        Block("v", (1, 1, tk, D), dtype_bytes, "in", (B, KV, sk_p, D)),
        Block("out", (1, 1, tq, D), dtype_bytes, "out", (B, H, sq_p, D)),
        Block("acc", (tq, D), 4, "scratch"),
        Block("m_run", (tq,), 4, "scratch"),
        Block("l_run", (tq,), 4, "scratch"),
    )
    return KernelContract(op, context, grid, blocks), []


def decode_contract(*, B: int, H: int, KV: int, S: int, D: int,
                    dtype_bytes: int = 2, kv_block: int = 512,
                    context: str = "decode_attention",
                    ) -> Tuple[Optional[KernelContract], List[Finding]]:
    """Mirror of the linear-cache decode kernel: one query row per (b, h),
    KV streamed in tk-sized blocks."""
    op = "decode_attention"
    if KV <= 0 or H % KV:
        return None, [_finding(op, "KC106",
                               f"H={H} not divisible by KV={KV}; the "
                               "h // (H // KV) GQA index map is undefined",
                               context)]
    tk = min(kv_block, max(S, 8))
    s_p = S + (-S % tk)
    grid = (B, H, s_p // tk)
    blocks = (
        Block("q", (1, 1, 1, D), dtype_bytes, "in", (B, H, 1, D)),
        Block("k", (1, 1, tk, D), dtype_bytes, "in", (B, KV, s_p, D)),
        Block("v", (1, 1, tk, D), dtype_bytes, "in", (B, KV, s_p, D)),
        Block("pos", (1, 1), 4, "in", (B, 1)),
        Block("out", (1, 1, 1, D), dtype_bytes, "out", (B, H, 1, D)),
        Block("acc", (1, D), 4, "scratch"),
        Block("m_run", (1,), 4, "scratch"),
        Block("l_run", (1,), 4, "scratch"),
    )
    return KernelContract(op, context, grid, blocks), []


def paged_decode_contract(*, B: int, H: int, KV: int, bs: int, nb: int,
                          D: int, n_pool: int = 0, dtype_bytes: int = 2,
                          context: str = "paged_decode_attention",
                          ) -> Tuple[Optional[KernelContract], List[Finding]]:
    """Mirror of the paged decode kernel: grid (B, H, nb), per-step KV
    blocks of one *physical pool block* (bs rows), block table and
    positions scalar-prefetched to SMEM (not VMEM-counted)."""
    op = "paged_decode_attention"
    if KV <= 0 or H % KV:
        return None, [_finding(op, "KC106",
                               f"H={H} not divisible by KV={KV}; the "
                               "h // (H // KV) GQA index map is undefined",
                               context)]
    n_pool = n_pool or B * nb
    grid = (B, H, nb)
    blocks = (
        Block("q", (1, 1, 1, D), dtype_bytes, "in", (B, H, 1, D)),
        Block("k_pool", (1, 1, bs, D), dtype_bytes, "in",
              (n_pool, KV, bs, D)),
        Block("v_pool", (1, 1, bs, D), dtype_bytes, "in",
              (n_pool, KV, bs, D)),
        Block("out", (1, 1, 1, D), dtype_bytes, "out", (B, H, 1, D)),
        Block("acc", (1, D), 4, "scratch"),
        Block("m_run", (1,), 4, "scratch"),
        Block("l_run", (1,), 4, "scratch"),
    )
    return KernelContract(op, context, grid, blocks), []


def ssd_contract(*, B: int, H: int, L: int, P: int, N: int, chunk: int = 256,
                 dtype_bytes: int = 4, context: str = "ssd_scan",
                 ) -> Tuple[Optional[KernelContract], List[Finding]]:
    """Mirror of the SSD chunked scan: grid (B, H, nc) with an
    ``arbitrary`` (sequential) chunk axis carrying the (N, P) state."""
    op = "ssd_scan"
    q = min(chunk, L)
    if L % q:
        return None, [_finding(op, "KC104",
                               f"L={L} not divisible by chunk={q}; the "
                               "kernel asserts L % chunk == 0", context)]
    grid = (B, H, L // q)
    blocks = (
        Block("x", (1, 1, q, P), dtype_bytes, "in", (B, H, L, P)),
        Block("dt", (1, 1, q), dtype_bytes, "in", (B, H, L)),
        Block("a_neg", (1, 1), dtype_bytes, "in", (H, 1)),
        Block("b", (1, q, N), dtype_bytes, "in", (B, L, N)),
        Block("c", (1, q, N), dtype_bytes, "in", (B, L, N)),
        Block("y", (1, 1, q, P), dtype_bytes, "out", (B, H, L, P)),
        Block("h_out", (1, 1, N, P), dtype_bytes, "out", (B, H, N, P)),
        Block("state", (N, P), 4, "scratch"),
    )
    return KernelContract(op, context, grid, blocks), []


# ---------------------------------------------------------------------------
# Contract checks
# ---------------------------------------------------------------------------


def check_contract(c: KernelContract,
                   chip: Chip = TPU_V5E) -> List[Finding]:
    out: List[Finding] = []
    if any(g <= 0 for g in c.grid):
        out.append(_finding(c.op, "KC101",
                            f"degenerate grid {c.grid}", c.context))
    for b in c.blocks:
        arr = b.array_shape
        if arr is not None:
            if len(arr) != len(b.shape):
                out.append(_finding(
                    c.op, "KC101",
                    f"{b.name}: block rank {len(b.shape)} != array rank "
                    f"{len(arr)}", c.context))
                continue
            for i, (blk_d, arr_d) in enumerate(zip(b.shape, arr)):
                if blk_d <= 0 or arr_d % blk_d:
                    out.append(_finding(
                        c.op, "KC101",
                        f"{b.name}: block {b.shape} does not tile array "
                        f"{arr} (dim {i}: {arr_d} % {blk_d} != 0)",
                        c.context))
                    break
        if len(b.shape) < 2:
            continue  # 1-D scratch vectors are not tile-constrained
        lane = b.shape[-1]
        full_lane = arr is not None and lane == arr[-1]
        lane_ok = (lane % LANE == 0
                   or (full_lane and (lane % 8 == 0 or arr[-1] < 8))
                   or (arr is None and lane % 8 == 0))
        if not lane_ok:
            out.append(_finding(
                c.op, "KC102",
                f"{b.name}: last dim {lane} of block {b.shape} is neither "
                f"a multiple of the {LANE}-wide lane nor the full "
                "8-aligned array dim", c.context))
        sub = b.shape[-2]
        mult = SUBLANE.get(b.dtype_bytes, 8)
        full_sub = arr is not None and sub == arr[-2]
        if not (sub % mult == 0 or sub == 1 or full_sub):
            out.append(_finding(
                c.op, "KC103",
                f"{b.name}: second-minor dim {sub} of block {b.shape} is "
                f"not a multiple of the {b.dtype_bytes}-byte sublane tile "
                f"({mult}) nor the full array dim", c.context))
    budget = int(chip.vmem_bytes) // 2
    ws = c.working_set_bytes
    if ws > budget:
        out.append(_finding(
            c.op, "KC105",
            f"working set {ws} B exceeds the Eq.-5 VMEM budget "
            f"{budget} B (= vmem_bytes/2, double-buffering headroom) on "
            f"{chip.name if hasattr(chip, 'name') else 'chip'}", c.context))
    return out


# ---------------------------------------------------------------------------
# Registry sweep — every TUNABLE_OPS entry x every arch that exercises it
# ---------------------------------------------------------------------------


def registry_contracts(
    *, dtypes: Sequence[int] = (2, 4), batch: int = 1, kv_block: int = 16,
) -> Tuple[List[KernelContract], List[Finding], Dict[str, List[str]]]:
    """Build contracts for every (op, arch, shape, dtype) combination the
    config registry implies.  ``kv_block`` is the serving pool block size
    (the ``JobSpec.kv_block`` default).  Returns (contracts, builder
    findings, audit) where audit maps op -> the contexts it was checked
    under — the acceptance hook that every tunable op faces >= 2 configs.
    """
    contracts: List[KernelContract] = []
    findings: List[Finding] = []
    audit: Dict[str, List[str]] = {}

    def add(op, built):
        c, fs = built
        findings.extend(fs)
        if c is not None:
            contracts.append(c)
        ctx = (c.context if c is not None else
               (fs[0].context if fs else op))
        audit.setdefault(op, []).append(ctx)

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.has_attention:
            H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            if cfg.is_mla:
                # absorbed MLA decode: one shared latent "KV head" of
                # width kv_lora_rank + qk_rope_head_dim (576 for
                # deepseek-v2) — the wide-lane case KC102 must admit
                dec_kv, dec_d = 1, cfg.kv_cache_width
            else:
                dec_kv, dec_d = KV, D
            for shape in ("train_4k", "prefill_32k"):
                s = SHAPES[shape].seq_len
                for db in dtypes:
                    ctx = f"flash_attention:{arch}:{shape}:{DTYPE_NAMES[db]}"
                    add("flash_attention",
                        flash_contract(B=batch, H=H, KV=KV, Sq=s, Sk=s,
                                       D=D, dtype_bytes=db, context=ctx))
            for shape in ("decode_32k", "long_500k"):
                s = SHAPES[shape].seq_len
                for db in dtypes:
                    ctx = f"decode_attention:{arch}:{shape}:{DTYPE_NAMES[db]}"
                    add("decode_attention",
                        decode_contract(B=batch, H=H, KV=dec_kv, S=s,
                                        D=dec_d, dtype_bytes=db,
                                        context=ctx))
            s = SHAPES["decode_32k"].seq_len
            nb = s // kv_block
            for db in dtypes:
                ctx = (f"paged_decode_attention:{arch}:decode_32k:"
                       f"{DTYPE_NAMES[db]}")
                add("paged_decode_attention",
                    paged_decode_contract(B=batch, H=H, KV=dec_kv,
                                          bs=kv_block, nb=nb, D=dec_d,
                                          n_pool=2 * batch * nb,
                                          dtype_bytes=db, context=ctx))
        if cfg.has_ssm:
            for shape in ("train_4k", "prefill_32k"):
                s = SHAPES[shape].seq_len
                for db in dtypes:
                    ctx = f"ssd_scan:{arch}:{shape}:{DTYPE_NAMES[db]}"
                    add("ssd_scan",
                        ssd_contract(B=batch, H=cfg.ssm_heads, L=s,
                                     P=cfg.ssm_head_dim, N=cfg.ssm_state,
                                     chunk=cfg.ssm_chunk, dtype_bytes=db,
                                     context=ctx))
    return contracts, findings, audit


def check_registry(chip: Chip = TPU_V5E, **kw
                   ) -> Tuple[List[Finding], Dict[str, List[str]]]:
    """The analyzer entry point: sweep the registry, check every contract,
    and flag any TUNABLE_OPS entry the sweep never covered (KC100)."""
    contracts, findings, audit = registry_contracts(**kw)
    for c in contracts:
        findings.extend(check_contract(c, chip))
    try:  # drift guard against the tuning registry (imports jax)
        from repro.kernels.ops import TUNABLE_OPS
    except Exception:  # pragma: no cover - jax always importable in-repo
        TUNABLE_OPS = tuple(KERNEL_FILES)
    for op in TUNABLE_OPS:
        if not audit.get(op):
            findings.append(_finding(
                op if op in KERNEL_FILES else "flash_attention", "KC100",
                f"TUNABLE_OPS entry {op!r} has no kernel-contract coverage",
                f"registry:{op}"))
    return findings, audit


# ---------------------------------------------------------------------------
# KC107 — 1F1B pipeline-stage working set vs the Eq.-5 HBM budget
# ---------------------------------------------------------------------------


def pipeline_stage_findings(cfg, shape, *, pipe: int, n_microbatch: int,
                            dp: int, tp: int = 1, attn_impl: str = "flash",
                            remat: str = "block", chip: Chip = TPU_V5E,
                            frac: float = 0.9,
                            context: str = "pipeline_stage") -> List[Finding]:
    """Check every 1F1B stage of a pinned pipeline shape: the stage's
    balanced-cut share of params/grads/optimizer state plus its peak
    activation working set (``stage_activation_bytes``: in-flight
    microbatches scale with ``min(pipe - s, m)``) must fit
    ``frac * hbm_bytes``.  Emits one KC107 per violating stage."""
    # lazy: memory_model reaches repro.models (jax) — same rule as the
    # TUNABLE_OPS drift guard, the pure checkers above stay import-light
    from repro.core.memory_model import n_params, stage_activation_bytes
    from repro.core.pipeline import balanced_stage_cut

    op = "pipeline_stage"
    cycles = ((cfg.num_layers - cfg.first_k_dense)
              // max(len(cfg.pattern), 1))
    if pipe < 1 or cycles < pipe:
        return [_finding(op, "KC107",
                         f"pipe={pipe} does not cut {cycles} layer cycles "
                         "into non-empty stages", context)]
    cut = balanced_stage_cut(cycles, pipe)
    N = n_params(cfg)
    chips = dp * tp
    # per-stage static share (train_memory's conventions: bf16 + fp32
    # master weights, fp32 grads, ZeRO-1 adamw state)
    static = ((2 * N / tp + 4 * N / chips) + 4 * N / tp + 8 * N / chips) / pipe
    budget = frac * chip.hbm_bytes
    out: List[Finding] = []
    for s in range(pipe):
        act = stage_activation_bytes(
            cfg, shape, dp=dp, tp=tp, pipe=pipe, n_microbatch=n_microbatch,
            stage=s, stage_cycles=cut[s + 1] - cut[s], attn_impl=attn_impl,
            remat=remat, seq_parallel=True)
        ws = static + act
        if ws > budget:
            out.append(_finding(
                op, "KC107",
                f"stage {s}/{pipe} working set {ws:.3g} B (static "
                f"{static:.3g} + activations {act:.3g}, "
                f"{min(pipe - s, max(n_microbatch, pipe))} microbatches in "
                f"flight) exceeds the Eq.-5 budget {budget:.3g} B "
                f"(= {frac} * hbm)", context))
    return out


def check_pipeline_registry(chip: Chip = TPU_V5E, *, world: int = 8,
                            shapes: Sequence[str] = ("train_4k",),
                            ) -> Tuple[List[Finding], Dict[str, List[str]]]:
    """Registry sweep for KC107: for every arch x pipe in {2, 4} x shape,
    derive the smallest microbatch count in {p, 2p, 4p} the Eq.-5 gate
    (``memory_model.train_memory``, the planner's own feasibility check)
    accepts.  Cells the gate rejects at every count are *skipped* — the
    planner would never pick them, so they are not lint findings.  A
    gate-accepted cell whose per-stage audit still flags means this
    mirror and ``memory_model`` drifted apart — that surfaces as KC107."""
    from repro.core.memory_model import train_memory

    findings: List[Finding] = []
    audit: Dict[str, List[str]] = {"pipeline_stage": []}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cycles = ((cfg.num_layers - cfg.first_k_dense)
                  // max(len(cfg.pattern), 1))
        for pipe in (2, 4):
            if cycles < pipe or world % pipe:
                continue
            dp = world // pipe
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                ctx = f"pipeline_stage:{arch}:{shape_name}:p{pipe}"
                # doubling microbatch counts up to one row per microbatch
                # (more microbatches shrink the in-flight slice, so the
                # smallest feasible m is the tightest cell worth auditing)
                b_rep = max(shape.global_batch // (world // pipe), 1)
                candidates = []
                m = pipe
                while m <= max(b_rep, pipe):
                    candidates.append(m)
                    m *= 2
                for m in candidates:
                    # microbatch=0: the 1F1B rows-per-microbatch derive
                    # from m, the same convention stage_activation_bytes
                    # prices — the gate and the audit see one schedule
                    mem = train_memory(
                        cfg, shape, dp=dp, tp=1, fsdp=False, microbatch=0,
                        attn_impl="flash", remat="block", seq_parallel=True,
                        pipe=pipe, n_microbatch=m)
                    if mem.total > 0.9 * chip.hbm_bytes:
                        continue  # Eq.-5 gate rejects: planner skips too
                    audit["pipeline_stage"].append(f"{ctx}:m{m}")
                    findings.extend(pipeline_stage_findings(
                        cfg, shape, pipe=pipe, n_microbatch=m, dp=dp,
                        chip=chip, context=f"{ctx}:m{m}"))
                    break  # smallest feasible m prices the cell
    return findings, audit


def analyze(root=None) -> List[Finding]:
    """Uniform analyzer interface for the CLI (root unused: contracts come
    from the imported registry, not from file paths)."""
    findings, _ = check_registry()
    pipe_findings, _ = check_pipeline_registry()
    return findings + pipe_findings
