"""Finding records + baseline (suppression) plumbing for ``repro.analysis``.

Every analyzer emits :class:`Finding` rows.  A finding's *fingerprint* is
``code:path:context`` — deliberately line-number-free so a justified
suppression in ``tools/lint_baseline.json`` survives unrelated edits that
shift lines.  ``context`` is the dotted qualname of the enclosing
def/class for AST findings (``"<module>"`` at file scope) or an
``op:arch:shape`` triple for kernel-contract findings.

Two schema ids, registered with the schema-drift analyzer like every
other ``repro.*`` payload:

- ``repro.analysis/findings/v1`` — the ``--json`` artifact the CI lint
  job uploads (findings + suppression accounting + wall clock).
- ``repro.analysis/baseline/v1`` — the committed suppression file; each
  entry carries a mandatory human ``reason``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Tuple

FINDINGS_SCHEMA_ID = "repro.analysis/findings/v1"
BASELINE_SCHEMA_ID = "repro.analysis/baseline/v1"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str  # repo-relative, forward slashes
    line: int  # 1-based; 0 when the finding has no single line
    code: str  # e.g. "DT102"
    message: str
    context: str = "<module>"

    @property
    def fingerprint(self) -> str:
        return f"{self.code}:{self.path}:{self.context}"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: {self.code} {self.message} "
                f"[{self.context}]")


# ---------------------------------------------------------------------------
# Baseline file
# ---------------------------------------------------------------------------


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"{BASELINE_SCHEMA_ID}: {msg}")


def validate_baseline(d: Any) -> Dict[str, Any]:
    _require(isinstance(d, dict), f"expected object, got {type(d).__name__}")
    _require(d.get("schema") == BASELINE_SCHEMA_ID,
             f"schema {d.get('schema')!r} != {BASELINE_SCHEMA_ID!r}")
    sup = d.get("suppressions")
    _require(isinstance(sup, list), "suppressions must be a list")
    for i, s in enumerate(sup):
        _require(isinstance(s, dict), f"suppressions[{i}] must be an object")
        fp, reason = s.get("fingerprint"), s.get("reason")
        _require(isinstance(fp, str) and fp.count(":") >= 2,
                 f"suppressions[{i}].fingerprint must be code:path:context")
        _require(isinstance(reason, str) and reason.strip() != "",
                 f"suppressions[{i}].reason must be a non-empty string")
    return d


def load_baseline(path: Path) -> Dict[str, str]:
    """fingerprint -> reason; missing file means an empty baseline."""
    if not Path(path).exists():
        return {}
    d = validate_baseline(json.loads(Path(path).read_text()))
    return {s["fingerprint"]: s["reason"] for s in d["suppressions"]}


def apply_baseline(
    findings: Iterable[Finding], suppressions: Dict[str, str]
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (unbaselined, suppressed) and report stale
    suppression fingerprints that matched nothing (a fixed finding whose
    baseline entry should be deleted)."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    hit = set()
    for f in findings:
        if f.fingerprint in suppressions:
            suppressed.append(f)
            hit.add(f.fingerprint)
        else:
            kept.append(f)
    stale = sorted(set(suppressions) - hit)
    return kept, suppressed, stale


def make_baseline(findings: Iterable[Finding],
                  reasons: Dict[str, str]) -> Dict[str, Any]:
    """Build a baseline document suppressing ``findings`` (deduped by
    fingerprint); ``reasons`` may pre-seed justifications."""
    sup: Dict[str, str] = {}
    for f in findings:
        sup.setdefault(f.fingerprint,
                       reasons.get(f.fingerprint, "TODO: justify"))
    return {
        "schema": BASELINE_SCHEMA_ID,
        "suppressions": [{"fingerprint": fp, "reason": r}
                         for fp, r in sorted(sup.items())],
    }


# ---------------------------------------------------------------------------
# Findings artifact (the --json payload CI uploads)
# ---------------------------------------------------------------------------


def make_findings_payload(unbaselined: List[Finding],
                          suppressed: List[Finding],
                          stale: List[str],
                          wall_s: float) -> Dict[str, Any]:
    return {
        "schema": FINDINGS_SCHEMA_ID,
        "findings": [f.to_dict() for f in sorted(unbaselined)],
        "suppressed": [f.to_dict() for f in sorted(suppressed)],
        "stale_suppressions": list(stale),
        "wall_s": float(wall_s),
        "clean": not unbaselined,
    }


def validate_findings(d: Any) -> Dict[str, Any]:
    if not isinstance(d, dict):
        raise ValueError(f"{FINDINGS_SCHEMA_ID}: expected object")
    if d.get("schema") != FINDINGS_SCHEMA_ID:
        raise ValueError(f"{FINDINGS_SCHEMA_ID}: schema "
                         f"{d.get('schema')!r} != {FINDINGS_SCHEMA_ID!r}")
    for key in ("findings", "suppressed", "stale_suppressions"):
        if not isinstance(d.get(key), list):
            raise ValueError(f"{FINDINGS_SCHEMA_ID}: {key} must be a list")
    for row in d["findings"] + d["suppressed"]:
        for k in ("path", "line", "code", "message", "context",
                  "fingerprint"):
            if k not in row:
                raise ValueError(f"{FINDINGS_SCHEMA_ID}: finding missing {k}")
    if not isinstance(d.get("wall_s"), (int, float)):
        raise ValueError(f"{FINDINGS_SCHEMA_ID}: wall_s must be a number")
    if d.get("clean") != (not d["findings"]):
        raise ValueError(f"{FINDINGS_SCHEMA_ID}: clean flag inconsistent "
                         "with findings list")
    return d
