"""Schema-drift analyzer (SD1xx) — every schema id has a validator, every
golden still validates.

The repo's payloads are hand-rolled-validated (no jsonschema dep): the
``repro.api/*/v1`` Report family, ``repro.api/metrics/v1``,
``repro.api/campaign/v1``, the autotune cache, the bench trajectory, and
now the lint findings/baseline pair.  Drift between a schema-id literal
and its validator is a silent contract break; these rules pin them
together:

- **SD101** — a schema-id-shaped string literal (``repro.<pkg>/<name>/vN``)
  in ``src/`` or ``tools/`` that no known validator claims.
- **SD102** — a registered schema id that appears nowhere in the scanned
  sources (a validator for a payload nothing emits — dead registration).
- **SD103** — ``HISTOGRAM_KEYS`` drifted from what ``Histogram.summary()``
  actually emits, or a smoke ``MetricsRegistry.section()`` fails its own
  ``validate_metrics``.
- **SD104** — a golden in ``tests/goldens/`` fails its mapped validator.
- **SD105** — a golden JSON with no validator mapping (an unvalidated
  fixture is drift waiting to happen).
"""
from __future__ import annotations

import ast
import importlib.util
import json
import re
from pathlib import Path
from typing import Callable, Dict, List, Tuple

from repro.analysis.findings import Finding

# matches exactly a schema-id literal: repro.<pkg>/<slug>/v<N>
SCHEMA_ID_RE = re.compile(r"\Arepro\.[a-z_]+/[A-Za-z0-9._-]+/v\d+\Z")

SCAN_DIRS = ("src/repro", "tools")


def known_schema_ids() -> Dict[str, str]:
    """schema id -> 'module:validator' for every registered payload."""
    from repro.analysis import findings as an_findings
    from repro.api import campaign as api_campaign
    from repro.api import report as api_report
    from repro.checkpoint import io as ckpt_io
    from repro.core import autotune as core_autotune
    from repro.obs import metrics as obs_metrics

    ids = {
        ckpt_io.MANIFEST_SCHEMA_ID:
            "repro.checkpoint.io:validate_manifest",
        api_report.SCHEMA_ID: "repro.api.report:validate_report",
        api_report.TUNING_SCHEMA_ID: "repro.api.report:_validate_tuning",
        api_report.SERVING_SCHEMA_ID: "repro.api.report:_validate_serving",
        api_campaign.CAMPAIGN_SCHEMA_ID:
            "repro.api.campaign:Campaign.from_dict",
        obs_metrics.METRICS_SCHEMA_ID: "repro.obs.metrics:validate_metrics",
        core_autotune.CACHE_SCHEMA_ID:
            "repro.core.autotune:TuningCache.load",
        an_findings.FINDINGS_SCHEMA_ID:
            "repro.analysis.findings:validate_findings",
        an_findings.BASELINE_SCHEMA_ID:
            "repro.analysis.findings:validate_baseline",
    }
    ids[_trajectory_schema_id()] = "tools/bench_trajectory.py:load_trajectory"
    return ids


def _trajectory_schema_id() -> str:
    """Import tools/bench_trajectory.py by path (tools/ is not a package);
    fall back to the committed literal if the tool moved (SD102 then
    flags the drift)."""
    path = Path(__file__).resolve().parents[3] / "tools/bench_trajectory.py"
    try:
        spec = importlib.util.spec_from_file_location("_bench_traj", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod.TRAJECTORY_SCHEMA_ID
    except Exception:
        return "repro.obs/bench-trajectory/v1"


# ---------------------------------------------------------------------------
# SD101/SD102: literal <-> registry cross-check
# ---------------------------------------------------------------------------


def schema_literals(src: str, path: str) -> List[Tuple[str, int]]:
    """(schema id, line) for every schema-id-shaped string constant."""
    out = []
    for node in ast.walk(ast.parse(src, filename=path)):
        if (isinstance(node, ast.Constant) and isinstance(node.value, str)
                and SCHEMA_ID_RE.match(node.value)):
            out.append((node.value, node.lineno))
    return out


def analyze_literals(pairs, known: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    seen: Dict[str, int] = {}
    for path, src in pairs:
        for sid, line in schema_literals(src, path):
            seen[sid] = seen.get(sid, 0) + 1
            if sid not in known:
                findings.append(Finding(
                    path=path, line=line, code="SD101",
                    message=f"schema id {sid!r} has no registered "
                            "validator", context=sid))
    for sid, where in sorted(known.items()):
        if sid not in seen:
            mod = where.split(":")[0]
            home = mod if mod.startswith("tools/") else (
                "src/" + mod.replace(".", "/") + ".py")
            findings.append(Finding(
                path=home, line=0, code="SD102",
                message=f"registered schema id {sid!r} appears nowhere in "
                        f"{SCAN_DIRS} — dead registration", context=sid))
    return findings


# ---------------------------------------------------------------------------
# SD103: HISTOGRAM_KEYS vs emitted metrics
# ---------------------------------------------------------------------------


def check_histogram_keys() -> List[Finding]:
    from repro.obs.metrics import (HISTOGRAM_KEYS, Histogram,
                                   MetricsRegistry, validate_metrics)
    path = "src/repro/obs/metrics.py"
    out: List[Finding] = []
    h = Histogram()
    for i in range(32):
        h.observe(float(i))
    emitted = tuple(h.summary())
    if emitted != tuple(HISTOGRAM_KEYS):
        out.append(Finding(
            path=path, line=0, code="SD103",
            message=f"Histogram.summary() emits {emitted}, but "
                    f"HISTOGRAM_KEYS declares {tuple(HISTOGRAM_KEYS)}",
            context="HISTOGRAM_KEYS"))
    reg = MetricsRegistry()
    reg.inc("lint/smoke_total", 3)
    reg.set_gauge("lint/smoke_gauge", 1.5)
    for i in range(8):
        reg.observe("lint/smoke_s", 0.1 * i)
    try:
        validate_metrics(reg.section())
    except Exception as e:
        out.append(Finding(
            path=path, line=0, code="SD103",
            message=f"MetricsRegistry.section() fails validate_metrics: "
                    f"{e}", context="MetricsRegistry.section"))
    return out


# ---------------------------------------------------------------------------
# SD104/SD105: goldens still validate
# ---------------------------------------------------------------------------


def golden_validators() -> Dict[str, Callable]:
    """golden filename prefix -> validator over the parsed JSON."""
    from repro.api import Campaign, validate_report
    from repro.obs.metrics import validate_metrics
    return {
        "report_": validate_report,
        "tuning_": validate_report,
        "campaign_": lambda d: Campaign.from_dict(d),
        "metrics_": validate_metrics,
    }


def check_goldens(root) -> List[Finding]:
    root = Path(root)
    vals = golden_validators()
    out: List[Finding] = []
    for p in sorted((root / "tests" / "goldens").glob("*.json")):
        rel = p.relative_to(root).as_posix()
        fn = next((v for pre, v in vals.items()
                   if p.name.startswith(pre)), None)
        if fn is None:
            out.append(Finding(
                path=rel, line=0, code="SD105",
                message="golden has no validator mapping; add one to "
                        "repro.analysis.schema_drift.golden_validators",
                context=p.name))
            continue
        try:
            fn(json.loads(p.read_text()))
        except Exception as e:
            out.append(Finding(
                path=rel, line=0, code="SD104",
                message=f"golden fails its validator: {e}",
                context=p.name))
    return out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def analyze(root) -> List[Finding]:
    root = Path(root)
    pairs = []
    for d in SCAN_DIRS:
        base = root / d
        if base.exists():
            pairs.extend((p.relative_to(root).as_posix(), p.read_text())
                         for p in sorted(base.rglob("*.py")))
    known = known_schema_ids()
    out = analyze_literals(pairs, known)
    out.extend(check_histogram_keys())
    out.extend(check_goldens(root))
    return sorted(out)
