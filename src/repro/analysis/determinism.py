"""Determinism & purity analyzer (DT1xx) — AST pass over ``src/repro``.

The repo's bit-identity contracts (overlap sync, continuous-vs-static
serving) only hold if nothing in a measured path consults an unseeded RNG
or a second wall clock.  Four rules:

- **DT101** — unseeded randomness: legacy ``np.random.*`` global-RNG
  calls, zero-arg ``np.random.default_rng()``, zero-arg
  ``random.Random()``, and module-level ``random.*`` draws.  Every RNG in
  the repo must be an instance constructed from an explicit seed.
- **DT102** — wall-clock reads outside the sanctioned clock: any
  reference to ``time.time``/``perf_counter``/``monotonic``/
  ``datetime.now`` (aliases included) anywhere except
  ``repro.obs.trace`` — the one module allowed to own a clock.  Measured
  paths read time via ``Tracer`` spans or ``repro.obs.trace.monotonic``.
- **DT103** — host sync inside a collective phase: a function that issues
  ``jax.lax`` collectives (psum, all_gather, ...) must not also call
  ``float()``/``np.asarray()``/``.item()``/``jax.device_get()`` on its
  values — each is a device->host sync that serializes the very overlap
  the collective schedule exists to create.  (``int()`` is deliberately
  not flagged: it is used on static shapes, not on device values.)
- **DT104** — non-atomic checkpoint writes: inside
  ``src/repro/checkpoint/``, a function that persists state
  (``np.savez``/``np.save``, ``json.dump``, ``.write_text``/
  ``.write_bytes``) must also call ``os.replace``/``os.rename`` (or
  ``Path.replace``) in the same function — i.e. it wrote a tmp file and
  atomically renamed it.  A bare write can be torn by a crash, which is
  exactly the corruption the elastic-checkpoint protocol
  (``repro.checkpoint.io``) exists to rule out.

The pass resolves import aliases per module (``import numpy as np``,
``from time import perf_counter as pc``) so renamed imports cannot dodge
the rules.  Fingerprint context is the dotted qualname of the enclosing
def/class, keeping baseline entries stable across line drift.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

# files allowed to read the wall clock directly (repo-relative)
DT102_EXEMPT = {"src/repro/obs/trace.py"}

WALL_CLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}
# np.random.<fn> members that construct explicitly-seeded generators and
# are therefore fine to reference
NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                "Philox", "SFC64", "MT19937", "BitGenerator"}
RANDOM_MODULE_FNS = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "betavariate", "gammavariate", "paretovariate",
    "triangular", "vonmisesvariate", "weibullvariate", "getrandbits",
    "randbytes", "seed",
}
COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "psum_scatter",
               "all_gather", "ppermute", "all_to_all"}
HOST_SYNC = {"numpy.asarray", "numpy.array", "jax.device_get"}
# DT104: the checkpoint subtree where every persistent write must pair with
# an atomic rename in the same function
DT104_PREFIX = "src/repro/checkpoint/"
PERSIST_WRITES = {"numpy.savez", "numpy.savez_compressed", "numpy.save",
                  "json.dump"}
PERSIST_WRITE_METHODS = {"write_text", "write_bytes"}
ATOMIC_RENAMES = {"os.replace", "os.rename"}


class _Scope:
    __slots__ = ("name", "has_collective", "sync_calls", "writes",
                 "has_rename")

    def __init__(self, name: str):
        self.name = name
        self.has_collective = False
        self.sync_calls: List[Tuple[int, str]] = []
        self.writes: List[Tuple[int, str]] = []
        self.has_rename = False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self._ckpt = path.startswith(DT104_PREFIX)
        self.aliases: Dict[str, str] = {}  # local name -> dotted origin
        self.stack: List[str] = []
        self.scopes: List[_Scope] = []
        self.findings: List[Finding] = []
        self._flagged: Set[Tuple[int, int]] = set()

    # -- helpers --------------------------------------------------------
    @property
    def context(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted origin of an expression, following import aliases:
        ``np.random.rand`` -> ``numpy.random.rand``; None if the root
        name is not an import."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id)
        if root is None:
            return None
        return ".".join([root] + list(reversed(parts)))

    def _emit(self, node: ast.AST, code: str, msg: str) -> None:
        key = (node.lineno, node.col_offset)
        if key in self._flagged:
            return
        self._flagged.add(key)
        self.findings.append(Finding(path=self.path, line=node.lineno,
                                     code=code, message=msg,
                                     context=self.context))

    # -- imports --------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level or not node.module:
            return  # relative imports never reach stdlib clocks
        for a in node.names:
            if a.name == "*":
                continue
            self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    # -- scopes ---------------------------------------------------------
    def _enter(self, node, is_func: bool) -> None:
        self.stack.append(node.name)
        if is_func:
            self.scopes.append(_Scope(self.context))
        self.generic_visit(node)
        if is_func:
            sc = self.scopes.pop()
            if sc.has_collective:
                for line, what in sc.sync_calls:
                    self.findings.append(Finding(
                        path=self.path, line=line, code="DT103",
                        message=f"{what} inside a collective-issuing "
                                "function forces a device->host sync that "
                                "serializes comm/compute overlap",
                        context=sc.name))
            if sc.writes and not sc.has_rename:
                for line, what in sc.writes:
                    self.findings.append(Finding(
                        path=self.path, line=line, code="DT104",
                        message=f"{what} persists checkpoint state with no "
                                "os.replace/os.rename in the same function; "
                                "write a tmp file and atomically rename it "
                                "so a crash cannot leave a torn file",
                        context=sc.name))
        self.stack.pop()

    def visit_FunctionDef(self, node): self._enter(node, True)
    def visit_AsyncFunctionDef(self, node): self._enter(node, True)
    def visit_ClassDef(self, node): self._enter(node, False)

    # -- rules ----------------------------------------------------------
    def _check_wall_clock(self, node: ast.AST) -> None:
        dotted = self.resolve(node)
        if dotted in WALL_CLOCK and self.path not in DT102_EXEMPT:
            self._emit(node, "DT102",
                       f"wall-clock read {dotted}(); measured paths go "
                       "through repro.obs.trace (Tracer span or "
                       "monotonic())")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check_wall_clock(node)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load):
            self._check_wall_clock(node)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        dotted = self.resolve(node.func)
        if dotted:
            self._check_dt101(node, dotted)
            tail = dotted.rsplit(".", 1)
            if (len(tail) == 2 and tail[1] in COLLECTIVES
                    and tail[0] in ("jax.lax", "lax")):
                if self.scopes:
                    self.scopes[-1].has_collective = True
            if dotted in HOST_SYNC and self.scopes:
                self.scopes[-1].sync_calls.append(
                    (node.lineno, f"{dotted}()"))
        if (isinstance(node.func, ast.Name) and node.func.id == "float"
                and node.args
                and not isinstance(node.args[0], ast.Constant)
                and self.scopes):
            self.scopes[-1].sync_calls.append((node.lineno, "float()"))
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args
                and self.scopes):
            self.scopes[-1].sync_calls.append((node.lineno, ".item()"))
        if self._ckpt and self.scopes:
            self._check_dt104(node, dotted)
        self.generic_visit(node)

    def _check_dt104(self, node: ast.Call, dotted: Optional[str]) -> None:
        sc = self.scopes[-1]
        if dotted in PERSIST_WRITES:
            sc.writes.append((node.lineno, f"{dotted}()"))
        elif dotted in ATOMIC_RENAMES:
            sc.has_rename = True
        elif isinstance(node.func, ast.Attribute):
            if node.func.attr in PERSIST_WRITE_METHODS:
                sc.writes.append((node.lineno, f".{node.func.attr}()"))
            elif (node.func.attr == "replace" and dotted is None
                    and len(node.args) == 1):
                # Path.replace(target) is the same atomic rename syscall
                sc.has_rename = True

    def _check_dt101(self, node: ast.Call, dotted: str) -> None:
        if dotted == "numpy.random.default_rng":
            if not node.args and not node.keywords:
                self._emit(node, "DT101",
                           "np.random.default_rng() without a seed; pass "
                           "an explicit seed")
            return
        if dotted.startswith("numpy.random."):
            member = dotted.split(".", 2)[2].split(".")[0]
            if member not in NP_RANDOM_OK:
                self._emit(node, "DT101",
                           f"legacy global-RNG call {dotted}(); use "
                           "np.random.default_rng(seed)")
            return
        if dotted == "random.Random":
            if not node.args and not node.keywords:
                self._emit(node, "DT101",
                           "random.Random() without a seed; pass an "
                           "explicit seed")
            return
        if dotted.startswith("random."):
            member = dotted.split(".", 1)[1]
            if member in RANDOM_MODULE_FNS:
                self._emit(node, "DT101",
                           f"module-level {dotted}() draws from the "
                           "process-global RNG; use a seeded "
                           "random.Random(seed) instance")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def analyze_source(src: str, path: str) -> List[Finding]:
    """Run the determinism rules over one module's source text.  ``path``
    is the repo-relative path the findings (and DT102 exemptions) use."""
    v = _Visitor(path)
    v.visit(ast.parse(src, filename=path))
    return sorted(v.findings)


def analyze(root) -> List[Finding]:
    root = Path(root)
    out: List[Finding] = []
    for p in sorted((root / "src" / "repro").rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        out.extend(analyze_source(p.read_text(), rel))
    return out
