"""repro.analysis — static analysis that proves the repo's invariants
before runtime.

Four domain analyzers, each emitting :class:`~repro.analysis.findings.Finding`
rows with stable fingerprints (``code:path:context``) so justified
suppressions in ``tools/lint_baseline.json`` survive line drift:

- :mod:`~repro.analysis.kernel_contracts` (KC1xx) — symbolic
  BlockSpec/grid/VMEM audit of every Pallas kernel against the config
  registry's paper-scale shapes (the static form of
  ``tests/test_kernel_vmem.py``, generalized to all archs x shapes x
  dtypes).
- :mod:`~repro.analysis.determinism` (DT1xx) — unseeded RNGs, wall-clock
  reads outside ``repro.obs.trace``, host sync inside collective phases.
- :mod:`~repro.analysis.mesh_axes` (MX1xx) — literal collective axis
  names must be bound by a mesh declaration somewhere in the repo.
- :mod:`~repro.analysis.schema_drift` (SD1xx) — schema-id literals vs
  validators, ``HISTOGRAM_KEYS`` vs emitted metrics, goldens vs their
  validators.

``tools/repro_lint.py`` is the CLI/CI gate; ``docs/static_analysis.md``
is the rule catalogue.
"""
from repro.analysis.findings import (BASELINE_SCHEMA_ID, FINDINGS_SCHEMA_ID,
                                     Finding, apply_baseline, load_baseline,
                                     make_baseline, make_findings_payload,
                                     validate_baseline, validate_findings)

from repro.analysis import determinism, kernel_contracts, mesh_axes, \
    schema_drift  # noqa: E402  (analyzer modules re-exported as namespaces)

ANALYZERS = {
    "kernel": kernel_contracts.analyze,
    "determinism": determinism.analyze,
    "mesh": mesh_axes.analyze,
    "schema": schema_drift.analyze,
}


def run_analyzers(root, names=None):
    """Run the named analyzers (all by default) over the repo at ``root``;
    returns the combined sorted finding list."""
    out = []
    for name in names or sorted(ANALYZERS):
        out.extend(ANALYZERS[name](root))
    return sorted(out)


__all__ = [
    "ANALYZERS", "BASELINE_SCHEMA_ID", "FINDINGS_SCHEMA_ID", "Finding",
    "apply_baseline", "determinism", "kernel_contracts", "load_baseline",
    "make_baseline", "make_findings_payload", "mesh_axes", "run_analyzers",
    "schema_drift", "validate_baseline", "validate_findings",
]
