"""LLaVA-NeXT-34B — VLM: dense GQA language backbone consuming precomputed
patch embeddings (anyres tiling). [hf:llava-hf/llava-v1.6-mistral-7b-hf]
60L d_model=7168 56H GQA kv=8 d_ff=20480 vocab=64000. The ViT/SigLIP encoder +
projector is the modality-frontend stub (carve-out): ``input_specs`` supplies
(B, num_image_tokens, d_model) patch embeddings prepended to the text tokens.
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    pattern=(SlotSpec("attn", "dense"),),
    num_image_tokens=576,  # one anyres base tile (24x24 patches)
    rope_theta=1_000_000.0,
)
