"""Mamba2-780m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    pattern=(SlotSpec("mamba", "dense"),),  # mamba block has no separate MLP;
    # d_ff=0 makes the dense MLP a no-op passthrough (see blocks.py)
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
)
