"""Jamba-1.5-Large (398B) — hybrid Mamba+attention 1:7 with MoE every 2nd layer.

[arXiv:2403.19887] 72L d_model=8192, attn slots: 64H GQA kv=8; MoE 16 experts
top-2, d_ff=24576. Pattern cycle of 8: attn at slot 0, mamba at 1..7; MoE on
odd slots (every 2nd layer). Deviation: the mamba mixer uses Mamba-2 SSD (the
TPU/MXU-friendly dual form) instead of Mamba-1 — documented in DESIGN.md §8.
"""
from repro.configs.base import ModelConfig, SlotSpec

_CYCLE = tuple(
    SlotSpec("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_CYCLE,
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
)
