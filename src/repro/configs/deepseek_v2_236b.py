"""DeepSeek-V2 (236B) — MLA + fine-grained MoE. [arXiv:2405.04434]

60L d_model=5120, 128 heads MLA (kv_lora_rank=512, q_lora_rank=1536,
qk_nope=128, qk_rope=64, v=128); MoE: 160 routed experts top-6 + 2 shared,
expert d_ff=1536; layer 0 dense with d_ff=12288 (model card).
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head KV reconstructed from the latent
    head_dim=128,
    d_ff=12288,  # dense d_ff (first_k_dense layers)
    vocab_size=102400,
    pattern=(SlotSpec("mla", "moe"),),
    first_k_dense=1,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
)
