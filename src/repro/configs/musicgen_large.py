"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48L d_model=2048 32H (MHA, kv=32) d_ff=8192 vocab=2048,
4 EnCodec codebooks with delay interleave. The EnCodec codec itself is the
modality-frontend stub (carve-out): the decoder consumes/predicts the 4
codebook token streams directly.
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    pattern=(SlotSpec("attn", "dense"),),
    num_codebooks=4,
    rope_theta=10000.0,
)
