"""MiniCPM3-4B — small dense decoder with MLA. [hf:openbmb/MiniCPM3-4B]
62L d_model=2560 40H (MLA) d_ff=6400 vocab=73448; kv_lora_rank=256,
q_lora_rank=768, qk_nope=64, qk_rope=32, v=64.
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    pattern=(SlotSpec("mla", "dense"),),
    kv_lora_rank=256,
    q_lora_rank=768,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    tie_embeddings=True,
)
