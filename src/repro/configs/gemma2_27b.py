"""Gemma2-27B — dense GQA with alternating local(SWA-4096)/global attention and
logit softcapping. [arXiv:2408.00118]
46L d_model=4608 32H GQA kv=16 head_dim=128 d_ff=36864 vocab=256000.
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    source="arXiv:2408.00118",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=(SlotSpec("swa", "dense"), SlotSpec("attn", "dense")),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    use_post_norm=True,
    scale_embed=True,
)
