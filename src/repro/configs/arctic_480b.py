"""Snowflake Arctic (480B) — dense-MoE hybrid: 128-expert top-2 MoE in parallel
with a dense residual MLP on every layer. [hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H GQA kv=8 d_ff=4864 (both the dense residual and each
expert) vocab=32000.
"""
from repro.configs.base import ModelConfig, SlotSpec

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=(SlotSpec("attn", "moe_dense"),),
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
)
