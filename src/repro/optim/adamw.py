"""Functional optimizers with ZeRO-1-style sharded state.

The paper's parameter-server cluster maps onto the ``data`` mesh axis: each
data shard owns 1/N of the optimizer state ("pull" = all-gather of updated
params, "push" = reduce-scatter of grads — both inserted by GSPMD from the
sharding annotations). ``opt_sharding_rules`` therefore maps the ``embed``
logical axis onto the data-parallel axes unconditionally, even when the
bf16 compute params are not FSDP-sharded.

Optimizers: ``adamw`` (default) and ``momentum`` (the paper-era SGD+momentum;
planner falls back to it when Adam state cannot fit M_bound).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | momentum
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(opt: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    return opt.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def init_state(opt: OptConfig, params, *,
               error_feedback: bool = False) -> Dict[str, Any]:
    """``error_feedback=True`` adds an ``"ef"`` slot (zeros_like params) for
    gradient-compression residuals (repro.distributed.compression); it rides
    through :func:`apply_updates` untouched, like any extra state key."""
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    state: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if opt.kind == "adamw":
        state["m"] = zeros()
        state["v"] = zeros()
    elif opt.kind == "momentum":
        state["m"] = zeros()
    else:
        raise ValueError(opt.kind)
    if error_feedback:
        state["ef"] = zeros()
    return state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(opt: OptConfig, params, grads, state):
    """Returns (new_params, new_state, grad_norm). Grads may be bf16; state fp32."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if opt.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
    else:
        gnorm = jnp.float32(0)
    step = state["step"] + 1
    lr = schedule(opt, step)

    if opt.kind == "adamw":
        b1, b2 = opt.b1, opt.b2
        m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                                   state["m"], grads)
        v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                                   state["v"], grads)
        t = step.astype(jnp.float32)
        bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + opt.eps)
            return (p - lr * (u + opt.weight_decay * p)).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        new_state = dict(state, step=step, m=m, v=v)
        return new_params, new_state, gnorm

    # momentum SGD
    m = jax.tree_util.tree_map(lambda m_, g: opt.momentum * m_ + g,
                               state["m"], grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m_: (p - lr * (m_ + opt.weight_decay * p)).astype(p.dtype),
        params, m)
    return new_params, dict(state, step=step, m=m), gnorm
