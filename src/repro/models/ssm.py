"""Mamba-2 SSD (state-space duality) mixer — chunked-scan reference in pure
jnp (the Pallas kernel in ``repro.kernels.ssd_scan`` mirrors the same chunked
algorithm), plus O(1) single-token decode.

Block: in_proj -> [z | x | B | C | dt]; causal depthwise conv over (x,B,C);
SSD core y = SSD(a, dt*Bx, C) + D*x; gated RMSNorm(y * silu(z)); out_proj.
Group count G=1 (B/C shared across heads), as in Mamba-2 defaults.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, rms_norm, swish


def ssm_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv_width
    conv_ch = DI + 2 * N  # x, B, C share the conv
    L = (layers,)
    la = ("layers",)
    return {
        "w_z": ParamSpec(L + (D, DI), la + ("embed", "inner")),
        "w_xbc": ParamSpec(L + (D, DI + 2 * N), la + ("embed", "conv_ch")),
        "w_dt": ParamSpec(L + (D, H), la + ("embed", "ssm_heads")),
        "conv_w": ParamSpec(L + (W, conv_ch), la + (None, "conv_ch"), scale=3.0),
        "conv_b": ParamSpec(L + (conv_ch,), la + ("conv_ch",), init="zeros"),
        "a_log": ParamSpec(L + (H,), la + ("ssm_heads",), init="ssm_a"),
        "dt_bias": ParamSpec(L + (H,), la + ("ssm_heads",), init="ssm_dt"),
        "d_skip": ParamSpec(L + (H,), la + ("ssm_heads",), init="ones"),
        "gate_norm": ParamSpec(L + (DI,), la + ("inner",), init="zeros"),
        "w_out": ParamSpec(L + (DI, D), la + ("inner", "embed")),
    }


# ---------------------------------------------------------------------------
# SSD core — chunked reference
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, a_neg, b_mat, c_mat, chunk: int, h0=None):
    """SSD over a full sequence, chunked.

    x      (B, L, H, P)   per-head inputs
    dt     (B, L, H)      softplus'd step sizes (>=0)
    a_neg  (H,)           negative continuous-time decay (-exp(a_log))
    b_mat  (B, L, N)      input projection onto state  (G=1, shared over heads)
    c_mat  (B, L, N)      state readout
    h0     (B, H, N, P)   optional initial state
    returns y (B, L, H, P), h_final (B, H, N, P)
    """
    B, L, H, P = x.shape
    N = b_mat.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    loga = dt * a_neg  # (B, L, H) log per-step decay, <= 0
    xr = x.reshape(B, nc, Q, H, P)
    dtr = dt.reshape(B, nc, Q, H)
    logar = loga.reshape(B, nc, Q, H)
    br = b_mat.reshape(B, nc, Q, N)
    cr = c_mat.reshape(B, nc, Q, N)

    cl = jnp.cumsum(logar, axis=2)  # (B,nc,Q,H) inclusive cumsum of log decay
    # intra-chunk: Lmat[h,i,j] = exp(cl_i - cl_j) for i >= j (decay j+1..i)
    diff = cl[:, :, :, None, :] - cl[:, :, None, :, :]  # (B,nc,Q(i),Q(j),H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE the exp: for i < j the exponent cl_i - cl_j is positive and
    # can overflow to inf, which the where() would drop in the forward pass
    # but turn into 0 * inf = NaN in the backward pass
    lmat = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)  # (B,nc,Q,Q)
    w = cb[..., None] * lmat * dtr[:, :, None, :, :]  # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xr)

    # chunk-final partial states: S_c = sum_j exp(cl_Q - cl_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cl[:, :, -1:, :] - cl)  # (B,nc,Q,H)
    sx = xr * (decay_to_end * dtr)[..., None]  # (B,nc,Q,H,P)
    s_chunk = jnp.einsum("bcjn,bcjhp->bchnp", br, sx)  # (B,nc,H,N,P)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cl[:, :, -1, :])  # (B,nc,H) total decay of chunk

    def scan_fn(h, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        h_next = h * dec[..., None, None] + s_c.astype(h.dtype)
        return h_next, h

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_fin, h_prev = jax.lax.scan(
        scan_fn,
        h0.astype(jnp.float32),
        (s_chunk.transpose(1, 0, 2, 3, 4),
         chunk_decay.astype(jnp.float32).transpose(1, 0, 2)),
    )
    h_prev = h_prev.astype(x.dtype)
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state at chunk start

    # inter-chunk contribution: y_i += exp(cl_i) * C_i . h_chunk_start
    decay_from_start = jnp.exp(cl)  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", cr, h_prev) * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y, h_fin


def ssd_step(h, x_t, dt_t, a_neg, b_t, c_t):
    """Single-token SSD update.
    h (B,H,N,P), x_t (B,H,P), dt_t (B,H), b_t (B,N), c_t (B,N)."""
    dec = jnp.exp(dt_t * a_neg)  # (B,H)
    inject = jnp.einsum("bn,bhp->bhnp", b_t, x_t * dt_t[..., None])
    h = h * dec[..., None, None] + inject
    y = jnp.einsum("bn,bhnp->bhp", c_t, h)
    return y, h


# ---------------------------------------------------------------------------
# Mixer forward / decode
# ---------------------------------------------------------------------------


def _project(p, x):
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"]


def ssm_forward(p, x, positions, cfg: ModelConfig, *, impl="auto"):
    """Full-sequence mamba2 block. Returns (out, cache) with final state cache."""
    B, L, D = x.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width

    z, xbc_raw, dt_raw = _project(p, x)

    # causal depthwise conv over (x,B,C) channels
    pad = jnp.pad(xbc_raw, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + L] * p["conv_w"][i][None, None] for i in range(W)
    ) + p["conv_b"][None, None]
    xbc = swish(conv)

    xs = xbc[..., :DI].reshape(B, L, H, P)
    b_mat = xbc[..., DI : DI + N]
    c_mat = xbc[..., DI + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32)).astype(x.dtype)

    if impl == "pallas":
        from repro.kernels import ops as kops
        y, h_fin = kops.ssd_scan(xs, dt, a_neg, b_mat, c_mat, chunk=cfg.ssm_chunk)
    else:
        y, h_fin = ssd_chunked(xs, dt, a_neg, b_mat, c_mat, cfg.ssm_chunk)
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(B, L, DI)
    y = rms_norm(y * swish(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    # conv tail: last W-1 *pre-activation* (x,B,C) values, for decode continuation
    cache = {"state": h_fin, "conv": pad[:, L:]}
    return out, cache


def ssm_decode(p, x, pos, cache, cfg: ModelConfig):
    """Single-token mamba2 step. cache: state (B,H,N,P), conv (B,W-1,conv_ch)."""
    B = x.shape[0]
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width

    z, xbc_new, dt_raw = _project(p, x[:, 0])

    hist = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)  # (B,W,ch)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc = swish(conv)

    x_t = xbc[..., :DI].reshape(B, H, P)
    b_t = xbc[..., DI : DI + N]
    c_t = xbc[..., DI + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32)).astype(x.dtype)

    y, h = ssd_step(cache["state"], x_t, dt, a_neg, b_t, c_t)
    y = y + x_t * p["d_skip"][None, :, None]
    y = y.reshape(B, DI)
    y = rms_norm(y * swish(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None]
    return out, {"state": h, "conv": hist[:, 1:]}


def ssm_cache_specs(cfg: ModelConfig, layers: int, batch: int,
                    dtype: str = "bfloat16"):
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * N
    W = cfg.ssm_conv_width
    return {
        "state": ParamSpec((layers, batch, H, N, P),
                           ("layers", "batch", "ssm_heads", None, None),
                           dtype=dtype, init="zeros"),
        "conv": ParamSpec((layers, batch, W - 1, conv_ch),
                          ("layers", "batch", None, "conv_ch"),
                          dtype=dtype, init="zeros"),
    }
