"""Shared model machinery: ParamSpec trees (single source of truth for shapes,
init and logical sharding axes), norms, rope, softcap.

A model's ``param_specs(config)`` returns a pytree whose leaves are
:class:`ParamSpec`. The same tree is used to
  * materialize real parameters (``materialize(specs, key)``),
  * produce abstract ``jax.ShapeDtypeStruct`` stand-ins with shardings for the
    multi-pod dry-run (``abstractify(specs, mesh, rules)``),
  * derive per-parameter ``PartitionSpec`` from logical axis names
    (``partition_specs(specs, rules)``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    dtype: str = "float32"
    init: str = "normal"  # normal | zeros | ones | small_normal | ssm_a | ssm_dt
    scale: float = 1.0  # stddev multiplier / fan-in handled by caller

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def materialize(specs, key: jax.Array, dtype_override: Optional[str] = None):
    """Randomly initialize real parameters from a ParamSpec tree."""

    def init_leaf(path, spec: ParamSpec):
        dt = jnp.dtype(dtype_override or spec.dtype)
        # zlib.crc32, NOT hash(): python string hashing is randomized per
        # process (PYTHONHASHSEED), which would make init non-reproducible
        import zlib
        k = jax.random.fold_in(key, zlib.crc32(_path_str(path).encode()) % (2**31))
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "ssm_a":  # A_log init: log of uniform [1, 16]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if spec.init == "ssm_dt":  # dt_bias: softplus^-1 of uniform [1e-3, 0.1]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1e-3, 0.1)
            return (u + jnp.log(-jnp.expm1(-u))).astype(dt)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)

    return jax.tree_util.tree_map_with_path(init_leaf, specs, is_leaf=_is_spec)


def partition_specs(specs, rules: Dict[str, Any]):
    """Map logical axes -> mesh PartitionSpec via ``rules`` dict."""

    def leaf(spec: ParamSpec):
        return P(*(rules.get(a) if a is not None else None for a in spec.axes))

    return jax.tree_util.tree_map(leaf, specs, is_leaf=_is_spec)


def abstractify(specs, mesh, rules, dtype_override: Optional[str] = None):
    """ShapeDtypeStructs with NamedShardings attached (no allocation)."""
    pspecs = partition_specs(specs, rules)

    def leaf(spec: ParamSpec, ps):
        return jax.ShapeDtypeStruct(
            spec.shape,
            jnp.dtype(dtype_override or spec.dtype),
            sharding=NamedSharding(mesh, ps),
        )

    return jax.tree_util.tree_map(leaf, specs, pspecs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def param_bytes(specs, bytes_per_param: int = 2) -> int:
    return param_count(specs) * bytes_per_param


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D_rot); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swish(x):
    return x * jax.nn.sigmoid(x)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array,
                  logit_cap: float = 0.0) -> jax.Array:
    """Mean CE over mask. logits (..., V) f32-cast internally; labels int."""
    logits = softcap(logits.astype(jnp.float32), logit_cap)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
