"""Top-level decoder: embeddings (token / multi-codebook / VLM-prefix),
scan-over-cycles block stack, LM head, loss, and the three entry points

  * ``forward``      — full-sequence logits (+ prefill caches)
  * ``loss_fn``      — masked CE (+ MoE aux)
  * ``decode_step``  — single-token cached decoding

The stack is grouped by the config's layer-pattern *cycle*: parameters for
slot ``i`` are stacked over ``num_cycles`` and the decoder is a
``lax.scan`` over cycles, so HLO size is O(len(pattern)), not O(depth).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SlotSpec
from repro.models.blocks import (RunConfig, constrain, slot_cache_specs,
                                 slot_decode, slot_extend, slot_forward,
                                 slot_specs)
from repro.models.common import (ParamSpec, cross_entropy, rms_norm, softcap)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    V, D = cfg.padded_vocab, cfg.d_model
    s: Dict[str, Any] = {}
    if cfg.num_codebooks:
        s["embed"] = ParamSpec((cfg.num_codebooks, V, D), (None, "vocab", "embed"))
    else:
        s["embed"] = ParamSpec((V, D), ("vocab", "embed"))
    if cfg.first_k_dense:
        # prelude layers: same mixer as slot 0, dense MLP at cfg.d_ff
        pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")
        s["prelude"] = slot_specs(cfg, pre_slot, cfg.first_k_dense)
    cycles = (cfg.num_layers - cfg.first_k_dense) // len(cfg.pattern)
    s["slots"] = {
        f"slot{i}": slot_specs(cfg, slot, cycles)
        for i, slot in enumerate(cfg.pattern)
    }
    s["final_norm"] = ParamSpec((D,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            s["lm_head"] = ParamSpec((cfg.num_codebooks, D, V), (None, "embed", "vocab"))
        else:
            s["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    return s


def main_cycles(cfg: ModelConfig) -> int:
    return (cfg.num_layers - cfg.first_k_dense) // len(cfg.pattern)


def cache_specs(cfg: ModelConfig, batch: int, s_max: int,
                dtype: str = "bfloat16", kv_quant: bool = False) -> Dict[str, Any]:
    c: Dict[str, Any] = {}
    if cfg.first_k_dense:
        pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")
        c["prelude"] = slot_cache_specs(cfg, pre_slot, cfg.first_k_dense, batch,
                                        s_max, dtype, kv_quant)
    cycles = main_cycles(cfg)
    c["slots"] = {
        f"slot{i}": slot_cache_specs(cfg, slot, cycles, batch, s_max, dtype,
                                     kv_quant)
        for i, slot in enumerate(cfg.pattern)
    }
    return c


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        # (B,S,K) -> sum_k embed_k[token]
        parts = [
            jnp.take(params["embed"][k], tokens[..., k], axis=0)
            for k in range(cfg.num_codebooks)
        ]
        h = sum(parts)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if "image_embeds" in batch:
        h = jnp.concatenate([batch["image_embeds"].astype(h.dtype), h], axis=1)
    if cfg.scale_embed:
        h = h * np.sqrt(cfg.d_model)
    return h.astype(jnp.dtype(cfg.dtype))


def lm_logits(params, h, cfg: ModelConfig):
    if cfg.num_codebooks:
        w = (
            jnp.transpose(params["embed"], (0, 2, 1))
            if cfg.tie_embeddings
            else params["lm_head"]
        )
        logits = jnp.einsum("bsd,kdv->bskv", h, w)
    else:
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ w
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return softcap(logits, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _scan_cycles(params, h, positions, cfg, run, with_cache: bool):
    """Scan the main pattern cycles. Returns (h, caches, aux_total)."""
    slot_names = [f"slot{i}" for i in range(len(cfg.pattern))]
    stacked = {n: params["slots"][n] for n in slot_names}

    def cycle(h, cycle_params):
        caches, aux = {}, 0.0
        for n, slot in zip(slot_names, cfg.pattern):
            h, cache, a = slot_forward(cycle_params[n], h, positions, cfg, slot, run)
            caches[n] = cache
            aux = aux + a
        return h, (caches, aux)

    body = cycle
    if run.remat != "none":
        body = jax.checkpoint(cycle, prevent_cse=False)

    if run.unroll_layers:
        n = main_cycles(cfg)
        caches_list, aux_total = [], 0.0
        for i in range(n):
            cp = jax.tree_util.tree_map(lambda a: a[i], stacked)
            h, (c, aux) = body(h, cp)
            aux_total = aux_total + aux
            if with_cache:
                caches_list.append(c)
        caches = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches_list)
            if with_cache else None
        )
        return h, caches, aux_total

    def scan_body(h, cycle_params):
        h, (caches, aux) = body(h, cycle_params)
        return h, (caches if with_cache else None, aux)

    h, (caches, auxs) = jax.lax.scan(scan_body, h, stacked)
    return h, caches, jnp.sum(auxs) if np.ndim(auxs) else auxs


def cast_params(params, cfg: ModelConfig):
    """Compute-dtype view of the (fp32 master) parameters."""
    dt = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, params
    )


def forward(params, batch, cfg: ModelConfig, run: RunConfig,
            with_cache: bool = False):
    """Full-sequence forward. Returns (logits, caches, aux_loss)."""
    params = cast_params(params, cfg)
    h = embed_tokens(params, batch, cfg)
    h = constrain(h, run.act_sharding)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    pre_caches = None
    if cfg.first_k_dense:
        pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")

        def pre_cycle(h, layer_params):
            h, cache, _ = slot_forward(layer_params, h, positions, cfg, pre_slot, run)
            return h, cache if with_cache else None

        h, pre_caches = jax.lax.scan(pre_cycle, h, params["prelude"])

    h, caches, aux = _scan_cycles(params, h, positions, cfg, run, with_cache)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, h, cfg)
    # §Perf: keep logits sequence-sharded through the CE path (prevents a
    # full-vocab unsharded materialization, ~40 GB f32 for qwen2-72b train)
    logits = constrain(logits, run.logit_sharding)
    all_caches = {"slots": caches}
    if cfg.first_k_dense:
        all_caches["prelude"] = pre_caches
    return logits, (all_caches if with_cache else None), aux


def loss_fn(params, batch, cfg: ModelConfig, run: RunConfig,
            aux_weight: float = 0.01):
    """Masked next-token CE. ``labels`` < 0 are ignored. For VLM inputs the
    image-prefix positions carry no labels (mask handled via label padding)."""
    logits, _, aux = forward(params, batch, cfg, run)
    labels = batch["labels"]
    if "image_embeds" in batch:
        n_img = batch["image_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (n_img,) + labels.shape[2:], -1,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = (labels >= 0).astype(jnp.float32)
    ce = cross_entropy(logits, jnp.maximum(labels, 0), mask)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params, tokens, pos, caches, cfg: ModelConfig, run: RunConfig):
    """One decoding step.

    tokens (B,1) or (B,1,K) int32; pos (B,) int32 absolute positions;
    caches as produced by ``cache_specs``. Returns (logits, new_caches).
    """
    params = cast_params(params, cfg)
    h = embed_tokens(params, {"tokens": tokens}, cfg)
    B = h.shape[0]

    new_caches: Dict[str, Any] = {}
    if cfg.first_k_dense:
        pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")

        def pre_body(h, xs):
            layer_params, layer_cache = xs
            h, new_cache = slot_decode(layer_params, h, pos, layer_cache, cfg,
                                       pre_slot, run)
            return h, new_cache

        h, new_pre = jax.lax.scan(pre_body, h, (params["prelude"], caches["prelude"]))
        new_caches["prelude"] = new_pre

    slot_names = [f"slot{i}" for i in range(len(cfg.pattern))]
    stacked = ({n: params["slots"][n] for n in slot_names},
               {n: caches["slots"][n] for n in slot_names})

    def cycle(h, xs):
        cycle_params, cycle_cache = xs
        out_cache = {}
        for n, slot in zip(slot_names, cfg.pattern):
            h, nc = slot_decode(cycle_params[n], h, pos, cycle_cache[n], cfg,
                                slot, run)
            out_cache[n] = nc
        return h, out_cache

    if run.unroll_layers:
        outs = []
        for i in range(main_cycles(cfg)):
            xs_i = jax.tree_util.tree_map(lambda a: a[i], stacked)
            h, oc = cycle(h, xs_i)
            outs.append(oc)
        new_slot_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    else:
        h, new_slot_caches = jax.lax.scan(cycle, h, stacked)
    new_caches["slots"] = new_slot_caches

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, h, cfg)
    return logits, new_caches


def supports_extend(cfg: ModelConfig) -> bool:
    """Whether the config can run chunked prefill (``extend_step``):
    attention-only stacks.  Mamba state folds the whole prefix (no
    per-position cache to append to) and MLA decodes in absorbed-latent
    form — both fall back to whole-prompt prefill."""
    return all(s.mixer in ("attn", "swa") for s in cfg.pattern)


def extend_step(params, tokens, pos0, caches, cfg: ModelConfig,
                run: RunConfig):
    """Chunked prefill: append C prompt tokens to linear caches in one call.

    tokens (B,C) int32; pos0 (B,) absolute position of the chunk's first
    token; caches linear (non-ring) as placed by the serving engine.
    Returns (logits (B,C,V), new_caches) — logits[:, i] is the next-token
    distribution after absolute position pos0+i, identical to what a
    whole-prompt ``forward`` yields at that position.
    """
    if not supports_extend(cfg):
        raise NotImplementedError(
            f"{cfg.name}: chunked prefill needs an attention-only pattern")
    params = cast_params(params, cfg)
    h = embed_tokens(params, {"tokens": tokens}, cfg)

    slot_names = [f"slot{i}" for i in range(len(cfg.pattern))]
    stacked = ({n: params["slots"][n] for n in slot_names},
               {n: caches["slots"][n] for n in slot_names})

    def cycle(h, xs):
        cycle_params, cycle_cache = xs
        out_cache = {}
        for n, slot in zip(slot_names, cfg.pattern):
            h, nc = slot_extend(cycle_params[n], h, pos0, cycle_cache[n], cfg,
                                slot, run)
            out_cache[n] = nc
        return h, out_cache

    new_caches: Dict[str, Any] = {}
    if cfg.first_k_dense:
        pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")

        def pre_body(h, xs):
            layer_params, layer_cache = xs
            return slot_extend(layer_params, h, pos0, layer_cache, cfg,
                               pre_slot, run)

        h, new_pre = jax.lax.scan(pre_body, h,
                                  (params["prelude"], caches["prelude"]))
        new_caches["prelude"] = new_pre

    h, new_slot_caches = jax.lax.scan(cycle, h, stacked)
    new_caches["slots"] = new_slot_caches

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, h, cfg)
    return logits, new_caches
