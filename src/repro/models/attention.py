"""Attention mixers: GQA (optionally sliding-window / softcapped) and MLA
(DeepSeek-V2 multi-head latent attention), each with

  * full-sequence path (train / prefill)  — ``dense`` or ``chunked`` impl
    (chunked = online-softmax scan over KV blocks: the XLA flash-attention
    reference; the Pallas kernel in ``repro.kernels`` mirrors its math), and
  * cached single-token decode path (MLA uses the absorbed-latent form).

Shapes: x (B, S, D); caches are per-slot dicts of (B, S_max, ...) arrays.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, rope, softcap

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def gqa_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = (layers,)
    la = ("layers",)
    s = {
        "wq": ParamSpec(L + (D, H, hd), la + ("embed", "q_heads", None)),
        "wk": ParamSpec(L + (D, KV, hd), la + ("embed", "kv_heads", None)),
        "wv": ParamSpec(L + (D, KV, hd), la + ("embed", "kv_heads", None)),
        "wo": ParamSpec(L + (H, hd, D), la + ("q_heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec(L + (H, hd), la + ("q_heads", None), init="zeros")
        s["bk"] = ParamSpec(L + (KV, hd), la + ("kv_heads", None), init="zeros")
        s["bv"] = ParamSpec(L + (KV, hd), la + ("kv_heads", None), init="zeros")
    return s


def mla_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    D, H = cfg.d_model, cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    L = (layers,)
    la = ("layers",)
    return {
        "wq_down": ParamSpec(L + (D, qlr), la + ("embed", "lora")),
        "q_norm": ParamSpec(L + (qlr,), la + ("lora",), init="zeros"),
        "wq_up": ParamSpec(L + (qlr, H, nope + rdim), la + ("lora", "q_heads", None)),
        "wkv_down": ParamSpec(L + (D, kvlr + rdim), la + ("embed", None)),
        "kv_norm": ParamSpec(L + (kvlr,), la + (None,), init="zeros"),
        "wkv_up": ParamSpec(L + (kvlr, H, nope + vdim), la + (None, "q_heads", None)),
        "wo": ParamSpec(L + (H, vdim, D), la + ("q_heads", None, "embed")),
    }


def attn_specs(cfg: ModelConfig, mixer: str, layers: int) -> Dict[str, ParamSpec]:
    return mla_specs(cfg, layers) if mixer.startswith("mla") else gqa_specs(cfg, layers)


# ---------------------------------------------------------------------------
# Core attention math (shared by dense / chunked)
# ---------------------------------------------------------------------------


def _mask(q_pos, k_pos, window: int):
    """(..., Sq, Sk) boolean mask: causal + optional sliding window.
    Negative k_pos marks invalid (unwritten ring-buffer) slots."""
    m = (k_pos[..., None, :] <= q_pos[..., :, None]) & (k_pos[..., None, :] >= 0)
    if window:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return m


def dense_attention(q, k, v, q_pos, k_pos, *, scale, window=0, cap=0.0):
    """q (B,Sq,H,dk), k (B,Sk,KV,dk), v (B,Sk,KV,dv); GQA via head repeat."""
    B, Sq, H, dk = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dk)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    m = _mask(q_pos, k_pos, window)[:, None, None]  # (B,1,1,Sq,Sk)
    logits = jnp.where(m, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(q, k, v, q_pos, k_pos, *, scale, window=0, cap=0.0,
                      kv_block=1024, q_block=2048, unroll_kv=False):
    """Triangular blocked online-softmax attention — the XLA flash reference.

    Outer *unrolled* loop over query blocks (so each block sees a static KV
    prefix: no wasted FLOPs on fully-masked future blocks; sliding windows
    also bound the prefix from below); inner ``lax.scan`` over KV blocks with
    running (max, denom, acc). Live memory is O(q_block * kv_block * H)."""
    B, Sq, H, dk = q.shape
    Sk, KV, dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // KV
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    q_pad = -Sq % q_block
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, q_pad)), constant_values=-1)
    k_pad = -Sk % kv_block
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, k_pad)), constant_values=2**30)
    Sk_p = Sk + k_pad

    def one_q_block(qi: int):
        q_lo, q_hi = qi * q_block, (qi + 1) * q_block
        qg = (q[:, q_lo:q_hi].reshape(B, q_block, KV, G, dk) * scale)
        qp = q_pos[:, q_lo:q_hi]
        # static KV range this q block can see (assumes monotone positions:
        # q_pos = offset + arange, which holds for train/prefill paths)
        kv_hi = min(-(-q_hi // kv_block) * kv_block, Sk_p)
        kv_lo = 0
        if window:
            kv_lo = max(0, (q_lo - window) // kv_block * kv_block)
        nblk = (kv_hi - kv_lo) // kv_block
        kb = k[:, kv_lo:kv_hi].reshape(B, nblk, kv_block, KV, dk).transpose(1, 0, 2, 3, 4)
        vb = v[:, kv_lo:kv_hi].reshape(B, nblk, kv_block, KV, dv).transpose(1, 0, 2, 3, 4)
        pb = k_pos[:, kv_lo:kv_hi].reshape(B, nblk, kv_block).transpose(1, 0, 2)

        def step(carry, blk):
            m_run, l_run, acc = carry
            kc, vc, pc = blk
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32)
            logits = softcap(logits, cap)
            msk = _mask(qp, pc, window)[:, None, None]
            logits = jnp.where(msk, logits, NEG_INF)
            m_blk = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m_run, m_blk)
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, dv), jnp.float32)
        if unroll_kv:
            # counting mode for the dry-run FLOP accounting: XLA's
            # cost_analysis does not multiply while-body costs by trip count,
            # so the roofline lowers use a physically-unrolled KV loop.
            carry = (m0, l0, a0)
            for t in range(nblk):
                carry, _ = step(carry, (kb[t], vb[t], pb[t]))
            m_f, l_f, acc = carry
        else:
            (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, dv)

    blocks = [one_q_block(i) for i in range((Sq + q_pad) // q_block)]
    out = jnp.concatenate(blocks, axis=1) if len(blocks) > 1 else blocks[0]
    return out[:, :Sq].astype(v.dtype)


def attention(q, k, v, q_pos, k_pos, *, scale, window=0, cap=0.0,
              impl="auto", kv_block=1024):
    if impl == "pallas":
        # TPU production path; falls back to chunked under jit on CPU.
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos, scale=scale,
                                    window=window, cap=cap)
    if impl == "counting":
        # dry-run FLOP-accounting mode: big unrolled blocks, no while loops
        return chunked_attention(q, k, v, q_pos, k_pos, scale=scale,
                                 window=window, cap=cap, kv_block=8192,
                                 q_block=8192, unroll_kv=True)
    if impl == "auto":
        impl = "chunked" if k.shape[1] > 2048 else "dense"
    f = dense_attention if impl == "dense" else chunked_attention
    kw = {} if impl == "dense" else {"kv_block": kv_block}
    return f(q, k, v, q_pos, k_pos, scale=scale, window=window, cap=cap, **kw)


# ---------------------------------------------------------------------------
# GQA mixer
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, mixer: str) -> int:
    if mixer in ("swa", "mla_swa"):
        return cfg.sliding_window
    return cfg.attn_window_override  # 0 unless long-context SWA variant


def gqa_forward(p, x, positions, cfg: ModelConfig, mixer: str, *,
                impl="auto") -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = attention(
        q, k, v, positions, positions,
        scale=1.0 / np.sqrt(cfg.head_dim),
        window=_window_for(cfg, mixer),
        cap=cfg.attn_softcap,
        impl=impl,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": k, "v": v}


def quantize_kv(x):
    """Per-(token, head) int8 quantization: x (B,1,KV,hd) ->
    (int8 values, f32 scales (B,1,KV))."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


def dequantize_kv(q, s, dtype):
    return (q.astype(jnp.float32) * s[..., None].astype(jnp.float32)).astype(dtype)


def gqa_decode(p, x, pos, cache, cfg: ModelConfig, mixer: str,
               scatter: bool = False):
    """x (B,1,D); pos (B,) int32 current position; cache dict k/v (B,Smax,KV,hd).
    If the cache carries ``k_scale``/``v_scale`` it is int8-quantized (§Perf:
    halves decode cache bytes vs bf16; per-token-per-head scales)."""
    B = x.shape[0]
    quant = "k_scale" in cache
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    window = _window_for(cfg, mixer)
    wpos, k_pos = _ring_positions(pos, cache["k"].shape[1], window, B)
    write = _cache_write_scatter if (scatter or quant) else _cache_write
    new_cache = {}
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ckq = write(cache["k"], kq, wpos)
        cvq = write(cache["v"], vq, wpos)
        cks = write(cache["k_scale"], ks, wpos)
        cvs = write(cache["v_scale"], vs, wpos)
        ck = dequantize_kv(ckq, cks, x.dtype)
        cv = dequantize_kv(cvq, cvs, x.dtype)
        new_cache = {"k": ckq, "v": cvq, "k_scale": cks, "v_scale": cvs}
    else:
        ck = write(cache["k"], k, wpos)
        cv = write(cache["v"], v, wpos)
        new_cache = {"k": ck, "v": cv}
    out = attention(
        q, ck, cv, pos[:, None], k_pos,
        scale=1.0 / np.sqrt(cfg.head_dim),
        window=window,
        cap=cfg.attn_softcap,
        impl="dense",  # single query: dense == flash-decoding after SPMD
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def gqa_extend(p, x, pos0, cache, cfg: ModelConfig, mixer: str):
    """Chunked-prefill extension: append a chunk of C tokens to a *linear*
    cache.  x (B,C,D); pos0 (B,) absolute position of the chunk's first
    token; cache dict k/v (B,Smax,KV,hd), non-ring, bf16 (int8-quantized
    caches are a decode-path option and unsupported here).

    Equivalent to running prefill over prompt[:pos0+C] and keeping the last
    C outputs: the chunk attends causally to the cache (which holds every
    earlier position at its own slot) plus itself."""
    B, C = x.shape[:2]
    positions = pos0[:, None] + jnp.arange(C)[None]  # (B, C)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    ck = _cache_write_chunk(cache["k"], k, positions)
    cv = _cache_write_chunk(cache["v"], v, positions)
    s_cache = ck.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s_cache)[None], (B, s_cache))
    out = attention(
        q, ck, cv, positions, k_pos,
        scale=1.0 / np.sqrt(cfg.head_dim),
        window=_window_for(cfg, mixer),
        cap=cfg.attn_softcap,
        impl="dense",
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": ck, "v": cv}


def _cache_write_chunk(cache, new, positions):
    """Write new (B,C,...) into cache (B,Smax,...) at per-example positions
    (B,C) — the multi-token scatter behind chunked prefill."""
    b_idx = jnp.arange(cache.shape[0])[:, None]
    return cache.at[b_idx, positions].set(new.astype(cache.dtype))


def _ring_positions(pos, s_cache: int, window: int, batch: int):
    """Write index + absolute positions held by each cache slot.

    If the cache is window-sized (ring buffer for SWA slots), slot j holds
    absolute position pos - ((pos - j) mod S); unwritten slots come out
    negative and are masked. Otherwise the cache is linear: slot j = pos j."""
    ring = bool(window) and s_cache <= window
    j = jnp.arange(s_cache)[None]
    if ring:
        wpos = pos % s_cache
        k_pos = pos[:, None] - jnp.mod(pos[:, None] - j, s_cache)
    else:
        wpos = pos
        k_pos = jnp.broadcast_to(j, (batch, s_cache))
    return wpos, k_pos


def _cache_write_scatter(cache, new, pos):
    """In-place-friendly scatter write (§Perf): one row per example instead
    of the one-hot blend (which reads+writes the whole cache twice)."""
    b_idx = jnp.arange(cache.shape[0])
    return cache.at[b_idx, pos].set(new[:, 0].astype(cache.dtype))


def _cache_write(cache, new, pos):
    """Write new (B,1,...) into cache (B,Smax,...) at per-example pos (B,)."""
    B = cache.shape[0]
    oh = jax.nn.one_hot(pos, cache.shape[1], dtype=cache.dtype)  # (B, Smax)
    oh = oh.reshape((B, cache.shape[1]) + (1,) * (cache.ndim - 2))
    return cache * (1 - oh) + oh * new[:, 0][:, None]


# ---------------------------------------------------------------------------
# MLA mixer
# ---------------------------------------------------------------------------


def _mla_qkv(p, x, positions, cfg: ModelConfig):
    from repro.models.common import rms_norm

    nope, rdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(x @ p["wq_down"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_up"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["wkv_down"]  # (B,S,kvlr+rdim)
    ckv, k_rope = ckv_full[..., : cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_forward(p, x, positions, cfg: ModelConfig, mixer: str, *, impl="auto"):
    """Full-sequence MLA: reconstruct per-head K/V from the latent (train/prefill)."""
    nope, vdim = cfg.qk_nope_head_dim, cfg.v_head_dim
    H = cfg.num_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, positions, cfg)
    kv = jnp.einsum("bsl,lhk->bshk", ckv, p["wkv_up"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], k_nope.shape[:3] + (q_rope.shape[-1],))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(
        q, k, v, positions, positions,
        scale=1.0 / np.sqrt(nope + cfg.qk_rope_head_dim),
        window=_window_for(cfg, mixer),
        cap=cfg.attn_softcap,
        impl=impl,
    )
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), {"ckv": ckv, "k_rope": k_rope}


def mla_decode(p, x, pos, cache, cfg: ModelConfig, mixer: str,
               scatter: bool = False):
    """Absorbed-latent decode: attend in the compressed kv_lora space.
    cache: ckv (B,Smax,kvlr), k_rope (B,Smax,rdim)."""
    nope = cfg.qk_nope_head_dim
    B = x.shape[0]
    q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv(p, x, pos[:, None], cfg)
    window = _window_for(cfg, mixer)
    wpos, k_pos = _ring_positions(pos, cache["ckv"].shape[1], window, B)
    write = _cache_write_scatter if scatter else _cache_write
    ckv = write(cache["ckv"], ckv_new, wpos)
    krope = write(cache["k_rope"], k_rope_new, wpos)

    w_uk = p["wkv_up"][..., :nope]  # (kvlr, H, nope)
    w_uv = p["wkv_up"][..., nope:]  # (kvlr, H, vdim)
    q_abs = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)  # absorbed query
    scale = 1.0 / np.sqrt(nope + cfg.qk_rope_head_dim)
    logits = (
        jnp.einsum("bshl,bkl->bhsk", q_abs, ckv)
        + jnp.einsum("bshr,bkr->bhsk", q_rope, krope)
    ).astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    m = _mask(pos[:, None], k_pos, window)[:, None]
    logits = jnp.where(m, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bhsk,bkl->bshl", probs, ckv)  # latent context
    out = jnp.einsum("bshl,lhv->bshv", ctx, w_uv)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), {"ckv": ckv, "k_rope": krope}


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------


def attn_cache_specs(cfg: ModelConfig, mixer: str, layers: int, batch: int,
                     s_max: int, dtype: str = "bfloat16",
                     kv_quant: bool = False):
    """ParamSpec-style descriptors for the per-slot KV cache (stacked layers).
    ``kv_quant``: int8 values + per-(token, head) f32 scales (GQA only)."""
    L = (layers, batch)
    la = ("layers", "batch")
    if mixer.startswith("mla"):
        return {
            "ckv": ParamSpec(L + (s_max, cfg.kv_lora_rank), la + ("kv_seq", None),
                             dtype=dtype, init="zeros"),
            "k_rope": ParamSpec(L + (s_max, cfg.qk_rope_head_dim),
                                la + ("kv_seq", None), dtype=dtype, init="zeros"),
        }
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    vdt = "int8" if kv_quant else dtype
    specs = {
        "k": ParamSpec(L + (s_max, KV, hd), la + ("kv_seq", None, None),
                       dtype=vdt, init="zeros"),
        "v": ParamSpec(L + (s_max, KV, hd), la + ("kv_seq", None, None),
                       dtype=vdt, init="zeros"),
    }
    if kv_quant:
        specs["k_scale"] = ParamSpec(L + (s_max, KV), la + ("kv_seq", None),
                                     dtype="float32", init="zeros")
        specs["v_scale"] = ParamSpec(L + (s_max, KV), la + ("kv_seq", None),
                                     dtype="float32", init="zeros")
    return specs
