"""Block (slot) composition: pre-norm mixer + residual, pre-norm MLP + residual,
optional post-norms (gemma2). Dispatches on SlotSpec (mixer, mlp)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax

from repro.configs.base import ModelConfig, SlotSpec
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import ParamSpec, rms_norm


@dataclass
class RunConfig:
    """Runtime (non-architecture) knobs — what the paper's planner tunes."""

    attn_impl: str = "auto"  # dense | chunked | pallas | auto
    remat: str = "block"  # none | block
    seq_parallel: bool = False
    microbatch: int = 0  # >0: gradient-accumulation microbatch size
    capacity_factor: float = 1.25
    # concrete NamedShardings injected by the launcher (None on single host):
    act_sharding: Any = None  # residual stream (B, S, D)
    kv_block: int = 1024
    q_block: int = 2048
    # dry-run FLOP-accounting mode: python-unroll the layer loops so that
    # cost_analysis (which ignores while-loop trip counts) sees every op
    unroll_layers: bool = False
    # --- beyond-paper optimizations (§Perf), all off by default ---
    logit_sharding: Any = None  # keep logits seq-sharded through the CE path
    moe_mesh: Any = None  # shard_map expert-parallel MoE over this mesh
    moe_axis: str = "model"  # expert axis name within moe_mesh
    pad_heads_to: int = 0  # zero-pad Q heads so TP divides them (llava/arctic)
    grad_shardings: Any = None  # pytree of NamedShardings: force reduce-scatter
    # grad sync onto the ZeRO layout instead of GSPMD's all-reduce choice
    cache_scatter: bool = False  # decode cache write via scatter, not one-hot
    bf16_grads: bool = False  # mixed precision: grads computed/synced in bf16


def constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def slot_specs(cfg: ModelConfig, slot: SlotSpec, layers: int) -> Dict[str, Any]:
    la = ("layers",)
    L = (layers,)
    s: Dict[str, Any] = {
        "mixer_norm": ParamSpec(L + (cfg.d_model,), la + ("embed",), init="zeros"),
    }
    if slot.mixer == "mamba":
        s["mixer"] = ssm_lib.ssm_specs(cfg, layers)
    else:
        s["mixer"] = attn.attn_specs(cfg, slot.mixer, layers)
    if cfg.use_post_norm:
        s["mixer_post_norm"] = ParamSpec(L + (cfg.d_model,), la + ("embed",), init="zeros")

    has_mlp = not (slot.mlp == "dense" and cfg.d_ff == 0)
    if has_mlp:
        s["mlp_norm"] = ParamSpec(L + (cfg.d_model,), la + ("embed",), init="zeros")
        if slot.mlp == "dense":
            s["mlp"] = moe_lib.dense_mlp_specs(cfg.d_model, cfg.d_ff, layers)
        elif slot.mlp == "moe":
            s["mlp"] = moe_lib.moe_specs(cfg, layers)
        else:  # moe_dense: arctic — parallel dense residual + MoE
            s["mlp"] = {
                "dense": moe_lib.dense_mlp_specs(cfg.d_model, cfg.d_ff, layers),
                "moe": moe_lib.moe_specs(cfg, layers),
            }
        if cfg.use_post_norm:
            s["mlp_post_norm"] = ParamSpec(L + (cfg.d_model,), la + ("embed",), init="zeros")
    return s


# ---------------------------------------------------------------------------
# Forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _mixer_forward(p, h, positions, cfg, slot: SlotSpec, run: RunConfig):
    if slot.mixer == "mamba":
        return ssm_lib.ssm_forward(p, h, positions, cfg, impl="auto")
    if slot.mixer.startswith("mla"):
        return attn.mla_forward(p, h, positions, cfg, slot.mixer, impl=run.attn_impl)
    return attn.gqa_forward(p, h, positions, cfg, slot.mixer, impl=run.attn_impl)


def _mlp_forward(p, h, cfg, slot: SlotSpec, run: RunConfig):
    if slot.mlp == "dense":
        return moe_lib.dense_mlp(p, h), 0.0
    moe_fn = moe_lib.moe_mlp
    kw = dict(capacity_factor=run.capacity_factor)
    if run.moe_mesh is not None:
        moe_fn = moe_lib.moe_mlp_sharded
        kw.update(mesh=run.moe_mesh, axis=run.moe_axis)
    if slot.mlp == "moe":
        return moe_fn(p, h, cfg, **kw)
    y_moe, aux = moe_fn(p["moe"], h, cfg, **kw)
    return moe_lib.dense_mlp(p["dense"], h) + y_moe, aux


def slot_forward(p, h, positions, cfg: ModelConfig, slot: SlotSpec, run: RunConfig):
    """Returns (h, cache, aux_loss)."""
    resid = h
    u = rms_norm(h, p["mixer_norm"], cfg.norm_eps)
    u, cache = _mixer_forward(p["mixer"], u, positions, cfg, slot, run)
    if cfg.use_post_norm:
        u = rms_norm(u, p["mixer_post_norm"], cfg.norm_eps)
    h = constrain(resid + u, run.act_sharding)

    aux = 0.0
    if "mlp_norm" in p:
        resid = h
        u = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        u, aux = _mlp_forward(p["mlp"], u, cfg, slot, run)
        if cfg.use_post_norm:
            u = rms_norm(u, p["mlp_post_norm"], cfg.norm_eps)
        h = constrain(resid + u, run.act_sharding)
    return h, cache, aux


# ---------------------------------------------------------------------------
# Decode (single token, cached)
# ---------------------------------------------------------------------------


def _mixer_decode(p, h, pos, cache, cfg, slot: SlotSpec, run: RunConfig):
    if slot.mixer == "mamba":
        return ssm_lib.ssm_decode(p, h, pos, cache, cfg)
    if slot.mixer.startswith("mla"):
        return attn.mla_decode(p, h, pos, cache, cfg, slot.mixer,
                               scatter=run.cache_scatter)
    return attn.gqa_decode(p, h, pos, cache, cfg, slot.mixer,
                           scatter=run.cache_scatter)


def slot_decode(p, h, pos, cache, cfg: ModelConfig, slot: SlotSpec, run: RunConfig):
    resid = h
    u = rms_norm(h, p["mixer_norm"], cfg.norm_eps)
    u, new_cache = _mixer_decode(p["mixer"], u, pos, cache, cfg, slot, run)
    if cfg.use_post_norm:
        u = rms_norm(u, p["mixer_post_norm"], cfg.norm_eps)
    h = resid + u
    if "mlp_norm" in p:
        resid = h
        u = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        u, _ = _mlp_forward(p["mlp"], u, cfg, slot, run)
        if cfg.use_post_norm:
            u = rms_norm(u, p["mlp_post_norm"], cfg.norm_eps)
        h = resid + u
    return h, new_cache


# ---------------------------------------------------------------------------
# Extend (multi-token cache append — chunked prefill)
# ---------------------------------------------------------------------------


def _mixer_extend(p, h, pos0, cache, cfg, slot: SlotSpec, run: RunConfig):
    if slot.mixer == "mamba" or slot.mixer.startswith("mla"):
        raise NotImplementedError(
            f"chunked prefill is attention-only; {slot.mixer!r} slots use "
            f"whole-prompt prefill (model.supports_extend gates this)")
    return attn.gqa_extend(p, h, pos0, cache, cfg, slot.mixer)


def slot_extend(p, h, pos0, cache, cfg: ModelConfig, slot: SlotSpec,
                run: RunConfig):
    """slot_decode's multi-token sibling: h (B,C,D), pos0 (B,) chunk start."""
    resid = h
    u = rms_norm(h, p["mixer_norm"], cfg.norm_eps)
    u, new_cache = _mixer_extend(p["mixer"], u, pos0, cache, cfg, slot, run)
    if cfg.use_post_norm:
        u = rms_norm(u, p["mixer_post_norm"], cfg.norm_eps)
    h = resid + u
    if "mlp_norm" in p:
        resid = h
        u = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        u, _ = _mlp_forward(p["mlp"], u, cfg, slot, run)
        if cfg.use_post_norm:
            u = rms_norm(u, p["mlp_post_norm"], cfg.norm_eps)
        h = resid + u
    return h, new_cache


def slot_cache_specs(cfg: ModelConfig, slot: SlotSpec, layers: int, batch: int,
                     s_max: int, dtype: str = "bfloat16",
                     kv_quant: bool = False):
    if slot.mixer == "mamba":
        return ssm_lib.ssm_cache_specs(cfg, layers, batch, dtype)
    window = attn._window_for(cfg, slot.mixer)
    eff = min(s_max, window) if window else s_max
    quant = kv_quant and not slot.mixer.startswith("mla")  # MLA stays bf16
    return attn.attn_cache_specs(cfg, slot.mixer, layers, batch, eff, dtype,
                                 kv_quant=quant)
