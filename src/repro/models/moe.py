"""Mixture-of-Experts MLP with sort-based capacity dispatch.

Dispatch is gather/scatter (memory ops), NOT one-hot einsum — a one-hot
dispatch matmul would inject O(T·E·C·D) fake FLOPs into the HLO and poison
the roofline compute term. Expert compute is a grouped einsum
``ecd,edf->ecf`` whose FLOP count equals the true active-expert FLOPs at
capacity factor 1.0.

Experts are sharded on the mesh "model" axis (expert parallelism); the
scatter/gather into the (E, C, D) buffer is GSPMD's all-to-all analogue.
Also provides the plain dense (SwiGLU) MLP and arctic's parallel
dense+MoE residual form.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, swish


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------


def dense_mlp_specs(d_model: int, d_ff: int, layers: int) -> Dict[str, ParamSpec]:
    L, la = (layers,), ("layers",)
    return {
        "w_gate": ParamSpec(L + (d_model, d_ff), la + ("embed", "ff")),
        "w_up": ParamSpec(L + (d_model, d_ff), la + ("embed", "ff")),
        "w_down": ParamSpec(L + (d_ff, d_model), la + ("ff", "embed")),
    }


def dense_mlp(p, x):
    return (swish(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    L, la = (layers,), ("layers",)
    s = {
        "router": ParamSpec(L + (D, E), la + ("embed", None), scale=0.1),
        "w_gate": ParamSpec(L + (E, D, F), la + ("experts", "embed", None)),
        "w_up": ParamSpec(L + (E, D, F), la + ("experts", "embed", None)),
        "w_down": ParamSpec(L + (E, F, D), la + ("experts", None, "embed")),
    }
    if cfg.num_shared_experts:
        s["shared"] = dense_mlp_specs(D, cfg.moe_d_ff * cfg.num_shared_experts, layers)
    return s


def _router_topk(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits (T, E) -> (weights (T,k), experts (T,k) int32, aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    onehot = jax.nn.one_hot(idx[:, 0], E)  # fraction routed (top-1 proxy)
    fe = jnp.mean(onehot, axis=0)
    aux = E * jnp.sum(fe * me)
    return w, idx, aux


def moe_mlp(p, x, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """x (B, S, D) -> (B, S, D); sort-based dispatch with per-expert capacity."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    w, idx, aux = _router_topk(xf @ p["router"], K)  # (T,K)

    C = int(capacity_factor * T * K / E) + 1
    C = max(C, 4)

    # flatten (token, k) assignments and sort by expert
    flat_e = idx.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each assignment within its expert group
    expert_start = jnp.searchsorted(se, jnp.arange(E))  # (E,)
    pos = jnp.arange(T * K) - expert_start[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)  # overflow -> dropped row

    # dispatch: buffer (E*C+1, D); last row is the drop bin
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xf[st])
    h = buf[: E * C].reshape(E, C, D)
    y = (
        jnp.einsum("ecf,efd->ecd",
                   swish(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
                   * jnp.einsum("ecd,edf->ecf", h, p["w_up"]),
                   p["w_down"])
    )
    y = jnp.concatenate([y.reshape(E * C, D), jnp.zeros((1, D), y.dtype)], axis=0)

    # combine
    out = jnp.zeros((T, D), jnp.float32).at[st].add(
        (y[slot] * jnp.where(keep, sw, 0.0)[:, None]).astype(jnp.float32)
    )
    out = out.astype(x.dtype).reshape(B, S, D)
    if cfg.num_shared_experts:
        out = out + dense_mlp(p["shared"], x)
    return out, aux


def _local_expert_pass(xf, router_w, wg, wu, wd, cfg: ModelConfig,
                       capacity_factor: float, e_lo, e_loc: int):
    """Tokens xf (T, D) through the LOCAL experts [e_lo, e_lo + e_loc) only
    (e_lo may be a traced axis_index; e_loc is static). Returns
    (partial_out (T, D) f32, aux); the caller reduces across expert shards."""
    T, D = xf.shape
    E, K = cfg.num_experts, cfg.top_k
    E_loc = e_loc
    w, idx, aux = _router_topk(xf @ router_w, K)

    C = int(capacity_factor * T * K / E) + 1
    C = max(C, 4)

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    expert_start = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * K) - expert_start[se]
    local = (se >= e_lo) & (se < e_lo + E_loc) & (pos < C)
    slot = jnp.where(local, (se - e_lo) * C + pos, E_loc * C)

    buf = jnp.zeros((E_loc * C + 1, D), xf.dtype).at[slot].set(xf[st])
    h = buf[: E_loc * C].reshape(E_loc, C, D)
    y = jnp.einsum(
        "ecf,efd->ecd",
        swish(jnp.einsum("ecd,edf->ecf", h, wg))
        * jnp.einsum("ecd,edf->ecf", h, wu),
        wd)
    y = jnp.concatenate([y.reshape(E_loc * C, D),
                         jnp.zeros((1, D), y.dtype)], axis=0)
    out = jnp.zeros((T, D), jnp.float32).at[st].add(
        (y[slot] * jnp.where(local, sw, 0.0)[:, None]).astype(jnp.float32))
    return out, aux


def moe_mlp_sharded(p, x, cfg: ModelConfig, *, mesh, axis: str = "model",
                    capacity_factor: float = 1.25):
    """Expert-parallel MoE via shard_map (§Perf optimization).

    The baseline ``moe_mlp`` scatters into an expert-sharded buffer, which
    GSPMD lowers to replicated scatters + giant all-reduces. Here each
    expert shard all-gathers the (sequence-sharded) tokens once, runs ONLY
    its local experts with local scatters, and the partial outputs are
    combined with one reduce-scatter back to the sequence-sharded layout:
    exactly 2 collectives per MoE layer instead of GSPMD's emergent storm.
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    B, S, D = x.shape
    tp = mesh.shape[axis]
    E = cfg.num_experts
    assert E % tp == 0, (E, tp)
    E_loc = E // tp
    dp = tuple(a for a in mesh.axis_names if a != axis)

    def body(xl, router_w, wg, wu, wd):
        # xl (B_loc, S/tp, D) -> gather full local-replica token set
        x_full = jax.lax.all_gather(xl, axis, axis=1, tiled=True)  # (B_loc,S,D)
        Bl, Sl, _ = x_full.shape
        xf = x_full.reshape(Bl * Sl, D)
        eidx = jax.lax.axis_index(axis)
        out, aux = _local_expert_pass(
            xf, router_w, wg, wu, wd, cfg, capacity_factor,
            e_lo=eidx * E_loc, e_loc=E_loc)
        out = out.reshape(Bl, Sl, D).astype(x.dtype)
        # sum partials across expert shards, landing seq-sharded again
        out = jax.lax.psum_scatter(out, axis, scatter_dimension=1, tiled=True)
        aux = jax.lax.pmean(aux, axis)
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, axis, None), P(), P(axis, None, None),
                  P(axis, None, None), P(axis, None, None)),
        out_specs=(P(dp, axis, None), P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.num_shared_experts:
        out = out + dense_mlp(p["shared"], x)
    return out, aux


def moe_mlp_ref(p, x, cfg: ModelConfig):
    """Naive per-token loop-free reference (computes ALL experts; test-only)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    w, idx, _ = _router_topk(xf @ p["router"], cfg.top_k)
    all_y = jnp.einsum(
        "ecf,efd->ecd",
        swish(jnp.einsum("td,edf->etf", xf, p["w_gate"]).transpose(0, 1, 2)) *
        jnp.einsum("td,edf->etf", xf, p["w_up"]),
        p["w_down"],
    )  # careful: dims (E,T,D)
    # gather chosen experts per token
    picked = all_y[idx, jnp.arange(xf.shape[0])[:, None]]  # (T,K,D)
    out = jnp.sum(picked * w[..., None], axis=1).astype(x.dtype).reshape(B, S, D)
    if cfg.num_shared_experts:
        out = out + dense_mlp(p["shared"], x)
    return out
