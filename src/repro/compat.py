"""Version-compat shims for the jax API surface this repo relies on.

jax moved ``shard_map`` out of ``jax.experimental`` (and renamed
``check_rep`` to ``check_vma``) around 0.5/0.6; this container ships 0.4.x.
Everything in-repo goes through :func:`shard_map` so both spellings work.
Kept dependency-free (imports only jax) so any layer may use it.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level, check_vma
    _new = jax.shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=False)
except AttributeError:  # jax 0.4.x: experimental, check_rep
    from jax.experimental.shard_map import shard_map as _old

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)


try:  # jax >= 0.6
    set_mesh = jax.set_mesh
except AttributeError:  # jax 0.4.x: Mesh is itself the context manager
    import contextlib

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh
