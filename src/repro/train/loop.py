"""Instrumented training loop — the paper's Fig.-1 pipeline made executable.

Each iteration measures the seven steps (parameter refresh is implicit in
SPMD — the ZeRO all-gather — so it is folded into compute; data load / prep /
h2d come from the PrefetchLoader; param+distributed update are inside the
jitted train_step and are folded into compute on a single host, while their
*modeled* costs come from the planner's SyncPlan). The loop emits StepTimes
so R_O and Lemma 3.1/3.2 can be evaluated on real measurements.

Entry points should go through ``repro.api`` (JobSpec -> Session -> Report)
rather than importing :func:`train` directly; the direct import stays
supported for library composition (the Session itself uses it) but is a
deprecation candidate for scripts — see README "One API".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline import StepTimes
from repro.data.pipeline import PrefetchLoader
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.obs.trace import Tracer, monotonic
from repro.optim import adamw as opt_lib
from repro.launch.steps import build_train_step
from repro.checkpoint import CheckpointManager, latest_step as ckpt_latest


@dataclass
class TrainResult:
    losses: List[float]
    step_times: List[StepTimes]
    tokens_per_s: float
    start_step: int = 0

    @property
    def mean_r_o(self) -> float:
        ros = [t.r_o() for t in self.step_times[2:]]
        return float(np.mean(ros)) if ros else 0.0

    def summary(self) -> Dict[str, Any]:
        """The measured block of a ``repro.api.Report``: loss trajectory,
        throughput, R_O, and steady-state (warmup-excluded) means of every
        Fig.-1 step."""
        from repro.core.pipeline import STEP_NAMES

        steady = self.step_times[2:] or self.step_times
        means = {name: float(np.mean([getattr(t, name) for t in steady]))
                 for name in STEP_NAMES} if steady else {}
        head, tail = self.losses[:5], self.losses[-5:]
        return {
            "steps": len(self.losses),
            "start_step": int(self.start_step),
            "loss_first": float(np.mean(head)) if head else float("nan"),
            "loss_last": float(np.mean(tail)) if tail else float("nan"),
            "losses": [float(l) for l in self.losses],
            "tokens_per_s": float(self.tokens_per_s),
            "r_o": self.mean_r_o,
            "step_times_mean": means,
        }


def train(cfg: ModelConfig, run: RunConfig, opt: opt_lib.OptConfig, *,
          batch: int, seq: int, steps: int, seed: int = 0,
          loader: Optional[PrefetchLoader] = None,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
          log_every: int = 10,
          params=None, opt_state=None,
          step_fn: Optional[Callable] = None,
          batch_sharding: Optional[Dict[str, Any]] = None,
          tracer: Optional[Tracer] = None) -> TrainResult:
    """``step_fn`` (optional) replaces the default jitted train step with a
    caller-built executor — e.g. repro.distributed.DataParallelTrainer's
    phase-split step. It may attach host-side phase timings to metrics as
    plain floats under ``t_comm`` / ``t_update``; they are split out of
    compute into StepTimes.dist_update / .param_update. ``batch_sharding``
    maps input names to shardings for the loader's h2d step.  ``tracer``
    (repro.obs) wraps every iteration in a ``step`` span (step index as a
    span arg) and the loader wait in ``data_wait``; phase-level spans come
    from the ``step_fn`` itself when it traces (the DataParallelTrainer
    does).

    The ``step`` span's wall clock IS the StepTimes compute measurement, so
    the loop needs a live clock: a missing/disabled tracer is replaced by a
    private enabled one (events go nowhere, timing still works).

    Checkpointing: when ``ckpt_dir`` is set the loop saves the full
    training state (``params`` + ``opt_state``, minus any dp-shaped ``ef``
    error-feedback leaves, which depend on the device grid and are re-
    initialized on restore) every ``ckpt_every`` steps via an async
    :class:`CheckpointManager`, and AUTO-RESUMES: if a complete checkpoint
    already exists in ``ckpt_dir``, training restarts from its step with
    the loader fast-forwarded, so the resumed loss trajectory matches an
    uninterrupted run — even onto a different ``(dp, pipe)`` grid, because
    the checkpoint stores the logical (replicated) tree and restore re-
    shards onto the live templates."""
    if tracer is None or not tracer.enabled:
        tracer = Tracer(enabled=True)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = materialize(M.model_specs(cfg), key)
    if opt_state is None:
        opt_state = opt_lib.init_state(opt, params)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr is not None and ckpt_latest(ckpt_dir) is not None:
        # "ef" has a leading dp axis (one slot per data shard) so it is
        # grid-dependent: excluded from the checkpoint, kept zero-fresh here
        ef = opt_state.get("ef") if isinstance(opt_state, dict) else None
        tmpl_state = {k: v for k, v in opt_state.items() if k != "ef"} \
            if isinstance(opt_state, dict) else opt_state
        restored, start_step = mgr.restore(
            {"params": params, "opt_state": tmpl_state})
        params = restored["params"]
        opt_state = restored["opt_state"]
        if ef is not None:
            opt_state = dict(opt_state)
            opt_state["ef"] = ef
        if start_step >= steps:
            print(f"  checkpoint at step {start_step} >= steps {steps}; "
                  f"nothing to do", flush=True)
        else:
            print(f"  resuming from checkpoint step {start_step}",
                  flush=True)

    own_loader = loader is None
    if loader is None:
        loader = PrefetchLoader(cfg, batch, seq, seed=seed,
                                sharding=batch_sharding,
                                skip_batches=start_step)

    if step_fn is None:
        step_fn = jax.jit(build_train_step(cfg, run, opt),
                          donate_argnums=(0, 1))

    losses: List[float] = []
    times: List[StepTimes] = []
    t_start = monotonic()
    try:
        for i in range(start_step, steps):
            with tracer.span("data_wait", step=i):
                dev_batch, bt = next(loader)
            with tracer.span("step", step=i) as sp:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     dev_batch)
                loss = float(metrics["loss"])  # blocks
            t_comp = sp.elapsed_s
            t_comm = float(metrics.pop("t_comm", 0.0))
            t_upd = float(metrics.pop("t_update", 0.0))
            losses.append(loss)
            times.append(StepTimes(
                data_load=bt.data_load, data_prep=bt.data_prep, h2d=bt.h2d,
                compute=max(t_comp - t_comm - t_upd, 0.0),
                param_update=t_upd, dist_update=t_comm))
            if mgr is not None and ckpt_every and (i + 1) % ckpt_every == 0:
                payload = {"params": params,
                           "opt_state": {k: v for k, v in opt_state.items()
                                         if k != "ef"}
                           if isinstance(opt_state, dict) else opt_state}
                mgr.save(i + 1, payload)
            if log_every and (i % log_every == 0 or i == steps - 1):
                print(f"  step {i:4d} loss {loss:.4f} "
                      f"compute {t_comp*1e3:.0f}ms io "
                      f"{(bt.data_load+bt.data_prep+bt.h2d)*1e3:.0f}ms",
                      flush=True)
    finally:
        if own_loader:
            loader.close()
        if mgr is not None:
            mgr.close()
    wall = monotonic() - t_start
    tokens = (steps - start_step) * batch * seq
    return TrainResult(losses, times, tokens / max(wall, 1e-9), start_step)
