"""Pure-jnp oracles for every Pallas kernel (kernel-layout adapters around
the model reference implementations in ``repro.models``)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import dense_attention
from repro.models.ssm import ssd_chunked


def flash_attention_ref(q, k, v, *, scale, window=0, cap=0.0):
    """q (B,H,Sq,D), k/v (B,KV,Sk,D) -> (B,H,Sq,D); causal."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qs = q.transpose(0, 2, 1, 3)  # (B,S,H,D)
    ks = k.transpose(0, 2, 1, 3)
    vs = v.transpose(0, 2, 1, 3)
    q_pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    k_pos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    out = dense_attention(qs, ks, vs, q_pos, k_pos, scale=scale,
                          window=window, cap=cap)
    return out.transpose(0, 2, 1, 3)


def decode_attention_ref(q, k, v, pos, *, scale, window=0, cap=0.0):
    """q (B,H,D), k/v (B,KV,S,D), pos (B,) -> (B,H,D)."""
    B, H, D = q.shape
    S = k.shape[2]
    qs = q[:, None]  # (B,1,H,D)
    ks = k.transpose(0, 2, 1, 3)
    vs = v.transpose(0, 2, 1, 3)
    k_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = dense_attention(qs, ks, vs, pos[:, None], k_pos, scale=scale,
                          window=window, cap=cap)
    return out[:, 0]


def ssd_scan_ref(x, dt, a_neg, b_mat, c_mat, *, chunk=256):
    """Kernel layout (B,H,L,P) -> model layout (B,L,H,P) and back."""
    y, h = ssd_chunked(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
        a_neg, b_mat, c_mat, chunk)
    return y.transpose(0, 2, 1, 3), h
