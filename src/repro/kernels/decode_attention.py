"""Pallas TPU flash-decoding: one query token against a long KV cache.

Grid (B, H, n_kv_blocks); kv sequential with running (m, l, acc) scratch —
the single-chip analogue of the cross-shard partial-softmax combine the
SPMD decode path performs. Per-example valid length arrives as a (B, 1)
int32 array (position of the current token; cache entries > pos masked).

Layout: q (B, H, D), k/v (B, KV, S, D).

Two entry points share one kernel body:

* :func:`decode_attention`        — linear per-request caches (B, KV, S, D)
* :func:`paged_decode_attention`  — a block-pool cache (N, KV, bs, D) plus a
  per-request block table (B, nb).  The table is a *scalar-prefetch* operand
  (``PrefetchScalarGridSpec``): the kv grid axis walks logical blocks and the
  BlockSpec index map translates them to physical pool blocks, so the kernel
  streams exactly the request's blocks with no gather materialization.
  With ``bs == kv_block`` both paths run the identical op sequence per
  block, so their outputs are bit-identical for the same cache content.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -2.0e38


def _flash_body(pos, ki, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale, cap, window, tk, nk):
    """One kv-block step of the running-softmax decode, shared by the linear
    and paged kernels. ``ki`` is the *logical* block index — masking is by
    logical position, so where the physical block came from is irrelevant."""

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * tk
    relevant = k_start <= pos
    if window:
        relevant &= (k_start + tk - 1) >= pos - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, D) block carries one head
        k = k_ref[0, 0].astype(jnp.float32)  # (tk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, tk)
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        mask = kpos <= pos
        if window:
            mask &= (pos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, cap, window, tk, nk):
    _flash_body(pos_ref[0, 0], pl.program_id(2), q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref, scale=scale, cap=cap, window=window,
                tk=tk, nk=nk)


def _paged_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, scale, cap, window, tk, nk):
    # table_ref routed the k/v BlockSpecs; the body only needs the position.
    del table_ref
    _flash_body(pos_ref[pl.program_id(0)], pl.program_id(2), q_ref, k_ref,
                v_ref, o_ref, acc_ref, m_ref, l_ref, scale=scale, cap=cap,
                window=window, tk=tk, nk=nk)


def decode_attention(q, k, v, pos, *, scale: float, window: int = 0,
                     cap: float = 0.0, kv_block: int = 512,
                     interpret: bool = True):
    """q (B,H,D), k/v (B,KV,S,D), pos (B,) -> (B,H,D)."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    tk = min(kv_block, max(S, 8))
    k_pad = -S % tk
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    nk = (S + k_pad) // tk
    q4 = q[:, :, None, :]  # (B, H, 1, D)
    pos2 = pos.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, cap=cap, window=window,
                               tk=tk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos2, q4, k, v)
    return out[:, :, 0, :]


def paged_decode_attention(q, k_pool, v_pool, block_table, pos, *,
                           scale: float, window: int = 0, cap: float = 0.0,
                           interpret: bool = True):
    """Flash-decoding over a paged KV cache.

    q (B,H,D); k_pool/v_pool (N,KV,bs,D) — N physical blocks of bs tokens;
    block_table (B,nb) int32 mapping each request's logical block ki to a
    physical pool block (entries past the request's length may repeat any
    valid id — those positions are masked by ``pos``); pos (B,) current
    position per request.  Returns (B,H,D).

    The table and positions ride in as scalar-prefetch operands so the k/v
    index maps can dereference the table per grid step — the kernel streams
    physical blocks directly, no gathered linear copy is materialized.
    """
    B, H, D = q.shape
    KV, bs = k_pool.shape[1], k_pool.shape[2]
    G = H // KV
    nb = block_table.shape[1]
    q4 = q[:, :, None, :]  # (B, H, 1, D)

    kernel = functools.partial(_paged_kernel, scale=scale, cap=cap,
                               window=window, tk=bs, nk=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, tbl, p: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, ki, tbl, p, g=G: (tbl[b, ki], h // g, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, ki, tbl, p, g=G: (tbl[b, ki], h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, tbl, p: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(pos, jnp.int32),
      q4, k_pool, v_pool)
    return out[:, :, 0, :]
