"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes eagerly with the same block/grid schedule; on TPU the
same call sites compile natively. Model code passes (B, S, H, D) layouts;
these wrappers adapt to the kernels' (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as dec_k
from repro.kernels import flash_attention as fa_k
from repro.kernels import ssd_scan as ssd_k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "cap"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, scale, window=0,
                    cap=0.0):
    """(B,S,H,D) x (B,S,KV,D) -> (B,S,H,D), causal from position 0."""
    out = fa_k.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        scale=scale, window=window, cap=cap, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "window", "cap"))
def decode_attention(q, k, v, pos, *, scale, window=0, cap=0.0):
    """q (B,1,H,D), cache k/v (B,S,KV,D), pos (B,) -> (B,1,H,D)."""
    out = dec_k.decode_attention(
        q[:, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), pos,
        scale=scale, window=window, cap=cap, interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_neg, b_mat, c_mat, *, chunk=256):
    """Model layout x (B,L,H,P), dt (B,L,H) -> y (B,L,H,P), h (B,H,N,P)."""
    y, h = ssd_k.ssd_scan(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), a_neg, b_mat, c_mat,
        chunk=chunk, interpret=_interpret())
    return y.transpose(0, 2, 1, 3), h
