"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes eagerly with the same block/grid schedule; on TPU the
same call sites compile natively. Model code passes (B, S, H, D) layouts;
these wrappers adapt to the kernels' (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as dec_k
from repro.kernels import flash_attention as fa_k
from repro.kernels import ssd_scan as ssd_k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "cap"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, scale, window=0,
                    cap=0.0):
    """(B,S,H,D) x (B,S,KV,D) -> (B,S,H,D), causal from position 0."""
    out = fa_k.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        scale=scale, window=window, cap=cap, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "window", "cap"))
def decode_attention(q, k, v, pos, *, scale, window=0, cap=0.0):
    """q (B,1,H,D), cache k/v (B,S,KV,D), pos (B,) -> (B,1,H,D)."""
    out = dec_k.decode_attention(
        q[:, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), pos,
        scale=scale, window=window, cap=cap, interpret=_interpret())
    return out[:, None]


@jax.jit
def gather_kv_blocks(pool, block_table):
    """Materialize linear caches from a block pool: pool (N, bs, *tail) and
    block_table (B, nb) -> (B, nb*bs, *tail).

    The slow-path twin of :func:`paged_decode_attention` — used by the
    engine's batch-reconstruction path and as the reference the paged kernel
    is tested bit-identical against."""
    g = pool[block_table]  # (B, nb, bs, *tail)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


@functools.partial(jax.jit, static_argnames=("scale", "window", "cap"))
def paged_decode_attention(q, k_pool, v_pool, block_table, pos, *, scale,
                           window=0, cap=0.0):
    """q (B,1,H,D), pools (N,bs,KV,D) in model layout, block_table (B,nb),
    pos (B,) -> (B,1,H,D).  Streams the request's physical blocks via the
    scalar-prefetched table; no gathered linear cache is materialized."""
    out = dec_k.paged_decode_attention(
        q[:, 0], k_pool.transpose(0, 2, 1, 3), v_pool.transpose(0, 2, 1, 3),
        block_table, pos, scale=scale, window=window, cap=cap,
        interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_neg, b_mat, c_mat, *, chunk=256):
    """Model layout x (B,L,H,P), dt (B,L,H) -> y (B,L,H,P), h (B,H,N,P)."""
    y, h = ssd_k.ssd_scan(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), a_neg, b_mat, c_mat,
        chunk=chunk, interpret=_interpret())
    return y.transpose(0, 2, 1, 3), h


# ---------------------------------------------------------------------------
# Tuning registry — the autotuner's view of this layer
# ---------------------------------------------------------------------------
# Every op the paper's "choose the computation algorithm" procedure can pick
# between is enumerable here: `tune_inputs(op)` builds representative
# kernel-layout inputs, `tune_candidates(op)` returns the named variants
# (pallas kernel vs jnp reference, and per-chunk schedules for the scan).
# `repro.core.autotune` times these and records the fastest feasible one.

TUNABLE_OPS = ("flash_attention", "decode_attention",
               "paged_decode_attention", "ssd_scan")


def tune_inputs(op: str, *, seed: int = 0, batch: int = 1, seq: int = 128,
                heads: int = 2, head_dim: int = 64, ssm_p: int = 32,
                ssm_n: int = 16):
    """Representative random inputs for ``op`` in KERNEL layout (B,H,S,D)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    if op == "flash_attention":
        q = jax.random.normal(ks[0], (batch, heads, seq, head_dim))
        k = jax.random.normal(ks[1], (batch, heads, seq, head_dim))
        v = jax.random.normal(ks[2], (batch, heads, seq, head_dim))
        return (q, k, v)
    if op == "decode_attention":
        q = jax.random.normal(ks[0], (batch, heads, head_dim))
        k = jax.random.normal(ks[1], (batch, heads, seq, head_dim))
        v = jax.random.normal(ks[2], (batch, heads, seq, head_dim))
        pos = jnp.full((batch,), seq - 1, jnp.int32)
        return (q, k, v, pos)
    if op == "paged_decode_attention":
        bs = 16
        nb = max(seq // bs, 1)
        n_pool = 2 * batch * nb  # half-occupied pool, non-contiguous tables
        q = jax.random.normal(ks[0], (batch, heads, head_dim))
        k_pool = jax.random.normal(ks[1], (n_pool, heads, bs, head_dim))
        v_pool = jax.random.normal(ks[2], (n_pool, heads, bs, head_dim))
        table = jax.random.permutation(
            ks[3], n_pool)[: batch * nb].reshape(batch, nb).astype(jnp.int32)
        pos = jnp.full((batch,), nb * bs - 1, jnp.int32)
        return (q, k_pool, v_pool, table, pos)
    if op == "ssd_scan":
        x = jax.random.normal(ks[0], (batch, heads, seq, ssm_p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (batch, heads, seq)))
        a_neg = -jnp.exp(jax.random.normal(ks[2], (heads,)) * 0.5)
        b = jax.random.normal(ks[3], (batch, seq, ssm_n))
        c = jax.random.normal(ks[4], (batch, seq, ssm_n))
        return (x, dt, a_neg, b, c)
    raise KeyError(f"unknown tunable op {op!r}; known: {TUNABLE_OPS}")


def tune_candidates(op: str, *, ssd_chunks=(32, 64, 128)):
    """Named algorithm variants for ``op``, each a callable on the arrays
    from :func:`tune_inputs`.  ``pallas`` variants run interpreted on CPU
    and compiled on TPU (same code path as the model)."""
    if op == "flash_attention":
        def _scale(q):
            return 1.0 / (q.shape[-1] ** 0.5)
        return {
            "pallas": lambda q, k, v: fa_k.flash_attention(
                q, k, v, scale=_scale(q), interpret=_interpret()),
            "ref": lambda q, k, v: _ref().flash_attention_ref(
                q, k, v, scale=_scale(q)),
        }
    if op == "decode_attention":
        return {
            "pallas": lambda q, k, v, pos: dec_k.decode_attention(
                q, k, v, pos, scale=1.0 / (q.shape[-1] ** 0.5),
                interpret=_interpret()),
            "ref": lambda q, k, v, pos: _ref().decode_attention_ref(
                q, k, v, pos, scale=1.0 / (q.shape[-1] ** 0.5)),
        }
    if op == "paged_decode_attention":
        def _gathered(pool, table):
            # (N,KV,bs,D)[table] -> (B,nb,KV,bs,D) -> linear (B,KV,nb*bs,D)
            g = pool[table]
            b, nb, kv, bs, d = g.shape
            return g.transpose(0, 2, 1, 3, 4).reshape(b, kv, nb * bs, d)
        return {
            "pallas": lambda q, kp, vp, tbl, pos: dec_k.paged_decode_attention(
                q, kp, vp, tbl, pos, scale=1.0 / (q.shape[-1] ** 0.5),
                interpret=_interpret()),
            "gather_ref": lambda q, kp, vp, tbl, pos: _ref().decode_attention_ref(
                q, _gathered(kp, tbl), _gathered(vp, tbl), pos,
                scale=1.0 / (q.shape[-1] ** 0.5)),
        }
    if op == "ssd_scan":
        def _chunk_variant(c):
            return lambda *a: ssd_k.ssd_scan(*a, chunk=c,
                                             interpret=_interpret())
        out = {f"pallas_chunk{c}": _chunk_variant(c) for c in ssd_chunks}
        out["ref"] = lambda *a: _ref().ssd_scan_ref(*a)
        return out
    raise KeyError(f"unknown tunable op {op!r}; known: {TUNABLE_OPS}")


def _ref():
    from repro.kernels import ref
    return ref
