"""Pallas TPU flash attention (causal, GQA, sliding-window, softcap).

Grid (B, H, n_q_blocks, n_kv_blocks); the innermost kv dimension is
sequential ("arbitrary") so the online-softmax running state lives in VMEM
scratch across kv steps. Block shapes are MXU-aligned (q_block × head_dim,
head_dim a multiple of 128 where the arch allows). Fully-masked kv blocks
(above the causal diagonal / outside the sliding window) are skipped with
``pl.when`` — the same triangular saving the XLA reference gets from its
static q-block prefix.

Layout: q (B, H, Sq, D), k/v (B, KV, Sk, D) — transposed by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, cap, window, sk_real, tq, tk, nk):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * tq
    k_start = ki * tk
    # block-level relevance: causal (k_start <= q_end) and window
    relevant = k_start <= q_start + tq - 1
    if window:
        relevant &= (k_start + tk - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (tq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (tk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = (kpos <= qpos) & (kpos < sk_real)
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale: float, window: int = 0,
                    cap: float = 0.0, q_block: int = 512, kv_block: int = 512,
                    interpret: bool = True):
    """q (B,H,Sq,D), k/v (B,KV,Sk,D) -> (B,H,Sq,D). Causal."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    tq = min(q_block, max(Sq, 8))
    tk = min(kv_block, max(Sk, 8))
    q_pad = -Sq % tq
    k_pad = -Sk % tk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    nq = (Sq + q_pad) // tq
    nk = (Sk + k_pad) // tk

    kernel = functools.partial(
        _kernel, scale=scale, cap=cap, window=window, sk_real=Sk,
        tq=tq, tk=tk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + q_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, D), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
