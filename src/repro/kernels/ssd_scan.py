"""Pallas TPU Mamba-2 SSD chunked scan.

Grid (B, H, n_chunks); chunks sequential with the (N, P) inter-chunk state
in VMEM scratch. Per chunk: the quadratic intra-chunk term (the "dual"
attention-like form, MXU matmuls), the chunk-state contribution of the
carried state, and the state update — mirroring ``repro.models.ssm.
ssd_chunked`` exactly (its pure-jnp math is the oracle in ref.py).

Layouts: x (B,H,L,P), dt (B,H,L), a_neg (H,1), b/c (B,L,N) (G=1: shared
across heads). Outputs y (B,H,L,P) and final state (B,H,N,P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, state_ref, *,
            q, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0, 0]  # scalar (negative)
    b = b_ref[0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0].astype(jnp.float32)  # (Q, N)

    loga = dt * a  # (Q,) log per-step decay
    cl = jnp.cumsum(loga)  # (Q,)

    # intra-chunk (dual/quadratic form)
    diff = cl[:, None] - cl[None, :]  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * lmat * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # contribution of the carried inter-chunk state
    h = state_ref[...]  # (N, P)
    ch = jax.lax.dot_general(c, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, P)
    y = y + ch * jnp.exp(cl)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h' = exp(cl_Q) h + sum_j exp(cl_Q - cl_j) dt_j b_j x_j^T
    decay_end = jnp.exp(cl[q - 1] - cl) * dt  # (Q,)
    sx = x * decay_end[:, None]  # (Q, P)
    s_chunk = jax.lax.dot_general(b, sx, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = h * jnp.exp(cl[q - 1]) + s_chunk

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, a_neg, b_mat, c_mat, *, chunk: int = 256,
             interpret: bool = True):
    """x (B,H,L,P), dt (B,H,L), a_neg (H,), b/c (B,L,N).
    Returns y (B,H,L,P), h_final (B,H,N,P)."""
    B, H, L, P = x.shape
    N = b_mat.shape[-1]
    q = min(chunk, L)
    assert L % q == 0, (L, q)
    nc = L // q
    a2 = a_neg.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_kernel, q=q, nc=nc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, q), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1, 1), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, q, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a2, b_mat, c_mat)
    return y, h_fin
