"""Serialized async checkpointing on top of :mod:`repro.checkpoint.io`.

The seed-era ``save(blocking=False)`` returned a raw ``daemon=True``
thread: interpreter exit could kill it mid-write, and two overlapping
saves raced on ``manifest.json``.  ``CheckpointManager`` replaces that
API with one long-lived writer thread fed by a queue — saves are
serialized in submission order, ``wait()`` blocks until the queue is
drained, and an ``atexit`` hook drains it before the interpreter goes
away so a non-blocking save near the end of a run still lands on disk.

Leaves are materialized to host numpy arrays on the *caller's* thread at
enqueue time, so the writer never touches live device buffers (a later
donated/updated param cannot corrupt an in-flight save).
"""
from __future__ import annotations

import atexit
import queue
import threading
from typing import Optional

import numpy as np

from repro.checkpoint import io as ckpt_io


class CheckpointManager:
    """Atomic, serialized, optionally-async checkpoint saves.

    Parameters
    ----------
    directory:
        Where step files and the manifest live (created on first save).
    """

    def __init__(self, directory: str):
        self.directory = str(directory)
        self._queue: "queue.Queue" = queue.Queue()
        self._last_step: Optional[int] = None
        self._errors: list = []
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        atexit.register(self.close)

    # -- internals -------------------------------------------------------
    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._drain, name="ckpt-writer", daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                step, flat = item
                ckpt_io._write_step(ckpt_io.Path(self.directory), step, flat)
            except Exception as exc:  # surfaced on wait()/next save
                with self._lock:
                    self._errors.append(exc)
            finally:
                self._queue.task_done()

    def _raise_pending(self):
        with self._lock:
            if self._errors:
                exc = self._errors[0]
                self._errors.clear()
                raise RuntimeError("async checkpoint save failed") from exc

    # -- public API ------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Save ``tree`` as checkpoint ``step``.

        Steps must be strictly increasing per manager; the flatten (and
        device→host copy) happens here, synchronously, so the caller may
        immediately mutate or donate the arrays it passed in.
        """
        if self._closed:
            raise RuntimeError("CheckpointManager is closed")
        step = int(step)
        if self._last_step is not None and step <= self._last_step:
            raise ValueError(
                f"checkpoint steps must be strictly increasing: got {step} "
                f"after {self._last_step}")
        self._raise_pending()
        self._last_step = step
        flat = ckpt_io._flatten(tree)
        d = ckpt_io.Path(self.directory)
        d.mkdir(parents=True, exist_ok=True)
        if blocking:
            ckpt_io._write_step(d, step, flat)
            return
        # np.asarray in _flatten can be a zero-copy VIEW (numpy leaves, CPU
        # jax buffers); an async save must own its bits before the caller
        # mutates or donates them
        flat = {k: np.array(v, copy=True) for k, v in flat.items()}
        self._ensure_worker()
        self._queue.put((step, flat))

    def wait(self) -> None:
        """Block until every queued save has hit the disk (then re-raise
        the first writer-thread failure, if any)."""
        self._queue.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain outstanding saves and stop the writer thread.  Idempotent;
        also runs via ``atexit`` so shutdown never loses a queued save."""
        if self._closed:
            return
        self._queue.join()
        if self._worker is not None and self._worker.is_alive():
            self._queue.put(None)
            self._worker.join(timeout=30.0)
        self._closed = True
        atexit.unregister(self.close)
        self._raise_pending()

    def latest_step(self) -> Optional[int]:
        return ckpt_io.latest_step(self.directory)

    def restore(self, template, step: Optional[int] = None):
        """See :func:`repro.checkpoint.io.restore`; waits for queued saves
        first so a restore never misses a save submitted before it."""
        self.wait()
        return ckpt_io.restore(template, self.directory, step)
