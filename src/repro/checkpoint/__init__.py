"""Crash-safe, elastic checkpointing.

:mod:`repro.checkpoint.io` holds the synchronous primitives (atomic
``save`` / ``latest_step`` / ``restore``); ``CheckpointManager`` adds
serialized async saves with ``wait()`` semantics.
"""
from repro.checkpoint.io import (MANIFEST_SCHEMA_ID, latest_step, restore,
                                 save, validate_manifest)
from repro.checkpoint.manager import CheckpointManager

__all__ = [
    "MANIFEST_SCHEMA_ID",
    "CheckpointManager",
    "latest_step",
    "restore",
    "save",
    "validate_manifest",
]
