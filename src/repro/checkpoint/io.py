"""Crash-safe sharded checkpointing without external deps.

One ``.npz`` + one ``.meta.json`` per step, plus a top-level
``manifest.json`` pointing at the newest complete step.  Leaves are
flattened by pytree path; restore rebuilds the tree and re-shards via
``device_put`` onto the *template's* shardings — the on-disk layout is
purely logical (path-keyed arrays + their true dtypes), so the same
checkpoint restores onto any ``(dp, pipe)`` grid whose logical tree
matches (elastic resume).

Atomicity protocol (every write in this module follows it):

1. write the payload to ``<name>.tmp.<pid>`` in the same directory,
2. ``os.replace`` it over the final name — atomic on POSIX, so a crash
   mid-write leaves only a dead tmp file, never a torn checkpoint;
3. the step's ``.meta.json`` is replaced only *after* its ``.npz``, and
   ``manifest.json`` only after both — readers that follow
   :func:`latest_step` can therefore never observe a partial step;
4. the manifest is step-monotonic: a slow (async) save of step N that
   finishes after step N+1's save must not move the pointer backwards.

Non-native dtypes (bfloat16 and friends from ml_dtypes, which
``np.savez`` would silently pickle as object arrays or reject) are stored
as an unsigned-integer view of the raw bits with the true dtype recorded
in the step's meta, and restored exactly.

Async saves live in :class:`repro.checkpoint.manager.CheckpointManager`
(one serialized writer thread + ``wait()``); the functions here are
synchronous primitives.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs.trace import monotonic

MANIFEST_SCHEMA_ID = "repro.checkpoint/manifest/v1"

# dtype kinds np.savez round-trips natively; anything else (ml_dtypes'
# bfloat16/fp8 register kind 'V') goes through the bit-pattern view
_NATIVE_KINDS = set("biufc?")
_UINT_BY_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def validate_manifest(d: Dict[str, Any]) -> Dict[str, Any]:
    """Raise ValueError unless ``d`` is a valid ``MANIFEST_SCHEMA_ID``
    payload; returns it.  The id covers both on-disk JSON shapes: the
    top-level ``manifest.json`` pointer (``keys`` + ``written_s``) and a
    step's ``.meta.json`` (per-key ``layout``)."""
    if not isinstance(d, dict):
        raise ValueError(f"manifest must be a dict, got {type(d).__name__}")
    if d.get("schema") != MANIFEST_SCHEMA_ID:
        raise ValueError(f"manifest schema {d.get('schema')!r} != "
                         f"{MANIFEST_SCHEMA_ID!r}")
    step = d.get("step")
    if not isinstance(step, int) or step < 0:
        raise ValueError(f"manifest step must be an int >= 0, got {step!r}")
    if "layout" in d:
        if not isinstance(d["layout"], dict):
            raise ValueError("meta layout must be a dict")
        for key, entry in d["layout"].items():
            for want in ("shape", "dtype", "stored_dtype"):
                if want not in entry:
                    raise ValueError(f"layout[{key!r}] missing {want!r}")
    elif "keys" in d:
        keys = d["keys"]
        if (not isinstance(keys, list)
                or any(not isinstance(k, str) for k in keys)):
            raise ValueError("manifest keys must be a list of strings")
    else:
        raise ValueError("manifest payload has neither 'keys' (pointer) "
                         "nor 'layout' (step meta)")
    return d


def _step_npz(d: Path, step: int) -> Path:
    return d / f"step_{step:08d}.npz"


def _step_meta(d: Path, step: int) -> Path:
    return d / f"step_{step:08d}.meta.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _storage_view(arr: np.ndarray) -> Tuple[np.ndarray, str, str]:
    """(storable array, true dtype name, stored dtype name).  Native
    dtypes pass through; extension dtypes (bf16, ...) become a same-width
    unsigned-int view so the npz holds raw bits, never pickled objects."""
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr, arr.dtype.name, arr.dtype.name
    uint = _UINT_BY_ITEMSIZE[arr.dtype.itemsize]
    return arr.view(uint), arr.dtype.name, np.dtype(uint).name


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype by name, including the ml_dtypes extension family."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _atomic_write_manifest(d: Path, step: int, keys, written_s: float):
    """Move the latest-step pointer forward — never backward: a slow async
    save of step N landing after step N+1 must not clobber the newer
    manifest.  tmp + ``os.replace`` keeps the pointer itself untearable."""
    path = d / "manifest.json"
    if path.exists():
        try:
            prev = json.loads(path.read_text())
        except (OSError, ValueError):
            prev = {}
        if int(prev.get("step", -1)) >= step:
            return
    manifest = {
        "schema": MANIFEST_SCHEMA_ID,
        "step": step,
        "keys": sorted(keys),
        "written_s": round(written_s, 3),
    }
    tmp = d / f"manifest.json.tmp.{os.getpid()}"
    tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, path)


def _write_step(d: Path, step: int, flat: Dict[str, np.ndarray]):
    """One complete step: npz (bit-pattern views), then its meta (logical
    layout), then the manifest pointer — each atomically, in that order."""
    t0 = monotonic()
    stored: Dict[str, np.ndarray] = {}
    layout: Dict[str, Dict[str, Any]] = {}
    for key, arr in flat.items():
        view, true_dtype, stored_dtype = _storage_view(arr)
        stored[key] = view
        layout[key] = {"shape": list(arr.shape), "dtype": true_dtype,
                       "stored_dtype": stored_dtype}
    npz = _step_npz(d, step)
    tmp = npz.with_suffix(f".npz.tmp.{os.getpid()}")
    with open(tmp, "wb") as f:
        np.savez(f, **stored)
    os.replace(tmp, npz)
    meta = {"schema": MANIFEST_SCHEMA_ID, "step": step, "layout": layout}
    mtmp = _step_meta(d, step).with_suffix(f".json.tmp.{os.getpid()}")
    mtmp.write_text(json.dumps(meta, indent=1))
    os.replace(mtmp, _step_meta(d, step))
    _atomic_write_manifest(d, step, flat.keys(), monotonic() - t0)


def save(tree, directory: str, step: int) -> None:
    """Blocking atomic save of ``tree`` as checkpoint ``step``.

    The old ``blocking=False`` raw-``Thread`` API is gone — its daemon
    writer was silently killed at interpreter exit and two overlapping
    saves raced on the manifest.  Use
    :class:`repro.checkpoint.manager.CheckpointManager` for serialized
    async saves with ``wait()``.
    """
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    _write_step(d, int(step), _flatten(tree))


def _complete_steps(d: Path):
    """Steps whose npz AND meta both exist, ascending — the only states a
    reader may observe as restorable."""
    steps = []
    for p in sorted(d.glob("step_*.npz")):
        try:
            step = int(p.stem.split("_")[1])
        except (IndexError, ValueError):
            continue
        if _step_meta(d, step).exists():
            steps.append(step)
    return steps


def latest_step(directory: str) -> Optional[int]:
    """Newest *complete* step, or None.  The manifest pointer is only
    trusted when its step's files actually exist — a crash between the
    npz landing and the manifest moving (or a deleted step) falls back to
    a directory scan for the last valid step."""
    d = Path(directory)
    manifest = d / "manifest.json"
    if manifest.exists():
        try:
            step = int(json.loads(manifest.read_text())["step"])
        except (OSError, ValueError, KeyError):
            step = None
        if step is not None and _step_npz(d, step).exists() \
                and _step_meta(d, step).exists():
            return step
    steps = _complete_steps(d)
    return steps[-1] if steps else None


def _load_layout(d: Path, step: int) -> Dict[str, Dict[str, Any]]:
    meta = json.loads(_step_meta(d, step).read_text())
    return meta.get("layout", {})


def restore(template, directory: str, step: Optional[int] = None):
    """Restore into the structure (and shardings, if any) of ``template``.

    Returns ``(tree, step)``.  Key-set mismatches between the checkpoint
    and the template raise a single ``ValueError`` listing every missing
    and extra key (instead of a bare ``KeyError`` mid-loop); dtypes come
    back exactly as saved via the recorded layout.
    """
    d = Path(directory)
    step = latest_step(directory) if step is None else int(step)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    npz = _step_npz(d, step)
    if not npz.exists() or not _step_meta(d, step).exists():
        raise FileNotFoundError(f"checkpoint step {step} incomplete in "
                                f"{directory} (npz or meta missing)")
    layout = _load_layout(d, step)
    data = np.load(npz)

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    tmpl_keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path) for path, _ in flat_template]
    ckpt_keys = set(data.files)
    missing = sorted(set(tmpl_keys) - ckpt_keys)
    extra = sorted(ckpt_keys - set(tmpl_keys))
    if missing or extra:
        raise ValueError(
            f"checkpoint step {step} in {directory} does not match the "
            f"template tree: missing from checkpoint {missing or '[]'}; "
            f"extra in checkpoint {extra or '[]'}")
    out = []
    for key, (path, leaf) in zip(tmpl_keys, flat_template):
        arr = data[key]
        entry = layout.get(key)
        if entry and entry["dtype"] != entry.get("stored_dtype",
                                                 entry["dtype"]):
            arr = arr.view(_resolve_dtype(entry["dtype"]))
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            out.append(jax.device_put(arr, sharding))
        elif sharding is not None:
            out.append(jax.device_put(arr))
        else:
            # host (numpy) template: hand back the stored bits untouched —
            # device_put would canonicalize dtypes (int64 -> int32 without
            # x64) and break the exact round-trip
            out.append(np.ascontiguousarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
