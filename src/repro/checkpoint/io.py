"""Sharded checkpointing without external deps: one .npz per host plus a
JSON manifest. Leaves are flattened by pytree path; restore rebuilds the
tree and re-shards via device_put. Async save uses a background thread so
checkpoint I/O hides behind compute (the same pipelining doctrine as the
data path)."""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np

from repro.obs.trace import monotonic


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree, directory: str, step: int, *, blocking: bool = True):
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)

    def write():
        t0 = monotonic()
        np.savez(d / f"step_{step:08d}.npz", **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "written_s": round(monotonic() - t0, 3),
        }
        (d / "manifest.json").write_text(json.dumps(manifest, indent=1))

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not (d / "manifest.json").exists():
        return None
    return json.loads((d / "manifest.json").read_text())["step"]


def restore(template, directory: str, step: Optional[int] = None):
    """Restore into the structure (and shardings, if any) of ``template``."""
    d = Path(directory)
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(d / f"step_{step:08d}.npz")

    keys = iter(sorted(data.files))
    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_key = {}
    for path, leaf in flat_template:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        by_key[key] = leaf
    out = []
    for path, leaf in flat_template:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
