"""Data pipeline — the paper's steps (2) data loading, (3) data preparation,
(4) host->device transfer, with double-buffered background prefetch so they
hide behind step (5) compute, and per-step timing instrumentation that feeds
R_O (Lemma 3.1) and the Fig.-4 benchmark.

The corpus is synthetic (seeded zipfian token stream with a deterministic
"document" structure) — there is no dataset gate in this container, but the
loader is a real pipeline: it reads shards from disk if present, otherwise
generates them, and always goes through the same decode/augment/pack path.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.obs.trace import monotonic


@dataclass
class BatchTimes:
    data_load: float = 0.0
    data_prep: float = 0.0
    h2d: float = 0.0


class SyntheticCorpus:
    """Deterministic zipfian token shards, optionally persisted to disk
    (so step-2 'data loading' does real file I/O when a cache dir is set)."""

    def __init__(self, vocab: int, shard_tokens: int = 1 << 20,
                 cache_dir: Optional[str] = None, seed: int = 0):
        self.vocab = vocab
        self.shard_tokens = shard_tokens
        self.cache_dir = Path(cache_dir) if cache_dir else None
        self.seed = seed
        if self.cache_dir:
            self.cache_dir.mkdir(parents=True, exist_ok=True)

    def load_shard(self, idx: int) -> np.ndarray:
        if self.cache_dir:
            p = self.cache_dir / f"shard_{idx:05d}.npy"
            if p.exists():
                return np.load(p)
        rng = np.random.default_rng(self.seed + idx)
        # zipf-ish distribution clipped to vocab
        z = rng.zipf(1.3, size=self.shard_tokens)
        toks = (z % self.vocab).astype(np.int32)
        # inject deterministic n-gram structure so a model can learn something
        toks[1::7] = (toks[::7][: len(toks[1::7])] * 31 + 17) % self.vocab
        if self.cache_dir:
            np.save(self.cache_dir / f"shard_{idx:05d}.npy", toks)
        return toks


class PrefetchLoader:
    """Steps 2-4 with a background producer thread + bounded queue
    (double buffering). ``__next__`` returns (device_batch, BatchTimes)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 corpus: Optional[SyntheticCorpus] = None, depth: int = 2,
                 sharding=None, seed: int = 0, skip_batches: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.corpus = corpus or SyntheticCorpus(cfg.vocab_size, seed=seed)
        self.sharding = sharding
        self.skip_batches = int(skip_batches)
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._shard_idx = 0
        self._buf = np.zeros((0,), np.int32)
        self._thread.start()

    # -- producer (steps 2 & 3) ------------------------------------------
    def _fill(self, n_tokens: int) -> np.ndarray:
        while self._buf.size < n_tokens:
            shard = self.corpus.load_shard(self._shard_idx)
            self._shard_idx += 1
            self._buf = np.concatenate([self._buf, shard])
        out, self._buf = self._buf[:n_tokens], self._buf[n_tokens:]
        return out

    def _producer(self):
        k = self.cfg.num_codebooks or 0
        need = self.batch * (self.seq + 1) * max(k, 1)
        # elastic resume: the token stream is a pure function of (seed,
        # consumption order), so skipping N batches through the SAME _fill
        # path leaves _buf/_shard_idx exactly as N real batches would —
        # batch N+1 onward (and its shard-seeded image_embeds rng) is
        # bit-identical to an uninterrupted run
        for _ in range(self.skip_batches):
            if self._stop.is_set():
                return
            self._fill(need)
        while not self._stop.is_set():
            t0 = monotonic()
            raw = self._fill(need)
            t_load = monotonic() - t0

            t0 = monotonic()
            if k:
                arr = raw.reshape(self.batch, self.seq + 1, k)
                tokens, labels = arr[:, :-1], arr[:, 1:]
            else:
                arr = raw.reshape(self.batch, self.seq + 1)
                tokens, labels = arr[:, :-1], arr[:, 1:]
            batch: Dict[str, np.ndarray] = {
                "tokens": np.ascontiguousarray(tokens),
                "labels": np.ascontiguousarray(labels),
            }
            if self.cfg.num_image_tokens:
                rng = np.random.default_rng(self._shard_idx)
                batch["image_embeds"] = rng.standard_normal(
                    (self.batch, self.cfg.num_image_tokens, self.cfg.d_model),
                    dtype=np.float32) * 0.02
            t_prep = monotonic() - t0
            # keep retrying the SAME batch: timing out used to silently drop
            # it, which made the token stream depend on step wall-clock and
            # broke same-seed run-to-run determinism
            while not self._stop.is_set():
                try:
                    self.q.put((batch, t_load, t_prep), timeout=0.2)
                    break
                except queue.Full:
                    continue

    # -- consumer (step 4) -------------------------------------------------
    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        batch, t_load, t_prep = self.q.get()
        t0 = monotonic()
        if self.sharding is not None:
            dev = {k: jax.device_put(v, self.sharding.get(k))
                   for k, v in batch.items()}
        else:
            dev = {k: jax.device_put(v) for k, v in batch.items()}
        jax.block_until_ready(jax.tree_util.tree_leaves(dev)[0])
        t_h2d = monotonic() - t0
        return dev, BatchTimes(t_load, t_prep, t_h2d)

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
