"""repro.obs — the unified telemetry layer (tracing + metrics).

The paper's procedure is *measure, then configure*; this package is the
measuring half every subsystem reports through:

- :class:`~repro.obs.trace.Tracer` / :class:`~repro.obs.trace.Span` —
  nestable phase-level wall-clock spans, Chrome-trace/Perfetto export,
  optional ``jax.profiler`` annotation bracketing, and a zero-cost
  disabled fast path.
- :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges, and
  p50/p95/p99 histograms; renders the ``repro.api/metrics/v1`` section
  that every measured ``Report`` carries (``validate_metrics`` is the
  schema check ``repro.api.report`` delegates to).

See ``docs/observability.md`` for the walkthrough and
``tools/bench_trajectory.py`` for the per-PR ``BENCH_<area>.json``
trajectory these sections feed.
"""
from repro.obs.metrics import (METRICS_SCHEMA_ID, Counter, Gauge, Histogram,
                               MetricsRegistry, percentile, validate_metrics)
from repro.obs.trace import NULL_TRACER, Span, SpanEvent, Tracer

__all__ = [
    "METRICS_SCHEMA_ID", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "validate_metrics",
    "NULL_TRACER", "Span", "SpanEvent", "Tracer",
]
