"""Tracer — nestable wall-clock spans with Chrome-trace export.

The paper's whole method is *measure, then configure*: Lemma 3.1/3.2 only
pay off when step time, comm time, and overlap are observable quantities.
Until this module every hot path timed itself with scattered
``time.perf_counter()`` pairs and threw the measurement away at process
exit.  ``Tracer`` is the one clock those paths share:

- ``with tracer.span("dist_update") as sp: ...`` times a phase; the span's
  ``elapsed_s`` is exactly the ``perf_counter()`` pair it replaces, so the
  values that feed ``SyncReport`` / ``GenResult.stats()`` are unchanged —
  the span *additionally* lands in the tracer's event log.
- Spans nest (``span("step")`` around ``span("bucket_sync", bucket=i)``);
  the recorded depth/intervals reconstruct the phase tree offline.
- ``chrome_trace()`` / ``save()`` export the Chrome ``traceEvents`` JSON
  (load in ``chrome://tracing`` or https://ui.perfetto.dev).
- A *disabled* tracer is free: ``span()`` returns a shared no-op singleton
  (no event, no allocation that survives the call), so library code can
  trace unconditionally.
- ``jax_annotations=True`` additionally brackets every span with
  ``jax.profiler.TraceAnnotation`` so a device-side profile collected with
  ``jax.profiler.trace()`` carries the same phase names.

Import-light by design (stdlib only unless annotations are enabled): the
rest of ``repro.obs`` must be usable from ``repro.core``/CLI tools without
pulling in a backend.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

__all__ = ["Span", "SpanEvent", "Tracer", "NULL_TRACER", "monotonic"]


def monotonic() -> float:
    """The repo's sanctioned monotonic clock — the same clock ``Tracer``
    spans run on.  Measured paths that need a raw timestamp (rather than
    a span) read time through here, so this module stays the *only* place
    in ``src/repro`` that touches ``time`` directly; the determinism
    analyzer (DT102 in ``repro.analysis``) enforces exactly that."""
    return time.perf_counter()


@dataclass(frozen=True)
class SpanEvent:
    """One finished span: start offset from the tracer epoch + duration."""

    name: str
    t0_s: float          # start, seconds since the tracer's epoch
    dur_s: float         # wall-clock duration [s]
    depth: int           # nesting depth at entry (0 = top level, per thread)
    tid: int             # python thread id the span ran on
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def t1_s(self) -> float:
        return self.t0_s + self.dur_s


class _NullSpan:
    """Shared no-op span — the disabled tracer's zero-cost fast path."""

    __slots__ = ()
    elapsed_s = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Span:
    """A live span; use as a context manager.  ``elapsed_s`` after exit is
    the phase wall clock (mid-flight it reads the running elapsed)."""

    __slots__ = ("tracer", "name", "args", "t0", "t1", "depth", "_ann")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0
        self.depth = 0
        self._ann = None

    @property
    def elapsed_s(self) -> float:
        if self.t1:
            return self.t1 - self.t0
        return (self.tracer._clock() - self.t0) if self.t0 else 0.0

    def __enter__(self) -> "Span":
        tr = self.tracer
        stack = tr._thread_stack()
        self.depth = len(stack)
        stack.append(self.name)
        if tr.jax_annotations:
            self._ann = tr._annotation(self.name)
            if self._ann is not None:
                self._ann.__enter__()
        self.t0 = tr._clock()  # last: annotation setup stays untimed
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = self.tracer._clock()  # first: recording stays untimed
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        self.tracer._record(self)
        return False


class Tracer:
    """Phase-level wall-clock tracing with near-zero overhead when disabled.

    ``max_events`` bounds memory on long runs: past the cap new spans still
    time correctly (their ``elapsed_s`` keeps feeding the metrics that need
    it) but are not recorded; ``dropped`` counts them.
    """

    def __init__(self, enabled: bool = True, *, max_events: int = 100_000,
                 jax_annotations: bool = False, clock=time.perf_counter):
        self._enabled = bool(enabled)
        self.max_events = int(max_events)
        self.jax_annotations = bool(jax_annotations)
        self._clock = clock
        self._epoch = clock()
        self._events: List[SpanEvent] = []
        self._local = threading.local()
        self.dropped = 0

    # -- span creation -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def span(self, name: str, **args) -> Union[Span, _NullSpan]:
        """Open a (nestable) span.  Disabled tracers return the shared
        no-op singleton — nothing is timed or recorded."""
        if not self._enabled:
            return NULL_SPAN
        return Span(self, name, args or None)

    # -- internals ---------------------------------------------------------
    def _thread_stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @staticmethod
    def _annotation(name: str):
        try:
            from jax.profiler import TraceAnnotation
        except Exception:  # no backend: annotations silently off
            return None
        return TraceAnnotation(name)

    def _record(self, span: Span) -> None:
        stack = self._thread_stack()
        if stack:
            stack.pop()
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(SpanEvent(
            name=span.name, t0_s=span.t0 - self._epoch,
            dur_s=span.t1 - span.t0, depth=span.depth,
            tid=threading.get_ident(),
            args=dict(span.args) if span.args else {}))

    # -- queries -----------------------------------------------------------
    def events(self, name: Optional[str] = None) -> List[SpanEvent]:
        """Finished spans in completion order (children before parents),
        optionally filtered by name."""
        if name is None:
            return list(self._events)
        return [e for e in self._events if e.name == name]

    def total_s(self, name: str) -> float:
        """Summed duration of every span named ``name`` — the reconciliation
        hook: phase span sums must match the legacy perf_counter totals."""
        return sum(e.dur_s for e in self._events if e.name == name)

    def summarize(self) -> Dict[str, Dict[str, float]]:
        """Per-name count/total/mean/min/max over the recorded spans."""
        acc: Dict[str, List[float]] = {}
        for e in self._events:
            acc.setdefault(e.name, []).append(e.dur_s)
        return {
            name: {"count": float(len(ds)), "total_s": sum(ds),
                   "mean_s": sum(ds) / len(ds),
                   "min_s": min(ds), "max_s": max(ds)}
            for name, ds in sorted(acc.items())}

    def clear(self) -> None:
        self._events = []
        self.dropped = 0
        self._epoch = self._clock()

    # -- export ------------------------------------------------------------
    def chrome_trace(self, *, pid: int = 1,
                     process_name: str = "repro") -> Dict[str, Any]:
        """The Chrome ``traceEvents`` dict (``ph: "X"`` complete events, µs
        timestamps) — viewable in chrome://tracing or Perfetto."""
        tids: Dict[int, int] = {}
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": process_name}}]
        for e in self._events:
            tid = tids.setdefault(e.tid, len(tids))
            ev: Dict[str, Any] = {
                "name": e.name, "cat": "repro", "ph": "X", "pid": pid,
                "tid": tid, "ts": e.t0_s * 1e6, "dur": e.dur_s * 1e6}
            if e.args:
                ev["args"] = e.args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: Union[str, Path], **kw) -> Path:
        """Write ``chrome_trace()`` JSON to ``path`` (dirs created)."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.chrome_trace(**kw)))
        return p

    def __len__(self) -> int:
        return len(self._events)


# One shared disabled tracer: hot paths default to it so tracing is always
# written unconditionally (`with tracer.span(...)`) and costs ~a dict lookup
# when nobody is listening.
NULL_TRACER = Tracer(enabled=False)
