"""MetricsRegistry — counters, gauges, histograms, and the ``metrics/v1``
report section.

Every runtime subsystem publishes into one of these: the
``DataParallelTrainer`` (per-phase step times, per-bucket comm, overlap
fraction), the serving ``Engine``/``BatchScheduler`` (prefill/decode
latency, tokens/s, queue depth), and the ``Session.tune`` calibration loop.
``MetricsRegistry.section()`` renders the registry as the
``repro.api/metrics/v1`` dict that ``Session.train/serve/bench`` attach
under ``measured["metrics"]`` — checked by ``validate_report`` via
:func:`validate_metrics`, so every Report carries its own telemetry.

Conventions: metric names are ``area/quantity_unit`` (``train/compute_s``,
``serve/decode_s``, ``serve/queue_depth``); durations are seconds.
Histograms keep exact ``count/sum/min/max`` and a bounded reservoir sample
for the p50/p95/p99 quantiles (deterministic reservoir replacement, so CI
artifacts are reproducible).

Stdlib-only on purpose — ``repro.api.report`` imports this for validation
and must stay importable without a backend.
"""
from __future__ import annotations

import math
import random
from typing import Any, Dict, List

__all__ = ["METRICS_SCHEMA_ID", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "percentile", "validate_metrics"]

METRICS_SCHEMA_ID = "repro.api/metrics/v1"

# every histogram entry in a metrics/v1 section carries exactly these
HISTOGRAM_KEYS = ("count", "sum", "min", "max", "mean", "p50", "p95", "p99")


def percentile(values: List[float], p: float) -> float:
    """Linear-interpolated percentile (``p`` in [0, 100]) of ``values``
    (need not be sorted).  Matches ``numpy.percentile``'s default."""
    if not values:
        raise ValueError("percentile of empty sequence")
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"p must be in [0, 100], got {p}")
    xs = sorted(values)
    rank = (p / 100.0) * (len(xs) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(xs[int(rank)])
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class Counter:
    """Monotonic count (events, tokens, steps)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (overlap fraction, tokens/s)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution: exact count/sum/min/max plus a bounded
    reservoir for quantiles.  Up to ``max_samples`` observations the
    quantiles are exact; past it, classic reservoir sampling (seeded, so
    summaries are reproducible) keeps a uniform sample."""

    __slots__ = ("count", "sum", "min", "max", "max_samples", "_samples",
                 "_rng")

    def __init__(self, max_samples: int = 4096, seed: int = 0):
        if max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.max_samples = max_samples
        self._samples: List[float] = []
        self._rng = random.Random(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self._samples) < self.max_samples:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.max_samples:
                self._samples[j] = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, p: float) -> float:
        if not self._samples:
            raise ValueError("quantile of empty histogram")
        return percentile(self._samples, p)

    def summary(self) -> Dict[str, float]:
        """The metrics/v1 histogram entry (raises on an empty histogram —
        empty histograms are skipped at section time instead)."""
        return {"count": int(self.count), "sum": self.sum,
                "min": self.min, "max": self.max, "mean": self.mean,
                "p50": self.quantile(50), "p95": self.quantile(95),
                "p99": self.quantile(99)}


class MetricsRegistry:
    """Get-or-create named counters/gauges/histograms + the section dump."""

    def __init__(self, *, hist_max_samples: int = 4096):
        self._hist_max_samples = hist_max_samples
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                max_samples=self._hist_max_samples)
        return h

    # -- one-line publishing (the hot-path spelling) -----------------------
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # -- export ------------------------------------------------------------
    def section(self) -> Dict[str, Any]:
        """The ``repro.api/metrics/v1`` dict (empty histograms skipped)."""
        return {
            "schema": METRICS_SCHEMA_ID,
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())
                           if h.count},
        }


# ---------------------------------------------------------------------------
# Schema check (hand-rolled, like repro.api.report: no jsonschema in image)
# ---------------------------------------------------------------------------


def _require(cond: bool, msg: str):
    if not cond:
        raise ValueError(f"invalid metrics/v1 section: {msg}")


def _num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_metrics(m: Any) -> Dict[str, Any]:
    """Raise ValueError unless ``m`` is a valid metrics/v1 dict; returns it.

    Checks the schema id, section shapes, counter monotonicity (>= 0), and
    per-histogram internal consistency (count >= 1, required keys,
    min <= p50 <= p95 <= p99 <= max)."""
    _require(isinstance(m, dict), f"expected dict, got {type(m).__name__}")
    _require(m.get("schema") == METRICS_SCHEMA_ID,
             f"schema {m.get('schema')!r} != {METRICS_SCHEMA_ID!r}")
    for sect in ("counters", "gauges", "histograms"):
        _require(sect in m, f"missing section {sect!r}")
        _require(isinstance(m[sect], dict), f"{sect} must be a dict")
    for name, v in m["counters"].items():
        _require(_num(v) and v >= 0, f"counter {name!r} must be >= 0, "
                 f"got {v!r}")
    for name, v in m["gauges"].items():
        _require(_num(v), f"gauge {name!r} must be numeric, got {v!r}")
    eps = 1e-12
    for name, h in m["histograms"].items():
        _require(isinstance(h, dict), f"histogram {name!r} must be a dict")
        for key in HISTOGRAM_KEYS:
            _require(key in h, f"histogram {name!r} missing {key!r}")
            _require(_num(h[key]), f"histogram {name!r}.{key} must be "
                     f"numeric, got {h[key]!r}")
        _require(h["count"] >= 1, f"histogram {name!r}.count must be >= 1")
        _require(h["min"] <= h["p50"] + eps <= h["p95"] + 2 * eps
                 <= h["p99"] + 3 * eps <= h["max"] + 4 * eps,
                 f"histogram {name!r} quantiles out of order: "
                 f"min={h['min']} p50={h['p50']} p95={h['p95']} "
                 f"p99={h['p99']} max={h['max']}")
    return m
