"""PipelineTrainer — executable 1F1B pipeline parallelism over a
``(pipe, data)`` mesh.

The registry model's cycle stack is cut into ``pipe`` contiguous stage
groups (:func:`repro.core.pipeline.balanced_stage_cut`); each stage holds
only its slice of the stacked slot parameters (stage 0 additionally the
embedding + prelude, the last stage the final norm and LM head).  A step
runs the non-interleaved 1F1B schedule (:func:`schedule_1f1b`) host-
orchestrated: every ``(stage, fwd|bwd, microbatch)`` op is one jitted
``shard_map`` call over that stage's flat ``data`` mesh, timed as a tracer
span (``pipe_fwd`` / ``pipe_bwd`` with ``stage``/``micro`` args).  The
measured span durations replay through :func:`simulate_1f1b` so the
per-step bubble fraction is reconciled against the analytic
``(p-1)/(m+p-1)`` model — that is :meth:`pipeline_report`.

Numerics are *bit-identical* to the single-stage
:class:`~repro.distributed.trainer.DataParallelTrainer` run on
``world // pipe`` devices with ``run.microbatch`` set to this trainer's
per-device microbatch rows, on the same token stream (asserted per
strategy by ``tests/test_pipeline.py``):

* the stage forward reuses the exact single-stage op sequence
  (``cast_params`` → embed → prelude scan → ``M._scan_cycles`` over the
  stage's cycle slice → final norm → logits → masked CE), so a
  microbatch's loss is the same op sequence split at cycle boundaries;
* the backward recomputes the stage forward under ``jax.vjp`` — the same
  deterministic ops on the same inputs the baseline's backward consumes;
* gradients accumulate into fp32 zeros with ``jnp.add`` in microbatch
  index order then divide by ``m`` — exactly
  :func:`repro.launch.steps.build_grad_fn`'s accumulation scan (1F1B
  completes backwards in index order on every stage, so the order
  matches);
* each stage syncs its gradient shard over its own flat ``data`` mesh
  with the same strategy: every member of the collectives zoo is
  element-wise over the data axis, so the per-stage sync of a slice
  equals the slice of the full sync;
* the synced shards reassemble into the full gradient tree (slot slices
  concatenate along the cycle axis; the tied embedding's two cotangents
  — lookup and head — add once, like autodiff's own accumulation) and
  ONE replicated :func:`~repro.optim.adamw.apply_updates` applies them,
  so the global gradient-norm clip sees the identical leaf set.

The tied-embedding cotangent add is fp32-exact only when ``cfg.dtype`` is
float32 (under bf16 compute the baseline sums the two cotangents in bf16
at the cast boundary); the bit-match tests therefore pin
``dtype="float32"`` while bf16 runs agree within mixed-precision
tolerance.

Bit-identity additionally requires every stage to hold **at least two
cycles**: a single-cycle stage lowers its ``lax.scan`` with trip count 1,
which XLA's while-loop simplifier inlines and re-fuses with the
surrounding stage ops — ulp-level reassociation relative to the
baseline's intact loop body (observed empirically: 1-cycle stages drift
at ~1e-7 relative, 2-cycle stages match exactly).  ``balanced_stage_cut``
yields ≥2-cycle stages whenever ``main_cycles(cfg) >= 2 * pipe``.

Restrictions: multi-codebook embeddings, VLM image prefixes, stateful
(error-feedback) compressors and ``unroll_layers`` are rejected — each
breaks the contiguous-stage or element-wise-sync argument above.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, SlotSpec
from repro.core.pipeline import (StepTimes, balanced_stage_cut,
                                 pipeline_bubble, schedule_1f1b,
                                 simulate_1f1b, simulate_serial)
from repro.distributed.collectives import SyncStrategy, get_strategy
from repro.distributed.compression import Compressor, get_compressor
from repro.distributed.trainer import (DEFAULT_LINK_BW, SyncReport, _stack,
                                       _unstack)
from repro.models import model as M
from repro.models.blocks import RunConfig, slot_forward
from repro.models.common import cross_entropy, materialize, rms_norm
from repro.obs import MetricsRegistry, Tracer
from repro.optim import adamw as opt_lib
from repro.train import loop as loop_lib


@dataclass
class PipelineReport:
    """Measured-vs-model 1F1B schedule numbers for one training run."""

    pipe: int
    n_microbatch: int
    stage_cut: Tuple[int, ...]
    bubble_measured: float      # span durations replayed via simulate_1f1b
    bubble_model: float         # (p-1)/(m+p-1)
    bubble_serial: float        # the no-overlap reference schedule
    makespan_s: float
    stage_busy_s: Tuple[float, ...]
    fwd_times_s: Tuple[Tuple[float, ...], ...]   # [stage][micro]
    bwd_times_s: Tuple[Tuple[float, ...], ...]

    def as_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def _stage_params(params, cfg: ModelConfig, cut: Tuple[int, ...], s: int):
    """Stage ``s``'s parameter slice: slot stacks cut ``cut[s]:cut[s+1]``
    along the cycle axis, plus embedding/prelude on stage 0 and final norm
    (+ LM head, or the tied embedding under the ``embed_out`` key so its
    head cotangent stays separable) on the last stage."""
    p = len(cut) - 1
    sp: Dict[str, Any] = {
        "slots": jax.tree_util.tree_map(
            lambda a: a[cut[s]:cut[s + 1]], params["slots"])
    }
    if s == 0:
        sp["embed"] = params["embed"]
        if cfg.first_k_dense:
            sp["prelude"] = params["prelude"]
    if s == p - 1:
        sp["final_norm"] = params["final_norm"]
        if cfg.tie_embeddings:
            if p > 1:
                sp["embed_out"] = params["embed"]
            # p == 1: the stage's own "embed" serves lookup AND head, so
            # autodiff itself sums the two cotangents — like the baseline
        elif "lm_head" in params:
            sp["lm_head"] = params["lm_head"]
    return sp


def _positions(h):
    B, S = h.shape[:2]
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


class PipelineTrainer:
    """Host-orchestrated 1F1B over ``pipe`` stages x ``world // pipe`` data
    shards, loop-compatible (``step_fn`` / ``train`` / ``report``) with the
    DataParallelTrainer so the Session can swap it in."""

    def __init__(self, cfg: ModelConfig, run: RunConfig,
                 opt: opt_lib.OptConfig, *,
                 pipe: int, n_microbatch: int = 0,
                 strategy: Union[str, SyncStrategy] = "all_reduce",
                 compression: Union[str, Compressor] = "none",
                 devices: Optional[List] = None,
                 link_bw: float = DEFAULT_LINK_BW,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if cfg.num_codebooks:
            raise NotImplementedError(
                "pipeline stages need a single token embedding "
                "(multi-codebook unsupported)")
        if cfg.num_image_tokens:
            raise NotImplementedError(
                "pipeline trainer does not take VLM image prefixes")
        if run.unroll_layers:
            raise NotImplementedError(
                "pipeline stages scan their cycle slice; unroll_layers "
                "is incompatible")
        if run.microbatch:
            raise ValueError(
                "set n_microbatch on the trainer, not run.microbatch — "
                "1F1B owns the microbatch loop")
        self.cfg, self.run, self.opt = cfg, run, opt
        self.tracer = (tracer if tracer is not None and tracer.enabled
                       else Tracer(enabled=True))
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.strategy = (get_strategy(strategy)
                         if isinstance(strategy, str) else strategy)
        self.compressor = (get_compressor(compression)
                           if isinstance(compression, str) else compression)
        if self.compressor.stateful:
            raise NotImplementedError(
                "stateful (error-feedback) compressors are not supported "
                "under the pipeline trainer")
        devs = list(devices if devices is not None else jax.devices())
        if pipe < 1 or len(devs) % pipe:
            raise ValueError(f"pipe={pipe} must divide the {len(devs)} "
                             "visible devices")
        self.pipe = int(pipe)
        self.dp = len(devs) // self.pipe          # data shards per stage
        self.n_microbatch = int(n_microbatch) or self.pipe
        if self.n_microbatch < self.pipe:
            raise ValueError(f"n_microbatch={self.n_microbatch} must be >= "
                             f"pipe={self.pipe} (1F1B needs a full fill)")
        if self.strategy.hierarchical:
            # per-stage meshes are flat: degenerate single-tier sizing,
            # exactly what the baseline resolves without a topology
            self.strategy = dataclasses.replace(self.strategy,
                                                tiers=(self.dp,))
        self.cycles = M.main_cycles(cfg)
        self.stage_cut = balanced_stage_cut(self.cycles, self.pipe)
        # one global mesh declares the (pipe, data) axes (analysis/mesh_axes
        # reads this literal); per-stage flat meshes execute the stage
        # programs — a stage's flat mesh syncs exactly like the baseline's
        grid = np.array(devs).reshape(self.pipe, self.dp)
        self.mesh = Mesh(grid, ("pipe", "data"))
        self.stage_meshes = [Mesh(grid[s], ("data",))
                             for s in range(self.pipe)]
        self.link_bw = link_bw
        self._grad_bytes = 0.0
        self._times: List[StepTimes] = []
        # per-step measured op durations: [step][stage][micro]
        self._fwd_obs: List[List[List[float]]] = []
        self._bwd_obs: List[List[List[float]]] = []
        self._build_phases()

    # ------------------------------------------------------------------
    @classmethod
    def from_plan(cls, plan, cfg: ModelConfig, run: RunConfig,
                  opt: opt_lib.OptConfig, *,
                  compression: Union[str, Compressor] = "none",
                  devices: Optional[List] = None,
                  link_bw: float = DEFAULT_LINK_BW,
                  tracer: Optional[Tracer] = None,
                  metrics: Optional[MetricsRegistry] = None
                  ) -> "PipelineTrainer":
        """Trainer whose stage count / microbatching / sync strategy come
        from a planner ``Plan`` (``resolve_sync()`` supplies the
        Lemma-3.2-sized strategy instance)."""
        return cls(cfg, run, opt, pipe=int(getattr(plan, "pipe", 1) or 1),
                   n_microbatch=int(getattr(plan, "n_microbatch", 0) or 0),
                   strategy=plan.resolve_sync(), compression=compression,
                   devices=devices, link_bw=link_bw, tracer=tracer,
                   metrics=metrics)

    # ------------------------------------------------------------------
    # Stage programs
    # ------------------------------------------------------------------
    def _inner_fns(self):
        """Unsharded per-stage computations over stage-sliced params.

        The carry between stages is ``(h, aux)`` — activations plus the
        running MoE aux-loss sum; every stage's aux cotangent is the
        constant ``0.01`` (the ``aux_weight`` in
        :func:`repro.models.model.loss_fn`), so backward never threads it.
        """
        cfg, run, p = self.cfg, self.run, self.pipe

        def embed_prelude(cp, batch):
            h = M.embed_tokens(cp, batch, cfg)
            pos = _positions(h)
            if cfg.first_k_dense:
                pre_slot = SlotSpec(cfg.pattern[0].mixer, "dense")

                def pre_cycle(h, layer_params):
                    h, _, _ = slot_forward(layer_params, h, pos, cfg,
                                           pre_slot, run)
                    return h, None

                h, _ = jax.lax.scan(pre_cycle, h, cp["prelude"])
            return h, pos

        def first(sp, batch):
            """Stage 0 of p > 1: tokens -> (h, aux)."""
            cp = M.cast_params(sp, cfg)
            h, pos = embed_prelude(cp, batch)
            h, _, aux = M._scan_cycles(cp, h, pos, cfg, run, False)
            return h, jnp.asarray(aux, jnp.float32)

        def mid(sp, h, aux_in):
            """Interior stage: (h, aux) -> (h, aux)."""
            cp = M.cast_params(sp, cfg)
            h, _, aux = M._scan_cycles(cp, h, _positions(h), cfg, run, False)
            return h, aux_in + jnp.asarray(aux, jnp.float32)

        def head_loss(cp, h, batch, aux):
            h = rms_norm(h, cp["final_norm"], cfg.norm_eps)
            head = ({"embed": cp.get("embed_out", cp.get("embed"))}
                    if cfg.tie_embeddings else {"lm_head": cp["lm_head"]})
            logits = M.lm_logits(head, h, cfg)
            labels = batch["labels"]
            mask = (labels >= 0).astype(jnp.float32)
            ce = cross_entropy(logits, jnp.maximum(labels, 0), mask)
            return ce + 0.01 * aux

        def last(sp, batch, h, aux_in):
            """Final stage of p > 1: (h, aux) + labels -> loss."""
            cp = M.cast_params(sp, cfg)
            h, _, aux = M._scan_cycles(cp, h, _positions(h), cfg, run, False)
            return head_loss(cp, h, batch,
                             aux_in + jnp.asarray(aux, jnp.float32))

        def solo(sp, batch):
            """p == 1: the whole model, loss_fn's exact op sequence."""
            cp = M.cast_params(sp, cfg)
            h, pos = embed_prelude(cp, batch)
            h, _, aux = M._scan_cycles(cp, h, pos, cfg, run, False)
            return head_loss(cp, h, batch, jnp.asarray(aux, jnp.float32))

        return first, mid, last, solo

    def _build_phases(self):
        p, dp = self.pipe, self.dp
        strat, comp, m = self.strategy, self.compressor, self.n_microbatch
        first, mid, last, solo = self._inner_fns()
        cot_aux = jnp.asarray(0.01, jnp.float32)  # d loss / d aux_s

        # fwd: op call per (stage, microbatch); bwd: jax.vjp recompute.
        # Stacked (leading per-device axis) outputs mirror the baseline's
        # _stack convention so out_specs P("data") concatenates shards.
        self._fwd_fns: List[Any] = []
        self._bwd_fns: List[Any] = []
        for s in range(p):
            mesh, d = self.stage_meshes[s], P("data")
            if p == 1:
                def fwd_solo(sp, b):
                    return _stack(solo(sp, b))

                def bwd_solo(sp, b):
                    gp = jax.grad(solo)(sp, b)
                    return _stack(gp)

                self._fwd_fns.append(jax.jit(shard_map(
                    fwd_solo, mesh=mesh, in_specs=(P(), d), out_specs=d)))
                self._bwd_fns.append(jax.jit(shard_map(
                    bwd_solo, mesh=mesh, in_specs=(P(), d), out_specs=d)))
            elif s == 0:
                def fwd_first(sp, b):
                    h, aux = first(sp, b)
                    return h, _stack(aux)

                if self.cfg.tie_embeddings:
                    # fold the head cotangent (shipped from the last
                    # stage) into the lookup cotangent per microbatch —
                    # the add autodiff performs for the shared tied leaf,
                    # BEFORE accumulation, so the association matches
                    def bwd_first(sp, b, gy, gemb):
                        _, vjp = jax.vjp(lambda sp_: first(sp_, b), sp)
                        (gp,) = vjp((gy, cot_aux))
                        gp = dict(gp)
                        gp["embed"] = gp["embed"] + _unstack(gemb)
                        return _stack(gp)

                    self._bwd_fns.append(jax.jit(shard_map(
                        bwd_first, mesh=mesh, in_specs=(P(), d, d, d),
                        out_specs=d)))
                else:
                    def bwd_first(sp, b, gy):
                        _, vjp = jax.vjp(lambda sp_: first(sp_, b), sp)
                        (gp,) = vjp((gy, cot_aux))
                        return _stack(gp)

                    self._bwd_fns.append(jax.jit(shard_map(
                        bwd_first, mesh=mesh, in_specs=(P(), d, d),
                        out_specs=d)))
                self._fwd_fns.append(jax.jit(shard_map(
                    fwd_first, mesh=mesh, in_specs=(P(), d),
                    out_specs=(d, d))))
            elif s < p - 1:
                def fwd_mid(sp, h, aux):
                    h, aux = mid(sp, h, _unstack(aux))
                    return h, _stack(aux)

                def bwd_mid(sp, h, gy):
                    _, vjp = jax.vjp(
                        lambda sp_, h_: mid(sp_, h_, jnp.float32(0.0)),
                        sp, h)
                    gp, gh = vjp((gy, cot_aux))
                    return _stack(gp), gh

                self._fwd_fns.append(jax.jit(shard_map(
                    fwd_mid, mesh=mesh, in_specs=(P(), d, d),
                    out_specs=(d, d))))
                self._bwd_fns.append(jax.jit(shard_map(
                    bwd_mid, mesh=mesh, in_specs=(P(), d, d),
                    out_specs=(d, d))))
            else:
                def fwd_last(sp, b, h, aux):
                    return _stack(last(sp, b, h, _unstack(aux)))

                if self.cfg.tie_embeddings:
                    def bwd_last(sp, b, h):
                        # aux_in enters the loss additively (x 0.01): it
                        # never touches this stage's cotangents, so
                        # backward runs with aux_in = 0, bitwise identical
                        gp, gh = jax.grad(
                            lambda sp_, h_: last(sp_, b, h_,
                                                 jnp.float32(0.0)),
                            argnums=(0, 1))(sp, h)
                        gp = dict(gp)
                        gemb = gp.pop("embed_out")
                        return _stack(gp), _stack(gemb), gh

                    self._bwd_fns.append(jax.jit(shard_map(
                        bwd_last, mesh=mesh, in_specs=(P(), d, d),
                        out_specs=(d, d, d))))
                else:
                    def bwd_last(sp, b, h):
                        gp, gh = jax.grad(
                            lambda sp_, h_: last(sp_, b, h_,
                                                 jnp.float32(0.0)),
                            argnums=(0, 1))(sp, h)
                        return _stack(gp), gh

                    self._bwd_fns.append(jax.jit(shard_map(
                        bwd_last, mesh=mesh, in_specs=(P(), d, d),
                        out_specs=(d, d))))
                self._fwd_fns.append(jax.jit(shard_map(
                    fwd_last, mesh=mesh, in_specs=(P(), d, d, d),
                    out_specs=d)))

        # per-stage gradient sync: divide the microbatch sum by m (exactly
        # build_grad_fn's gsum / n), compress, then the strategy's data-
        # axis mean — the baseline's sync_phase over this stage's mesh
        self._sync_fns = []
        for s in range(p):
            def sync_one(gstack):
                g = _unstack(gstack)
                g = jax.tree_util.tree_map(lambda x: x / m, g)
                g, _ = comp.apply(g, None)
                return strat.sync(g, "data", dp)

            self._sync_fns.append(jax.jit(shard_map(
                sync_one, mesh=self.stage_meshes[s],
                in_specs=(P("data"),), out_specs=P())))

        # fp32 accumulators: zeros + g first (build_grad_fn starts from
        # zeros, and 0 + g is the baseline's first scan add), then g + g'
        self._acc_first = jax.jit(
            lambda g: jax.tree_util.tree_map(
                lambda x: jnp.zeros_like(x) + x, g))
        self._acc_add = jax.jit(
            lambda a, g: jax.tree_util.tree_map(jnp.add, a, g))
        self._loss_add = jax.jit(jnp.add)
        self._update_fn = jax.jit(
            lambda prm, st, g: opt_lib.apply_updates(self.opt, prm, g, st),
            donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        """Replicated fp32 master params + opt state on the global mesh."""
        params = materialize(M.model_specs(self.cfg),
                             jax.random.PRNGKey(seed))
        state = opt_lib.init_state(self.opt, params)
        rep = NamedSharding(self.mesh, P())
        params = jax.device_put(params, rep)
        state = jax.device_put(state, rep)
        self._grad_bytes = 4.0 * sum(
            int(np.prod(a.shape))
            for a in jax.tree_util.tree_leaves(params))
        return params, state

    def _stage_views(self, params):
        """Per-stage replicated views of the master params — the Fig.-1
        'parameter refresh' onto each stage's devices."""
        host = jax.tree_util.tree_map(np.asarray, params)
        return [
            jax.device_put(_stage_params(host, self.cfg, self.stage_cut, s),
                           NamedSharding(self.stage_meshes[s], P()))
            for s in range(self.pipe)
        ]

    def _shard_batch(self, batch, j: int):
        """Microbatch ``j``'s rows, dp-major: data shard ``d`` gets exactly
        the rows the baseline's device ``d`` consumes in accumulation-scan
        step ``j``."""
        m, dp = self.n_microbatch, self.dp
        out = {}
        for k, v in batch.items():
            arr = np.asarray(v)
            rows = arr.shape[0] // (dp * m)
            mb = arr.reshape((dp, m, rows) + arr.shape[1:])[:, j]
            out[k] = mb.reshape((dp * rows,) + arr.shape[1:])
        return out

    def _to_stage(self, x, s: int):
        """Move an array onto stage ``s``'s mesh, sharded over its data
        axis (host round-trip: bit-exact, device-set agnostic)."""
        return jax.device_put(np.asarray(x),
                              NamedSharding(self.stage_meshes[s], P("data")))

    def _reassemble(self, stage_grads):
        """Full gradient tree from the per-stage synced shards (leaf set
        and order identical to the baseline's grads, so the global-norm
        clip sees the same reduction)."""
        cfg, p = self.cfg, self.pipe
        rep = NamedSharding(self.mesh, P())
        gs = [jax.device_put(jax.tree_util.tree_map(np.asarray, g), rep)
              for g in stage_grads]
        full: Dict[str, Any] = {
            "slots": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0),
                *[g["slots"] for g in gs])
        }
        g0, gl = gs[0], gs[-1]
        # tied head cotangents were already folded into stage 0's embed
        # grad per microbatch (see bwd_first), so "embed" is complete here
        full["embed"] = g0["embed"]
        if not cfg.tie_embeddings and "lm_head" in gl:
            full["lm_head"] = gl["lm_head"]
        if cfg.first_k_dense:
            full["prelude"] = g0["prelude"]
        full["final_norm"] = gl["final_norm"]
        return full

    # ------------------------------------------------------------------
    def step_fn(self):
        """Loop-compatible step: one 1F1B round over ``m`` microbatches,
        per-stage sync, one replicated optimizer update."""
        p, m = self.pipe, self.n_microbatch
        order = schedule_1f1b(p, m)
        tr = self.tracer

        def step(params, opt_state, batch):
            with tr.span("param_refresh"):
                views = self._stage_views(params)
            micro = [self._shard_batch(batch, j) for j in range(m)]
            fwd_t = [[0.0] * m for _ in range(p)]
            bwd_t = [[0.0] * m for _ in range(p)]
            h_save: Dict[Tuple[int, int], Any] = {}   # stage input acts
            g_save: Dict[Tuple[int, int], Any] = {}   # pending h cotangents
            acc: List[Any] = [None] * p
            lsum = None
            with tr.span("compute"):
                for (s, kind, j) in order:
                    if kind == "fwd":
                        with tr.span("pipe_fwd", stage=s, micro=j) as sp:
                            out = self._run_fwd(s, j, views, micro, h_save)
                            jax.block_until_ready(out)
                        fwd_t[s][j] = sp.elapsed_s
                        if s == p - 1:
                            lsum = (out if lsum is None
                                    else self._loss_add(lsum, out))
                    else:
                        with tr.span("pipe_bwd", stage=s, micro=j) as sp:
                            gp = self._run_bwd(s, j, views, micro, h_save,
                                               g_save)
                            acc[s] = (self._acc_first(gp) if acc[s] is None
                                      else self._acc_add(acc[s], gp))
                            jax.block_until_ready(
                                jax.tree_util.tree_leaves(acc[s])[0])
                        bwd_t[s][j] = sp.elapsed_s
            with tr.span("dist_update") as sp_s:
                synced = []
                for s in range(p):
                    with tr.span("pipe_sync", stage=s):
                        g = self._sync_fns[s](acc[s])
                        jax.block_until_ready(
                            jax.tree_util.tree_leaves(g)[0])
                    synced.append(g)
            with tr.span("param_update") as sp_u:
                grads = self._reassemble(synced)
                params, opt_state, gnorm = self._update_fn(
                    params, opt_state, grads)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(params)[0])
            self._fwd_obs.append(fwd_t)
            self._bwd_obs.append(bwd_t)
            self._publish(fwd_t, bwd_t, sp_s.elapsed_s, sp_u.elapsed_s)
            losses = jnp.asarray(lsum).reshape(-1) / m
            metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm,
                       "t_comm": sp_s.elapsed_s, "t_update": sp_u.elapsed_s}
            return params, opt_state, metrics

        return step

    def _run_fwd(self, s, j, views, micro, h_save):
        p = self.pipe
        if p == 1:
            b = {k: self._to_stage(v, 0) for k, v in micro[j].items()}
            h_save[(0, j)] = b
            return self._fwd_fns[0](views[0], b)
        if s == 0:
            b = {"tokens": self._to_stage(micro[j]["tokens"], 0)}
            h_save[(0, j)] = b
            h, aux = self._fwd_fns[0](views[0], b)
            h_save[("out", 0, j)] = (h, aux)
            return h
        h_prev, aux_prev = h_save.pop(("out", s - 1, j))
        h_in = self._to_stage(h_prev, s)
        aux_in = self._to_stage(aux_prev, s)
        if s == self.pipe - 1:
            b = {"labels": self._to_stage(micro[j]["labels"], s)}
            h_save[(s, j)] = (b, h_in)
            return self._fwd_fns[s](views[s], b, h_in, aux_in)
        h_save[(s, j)] = h_in
        h, aux = self._fwd_fns[s](views[s], h_in, aux_in)
        h_save[("out", s, j)] = (h, aux)
        return h

    def _run_bwd(self, s, j, views, micro, h_save, g_save):
        p = self.pipe
        if p == 1:
            b = h_save.pop((0, j))
            return self._bwd_fns[0](views[0], b)
        if s == p - 1:
            b, h_in = h_save.pop((s, j))
            if self.cfg.tie_embeddings:
                gp, gemb, gh = self._bwd_fns[s](views[s], b, h_in)
                g_save[("emb", j)] = gemb
            else:
                gp, gh = self._bwd_fns[s](views[s], b, h_in)
            g_save[(s - 1, j)] = gh
            return gp
        gy = self._to_stage(g_save.pop((s, j)), s)
        if s == 0:
            b = h_save.pop((0, j))
            if self.cfg.tie_embeddings:
                gemb = self._to_stage(g_save.pop(("emb", j)), 0)
                return self._bwd_fns[0](views[0], b, gy, gemb)
            return self._bwd_fns[0](views[0], b, gy)
        h_in = h_save.pop((s, j))
        gp, gh = self._bwd_fns[s](views[s], h_in, gy)
        g_save[(s - 1, j)] = gh
        return gp

    def _publish(self, fwd_t, bwd_t, comm_s, upd_s):
        m = self.metrics
        busy = sum(sum(row) for row in fwd_t) + sum(sum(r) for r in bwd_t)
        m.inc("train/steps")
        m.observe("train/compute_s", busy)
        m.observe("train/dist_update_s", comm_s)
        m.observe("train/param_update_s", upd_s)
        m.observe("train/step_s", busy + comm_s + upd_s)

    # ------------------------------------------------------------------
    def train(self, *, batch: int, seq: int, steps: int, seed: int = 0,
              log_every: int = 10, params=None, opt_state=None,
              ckpt_dir: Optional[str] = None,
              ckpt_every: int = 0) -> loop_lib.TrainResult:
        rows = self.dp * self.n_microbatch
        if batch % rows:
            raise ValueError(
                f"batch {batch} not divisible by dp*n_microbatch={rows} "
                "(equal microbatch shards are required for exact means)")
        self._fwd_obs, self._bwd_obs = [], []
        if params is None or opt_state is None:
            params, opt_state = self.init(seed)
        elif self._grad_bytes == 0:
            self._grad_bytes = 4.0 * sum(
                int(np.prod(a.shape))
                for a in jax.tree_util.tree_leaves(params))
        # batch_sharding=None: the loader hands the step host batches and
        # the 1F1B orchestration owns every h2d placement
        res = loop_lib.train(
            self.cfg, self.run, self.opt, batch=batch, seq=seq, steps=steps,
            seed=seed, log_every=log_every, params=params,
            opt_state=opt_state, step_fn=self.step_fn(),
            batch_sharding=None, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            tracer=self.tracer)
        self._times = res.step_times
        return res

    # ------------------------------------------------------------------
    def report(self) -> SyncReport:
        """Session-compatible sync view: each stage worker syncs a 1/p
        parameter shard over its own dp-wide data axis."""
        steady = self._times[2:] or self._times
        comm = (float(np.mean([t.dist_update for t in steady]))
                if steady else 0.0)
        compute = (float(np.mean([t.compute for t in steady]))
                   if steady else 0.0)
        upd = (float(np.mean([t.param_update for t in steady]))
               if steady else 0.0)
        s_p = self._grad_bytes / self.pipe
        wire_payload = self.compressor.wire_bytes(s_p)
        predicted = self.strategy.predicted_comm_time(
            wire_payload, self.dp, self.link_bw)
        r_o = (float(np.mean([t.r_o() for t in steady])) if steady else 0.0)
        return SyncReport(
            strategy=self.strategy.name, compression=self.compressor.name,
            dp=self.dp, n_servers=self.strategy.n_servers,
            grad_bytes=s_p,
            wire_bytes=self.strategy.wire_bytes(wire_payload, self.dp),
            link_bw=self.link_bw,
            measured_comm_s=comm, predicted_comm_s=predicted,
            measured_compute_s=compute, measured_update_s=upd,
            masked_measured=comm <= compute,
            masked_predicted=predicted <= compute,
            r_o_measured=r_o,
            tiers=self.strategy.tiers,
            wire_bytes_by_tier=(
                self.strategy.wire_bytes_by_tier(wire_payload, self.dp)
                if self.strategy.hierarchical else None))

    def pipeline_report(self) -> PipelineReport:
        """Replay the steady-state measured op durations through the 1F1B
        DAG and set the resulting bubble against the analytic model and
        the serial reference schedule."""
        p, m = self.pipe, self.n_microbatch
        steady_f = self._fwd_obs[2:] or self._fwd_obs
        steady_b = self._bwd_obs[2:] or self._bwd_obs
        if not steady_f:
            raise RuntimeError("pipeline_report needs at least one "
                               "measured step; run train() first")
        # best-of over steady steps, per op: host noise only inflates
        fwd = tuple(tuple(min(step[s][j] for step in steady_f)
                          for j in range(m)) for s in range(p))
        bwd = tuple(tuple(min(step[s][j] for step in steady_b)
                          for j in range(m)) for s in range(p))
        sim = simulate_1f1b(fwd, bwd)
        serial = simulate_serial(fwd, bwd)
        model = pipeline_bubble(p, m)
        self.metrics.set_gauge("train/pipe", p)
        self.metrics.set_gauge("train/n_microbatch", m)
        self.metrics.set_gauge("train/bubble_measured", sim.bubble_fraction)
        self.metrics.set_gauge("train/bubble_model", model)
        return PipelineReport(
            pipe=p, n_microbatch=m, stage_cut=self.stage_cut,
            bubble_measured=sim.bubble_fraction, bubble_model=model,
            bubble_serial=serial.bubble_fraction,
            makespan_s=sim.makespan, stage_busy_s=sim.stage_busy,
            fwd_times_s=fwd, bwd_times_s=bwd)
