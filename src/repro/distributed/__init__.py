"""Executable gradient-sync runtime — Lemma 3.2 as running code.

The planner (``repro.core.planner``) *chooses* a sync schedule from the
paper's parameter-server inequality; this package *executes* that choice on
the mesh data axis and measures what the lemma only predicts:

- :mod:`repro.distributed.collectives` — the strategy zoo (all-reduce,
  reduce-scatter + all-gather, sharded parameter-server emulation), all
  expressed over the ``data`` axis via ``shard_map``.
- :mod:`repro.distributed.compression` — gradient compression (bf16 cast,
  int8 quantization with error feedback, top-k sparsification) that shrinks
  S_p before it hits the wire.
- :mod:`repro.distributed.trainer` — ``DataParallelTrainer``: wraps the
  instrumented training loop with a chosen strategy, times the sync phase
  separately from compute, and reports measured-vs-predicted Lemma 3.1/3.2
  numbers in a :class:`SyncReport`.
- :mod:`repro.distributed.pipeline` — ``PipelineTrainer``: executable
  non-interleaved 1F1B pipeline parallelism over a ``(pipe, data)`` mesh,
  bit-identical to ``DataParallelTrainer`` on the same token stream, with
  a measured-vs-``(p-1)/(m+p-1)`` bubble reconciliation in
  :class:`PipelineReport`.
- :mod:`repro.distributed.async_ps` — ``AsyncPSTrainer``: bounded-staleness
  parameter-server sync (workers at most ``s`` steps stale, ``s=0``
  bit-identical to the synchronous ``parameter_server`` strategy) with
  backup-worker straggler mitigation (drop the slowest ``k`` of ``dp``
  gradients), reconciled against ``repro.core.ps.async_step_time`` in an
  :class:`AsyncPSReport`.
- :mod:`repro.distributed.overlap` — bucketed comm/compute overlap:
  :class:`BucketPlan` partitions the gradient pytree into size-targeted,
  grad-availability-ordered sync buckets; ``DataParallelTrainer(
  sync_overlap=True)`` executes them as dataflow-independent collective
  chains inside one fused step and measures the achieved
  ``overlap_fraction`` / ``exposed_comm_time``.

Run anything here under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
so the data axis is real (8 simulated devices) rather than napkin math.
"""
from repro.distributed.async_ps import (  # noqa: F401
    AsyncPSReport, AsyncPSTrainer,
)
from repro.distributed.collectives import (  # noqa: F401
    STRATEGIES, SyncStrategy, get_strategy, flatten_tree, unflatten_tree,
)
from repro.distributed.compression import (  # noqa: F401
    COMPRESSORS, Compressor, get_compressor,
)
from repro.distributed.overlap import (  # noqa: F401
    BucketPlan, DEFAULT_BUCKET_MB, build_bucket_plan,
)
from repro.distributed.pipeline import (  # noqa: F401
    PipelineReport, PipelineTrainer,
)
from repro.distributed.trainer import (  # noqa: F401
    DataParallelTrainer, SyncReport,
)
