"""Gradient compression — shrink S_p before it hits the wire.

Lemma 3.2's numerator is 2*S_p: every byte shaved off the gradient payload
divides the required server count / comm time directly. Three standard
compressors, each a pure per-device transform applied to the local gradient
before the sync collective (compress -> decompress -> sync), so the
collectives stay dtype-uniform while the *wire* cost is the compressed size:

- ``bf16``  — round-to-bf16 cast (2x). Stateless.
- ``int8``  — per-leaf symmetric int8 quantization (4x) with error
  feedback: the quantization residual is carried to the next step, so the
  bias vanishes in the long run (1-bit SGD / Seide et al. lineage).
- ``topk``  — magnitude top-k sparsification (keep ``ratio`` of entries,
  wire cost ~ 2*ratio for value+index) with error feedback.

Error-feedback state lives in the optimizer-state dict under ``"ef"``
(`repro.optim.adamw.init_state(..., error_feedback=True)`) so checkpointing
and donation treat it like any other slot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Compressor:
    """Named compressor: (grads, ef_state) -> (decompressed grads, new ef).

    ``ef_state`` is None for stateless compressors. ``wire_ratio`` is the
    compressed-bytes / fp32-bytes factor used by the Lemma 3.2 prediction.
    """

    name: str
    wire_ratio: float
    stateful: bool
    _apply: Callable[[Any, Optional[Any]], Tuple[Any, Optional[Any]]]

    def apply(self, grads, ef_state=None):
        return self._apply(grads, ef_state)

    def wire_bytes(self, s_p: float) -> float:
        return s_p * self.wire_ratio


def _identity(grads, ef):
    return grads, ef


def _bf16(grads, ef):
    # reduce_precision, not an astype round-trip: XLA's excess-precision
    # simplification may elide a f32->bf16->f32 convert pair depending on
    # the surrounding program, which made the "compressed" payload
    # silently full-precision in some jits (and broke the bucketed-overlap
    # path's bit-equivalence with the serial path)
    out = jax.tree_util.tree_map(
        lambda g: jax.lax.reduce_precision(g.astype(jnp.float32),
                                           exponent_bits=8, mantissa_bits=7),
        grads)
    return out, ef


def _int8_ef(grads, ef):
    if ef is None:
        ef = jax.tree_util.tree_map(jnp.zeros_like, grads)

    def q(g, e):
        v = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
        qv = jnp.clip(jnp.round(v / scale), -127, 127)
        g_hat = qv * scale
        return g_hat, v - g_hat

    flat = jax.tree_util.tree_map(q, grads, ef)
    out = jax.tree_util.tree_map(lambda t: t[0], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return out, new_ef


def _topk_ef(ratio: float):
    def apply(grads, ef):
        if ef is None:
            ef = jax.tree_util.tree_map(jnp.zeros_like, grads)

        def sparsify(g, e):
            v = g.astype(jnp.float32) + e
            flat = v.reshape(-1)
            k = max(int(flat.size * ratio), 1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
            kept = (flat * mask).reshape(v.shape)
            return kept, v - kept

        flat = jax.tree_util.tree_map(sparsify, grads, ef)
        out = jax.tree_util.tree_map(lambda t: t[0], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
        new_ef = jax.tree_util.tree_map(lambda t: t[1], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return out, new_ef

    return apply


def get_compressor(name: str, *, topk_ratio: float = 0.1) -> Compressor:
    if name in ("none", "", None):
        return Compressor("none", 1.0, False, _identity)
    if name == "bf16":
        return Compressor("bf16", 0.5, False, _bf16)
    if name == "int8":
        return Compressor("int8", 0.25, True, _int8_ef)
    if name == "topk":
        # value (4 B) + index (4 B) per kept entry
        return Compressor("topk", 2.0 * topk_ratio, True, _topk_ef(topk_ratio))
    raise KeyError(f"unknown compressor {name!r}; known: {COMPRESSORS}")


COMPRESSORS: Tuple[str, ...] = ("none", "bf16", "int8", "topk")
