"""DataParallelTrainer — data parallelism that is real rather than napkin math.

Wraps the instrumented training loop (``repro.train.loop``) with an explicit
gradient-sync strategy over the mesh ``data`` axis. The step is split into
three separately-jitted, separately-timed phases so the paper's Fig.-1 steps
map onto measured wall-clock:

  1. **compute**   — per-device local gradients (shard_map, batch sharded),
  2. **dist_update** — compress + sync collectives (the Lemma 3.2 payload),
  3. **param_update** — replicated optimizer update.

The phase times land in ``StepTimes`` (compute / dist_update / param_update)
so R_O (Lemma 3.1) is evaluated on measurements, and :meth:`report` sets the
measured comm time against the Lemma 3.2 prediction for the same schedule.

With ``sync_overlap=True`` the strict 3-phase step gives way to the
bucketed overlap schedule (``repro.distributed.overlap``): the first
:data:`~DataParallelTrainer.N_CALIB_STEPS` steps run serial-bucketed (one
blocking collective per bucket — the per-bucket serial decomposition), and
every later step is ONE fused XLA program in which each bucket's
compress→sync chain is dataflow-independent from the others and from the
optimizer update, so the scheduler overlaps them (wait-free
backpropagation as XLA sees it).  Both paths are numerically identical to
the serial trainer — same collectives over the same per-leaf payloads —
and :meth:`report` adds the measured ``overlap_fraction`` /
``exposed_comm_time`` against the serial calibration.

Telemetry (``repro.obs``): every phase above is a tracer span — ``compute``
/ ``dist_update`` / ``param_update``, ``bucket_sync`` (per bucket, with the
bucket index and payload bytes as span args) and ``fused_step`` — and the
span wall clocks ARE the values that land in ``StepTimes``/``SyncReport``
(no second clock).  The same numbers stream into a ``MetricsRegistry``
(``train/compute_s`` etc. histograms, ``train/overlap_fraction`` gauges),
which ``Session.train`` renders into the Report's ``metrics/v1`` section.

Numerics: each device computes the mean loss over its batch shard; the
strategy returns the data-axis mean, so with equal shard sizes (enforced)
the synced gradient equals the full-batch gradient up to reduction order —
every strategy must match the single-device baseline within fp32 tolerance
(compression variants within their documented looser tolerance).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core.hardware import ClusterSpec
from repro.core.pipeline import StepTimes
from repro.distributed.collectives import SyncStrategy, get_strategy
from repro.distributed.compression import Compressor, get_compressor
from repro.distributed.overlap import (BucketPlan, DEFAULT_BUCKET_MB,
                                       bucket_span_args, build_bucket_plan,
                                       bucket_leaves, mb_to_bytes,
                                       unbucket_leaves)
from repro.launch.steps import build_grad_fn
from repro.obs import MetricsRegistry, Tracer
from repro.models import model as M
from repro.models.blocks import RunConfig
from repro.models.common import materialize
from repro.optim import adamw as opt_lib
from repro.train import loop as loop_lib

# CPU-emulation "link" bandwidth used for the Lemma 3.2 prediction when the
# caller does not supply one (bytes/s; ~memcpy-order for host collectives).
DEFAULT_LINK_BW = 4e9


@dataclass
class SyncReport:
    """Measured-vs-predicted Lemma 3.1/3.2 numbers for one training run."""

    strategy: str
    compression: str
    dp: int
    n_servers: Optional[int]
    grad_bytes: float           # S_p: fp32 gradient payload
    wire_bytes: float           # after compression, per Lemma's worker view
    link_bw: float
    measured_comm_s: float      # mean dist_update over steady-state steps
    predicted_comm_s: float     # Lemma 3.2 for this schedule + payload
    measured_compute_s: float   # mean T_C
    measured_update_s: float
    masked_measured: bool       # comm <= T_C on the wall clock
    masked_predicted: bool      # comm <= T_C per the lemma
    r_o_measured: float         # Lemma 3.1 overhead ratio from StepTimes
    # topology view (hierarchical runs): dp-axis fan-out per tier,
    # innermost first, and the per-tier wire-byte split of `wire_bytes`
    tiers: Optional[Tuple[int, ...]] = None
    wire_bytes_by_tier: Optional[Tuple[float, ...]] = None
    # bucketed-overlap view (repro.distributed.overlap). For serial runs
    # the sync is fully exposed: exposed_comm_time == measured_comm_s and
    # overlap_fraction == 0. For overlapped runs `measured_comm_s` is the
    # *serial-equivalent* comm measured on the bucketed calibration steps,
    # `exposed_comm_time` the residual the fused (overlapped) steps still
    # pay on the wall clock, and `overlap_fraction` the hidden share.
    sync_overlap: bool = False
    bucket_mb: float = 0.0            # bucket size target [MiB] (0 = unbucketed)
    n_buckets: int = 1
    bucket_sizes_bytes: Optional[Tuple[float, ...]] = None
    per_bucket_comm_s: Optional[Tuple[float, ...]] = None  # serial calibration
    exposed_comm_time: float = 0.0    # comm left outside compute [s]
    overlap_fraction: float = 0.0     # hidden comm / serial comm, in [0, 1]
    overlapped_step_s: float = 0.0    # mean fused-step wall clock [s]

    @property
    def effective_link_bw(self) -> float:
        """Measured bytes/s the sync phase actually moved per worker —
        the autotuner's feedback path: ``repro.core.autotune`` fits the
        calibrated tier bandwidths from this instead of the datasheet
        ``link_bw`` (0.0 when nothing crossed the wire)."""
        if self.measured_comm_s <= 0:
            return 0.0
        return self.wire_bytes / self.measured_comm_s

    def as_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in self.__dataclass_fields__}
        d["effective_link_bw"] = self.effective_link_bw
        return d


def _stack(tree):
    return jax.tree_util.tree_map(lambda x: x[None], tree)


def _unstack(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)


class DataParallelTrainer:
    """Run ``repro.train.loop.train`` under an explicit sync strategy.

    Parameters/optimizer state are replicated; the batch is sharded over the
    ``data`` axis (all visible devices unless ``devices`` is given). The
    strategy and compressor may be names (resolved via the registries) or
    instances — ``Plan.resolve_sync()`` hands over an instance sized by
    Lemma 3.2.
    """

    # serial-bucketed calibration steps at the head of an overlapped run:
    # step 0 absorbs the per-bucket compiles, step 1 supplies the clean
    # serial decomposition (compute / per-bucket comm / update) the fused
    # steps are measured against
    N_CALIB_STEPS = 2

    def __init__(self, cfg: ModelConfig, run: RunConfig,
                 opt: opt_lib.OptConfig, *,
                 strategy: Union[str, SyncStrategy] = "all_reduce",
                 compression: Union[str, Compressor] = "none",
                 devices: Optional[List] = None,
                 link_bw: float = DEFAULT_LINK_BW,
                 topology: Optional[ClusterSpec] = None,
                 sync_overlap: bool = False,
                 bucket_mb: float = DEFAULT_BUCKET_MB,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg, self.run, self.opt = cfg, run, opt
        # the phase wall clocks that feed StepTimes/SyncReport come FROM the
        # tracer's spans, so the trainer always times against an *enabled*
        # tracer — a disabled one would zero the measurements, so it is
        # substituted by a private live clock (events then go nowhere)
        self.tracer = (tracer if tracer is not None and tracer.enabled
                       else Tracer(enabled=True))
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if bucket_mb <= 0:
            raise ValueError(f"bucket_mb must be > 0, got {bucket_mb}")
        self.sync_overlap = bool(sync_overlap)
        self.bucket_mb = float(bucket_mb)
        self.strategy = (get_strategy(strategy)
                         if isinstance(strategy, str) else strategy)
        self.compressor = (get_compressor(compression)
                           if isinstance(compression, str) else compression)
        devs = list(devices if devices is not None else jax.devices())
        self.dp = len(devs)
        self.topology = topology
        self._tier_bws: Optional[Tuple[float, ...]] = None
        if self.strategy.hierarchical:
            sizes = self._resolve_tiers(topology)
            self.strategy = dataclasses.replace(self.strategy, tiers=sizes)
            if topology is not None and topology.tier_sizes == sizes:
                self._tier_bws = topology.tier_bws
            inner = sizes[0]
            if len(sizes) > 1 and self.dp // inner > 1:
                # nested axes: nodes (slow tier) x data (in-node, fast tier)
                self.mesh = Mesh(
                    np.array(devs).reshape(self.dp // inner, inner),
                    ("nodes", "data"))
                self._axes: Union[str, Tuple[str, ...]] = ("nodes", "data")
            else:
                self.mesh = Mesh(np.array(devs), ("data",))
                self._axes = "data"
        else:
            self.mesh = Mesh(np.array(devs), ("data",))
            self._axes = "data"
        self._data_spec = (P(self._axes) if isinstance(self._axes, str)
                           else P(tuple(self._axes)))
        self.link_bw = link_bw
        self._times: List[StepTimes] = []
        self._grad_bytes: float = 0.0
        self._bucket_plan: Optional[BucketPlan] = None
        self._bucket_sync_fn = None
        self._fused_fn = None
        # serial decomposition from the calibration steps (means of the
        # clean calibration step) + fused-step observations
        self._calib: Dict[str, Any] = {}
        self._fused_steps: List[Dict[str, float]] = []
        self._build_phases()

    def _resolve_tiers(self, topology: Optional[ClusterSpec]) -> Tuple[int, ...]:
        """dp-axis fan-out per tier for the hierarchical strategy: the
        strategy's own sizing when it matches this trainer's device count,
        else the topology's, else an adapted/degenerate split."""
        cands = []
        if self.strategy.tiers:
            cands.append(tuple(self.strategy.tiers))
        if topology is not None:
            cands.append(tuple(topology.tier_sizes))
        for sizes in cands:
            if math.prod(sizes) == self.dp:
                return sizes
        for sizes in cands:  # keep the in-node fan-out if it divides dp
            if sizes[0] > 1 and self.dp % sizes[0] == 0:
                return (sizes[0], self.dp // sizes[0])
        return (self.dp,)

    # ------------------------------------------------------------------
    @classmethod
    def from_plan(cls, plan, cfg: ModelConfig, run: RunConfig,
                  opt: opt_lib.OptConfig, *,
                  compression: Union[str, Compressor] = "none",
                  devices: Optional[List] = None,
                  link_bw: float = DEFAULT_LINK_BW,
                  topology: Optional[ClusterSpec] = None,
                  sync_overlap: Optional[bool] = None,
                  bucket_mb: Optional[float] = None,
                  tracer: Optional[Tracer] = None,
                  metrics: Optional[MetricsRegistry] = None
                  ) -> "DataParallelTrainer":
        """Trainer whose sync strategy comes from a planner ``Plan`` —
        ``resolve_sync()`` supplies the Lemma-3.2-sized strategy instance
        (the topology defaults to the plan's own, the overlap knobs to the
        plan's ``sync_overlap``/``bucket_mb``)."""
        if topology is None:
            topology = plan.cluster
        if sync_overlap is None:
            sync_overlap = bool(getattr(plan, "sync_overlap", False))
        if bucket_mb is None:
            bucket_mb = float(getattr(plan, "bucket_mb", 0.0)
                              or DEFAULT_BUCKET_MB)
        return cls(cfg, run, opt, strategy=plan.resolve_sync(),
                   compression=compression, devices=devices, link_bw=link_bw,
                   topology=topology, sync_overlap=sync_overlap,
                   bucket_mb=bucket_mb, tracer=tracer, metrics=metrics)

    # ------------------------------------------------------------------
    def _build_phases(self):
        grads_of = build_grad_fn(self.cfg, self.run)
        strat, comp, dp = self.strategy, self.compressor, self.dp
        axes, dspec = self._axes, self._data_spec

        def grad_phase(params, batch):
            # per-device local grads; stacked on a fresh leading data axis
            loss, _, grads = grads_of(params, batch)
            return _stack((loss, grads))

        self._grad_fn = jax.jit(shard_map(
            grad_phase, mesh=self.mesh,
            in_specs=(P(), dspec), out_specs=dspec))

        def sync_phase(gstack, efstack):
            grads = _unstack(gstack)
            ef = _unstack(efstack) if efstack is not None else None
            grads, ef = comp.apply(grads, ef)
            grads = strat.sync(grads, axes, dp)
            ef_out = _stack(ef) if ef is not None else None
            return grads, ef_out

        # ef may be None (stateless compressor): an empty pytree, for which
        # the data-axes prefix spec is vacuous
        self._sync_fn = jax.jit(shard_map(
            sync_phase, mesh=self.mesh,
            in_specs=(dspec, dspec),
            out_specs=(P(), dspec)))

        self._update_fn = jax.jit(
            lambda p, s, g: opt_lib.apply_updates(self.opt, p, g, s),
            donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # Bucketed overlap path (repro.distributed.overlap)
    # ------------------------------------------------------------------
    def _ensure_bucket_plan(self, params) -> BucketPlan:
        if self._bucket_plan is None:
            self._bucket_plan = build_bucket_plan(
                params, mb_to_bytes(self.bucket_mb))
        return self._bucket_plan

    def _build_overlap_fns(self):
        """Per-bucket sync executables (the serial calibration path, one
        blocking collective per bucket) and the fused overlapped step (one
        XLA program per step: every bucket's collective chain is dataflow-
        independent, so the scheduler overlaps bucket k+1's comm with
        bucket k's consumers — wait-free backpropagation as XLA sees it)."""
        if self._bucket_sync_fn is not None:
            return
        if self._bucket_plan is None:
            raise RuntimeError("overlap path needs a BucketPlan; call init() "
                               "(or train()) before step_fn()")
        plan = self._bucket_plan
        grads_of = build_grad_fn(self.cfg, self.run)
        strat, comp, dp = self.strategy, self.compressor, self.dp
        axes, dspec = self._axes, self._data_spec

        # one jitted sync shared by every bucket — jit's signature cache
        # specializes it per bucket's leaf shapes
        def bucket_sync(g_leaves, ef_leaves):
            g = _unstack(g_leaves)
            ef = _unstack(ef_leaves) if ef_leaves is not None else None
            g, ef = comp.apply(g, ef)
            g = strat.sync(g, axes, dp)
            ef_out = _stack(ef) if ef is not None else None
            return g, ef_out

        self._bucket_sync_fn = jax.jit(shard_map(
            bucket_sync, mesh=self.mesh,
            in_specs=(dspec, dspec), out_specs=(P(), dspec)))

        def sync_all_buckets(p, b, efs):
            """shard_map body of the fused step: local grads, then one
            compress+sync chain per bucket in grad-availability order."""
            loss, _, grads = grads_of(p, b)
            g_leaves, treedef = jax.tree_util.tree_flatten(grads)
            e_leaves = (jax.tree_util.tree_leaves(_unstack(efs))
                        if efs is not None else None)
            out_g: List[Any] = []
            out_e: List[Any] = []
            for idx in plan.buckets:
                gb = [g_leaves[i] for i in idx]
                eb = [e_leaves[i] for i in idx] if e_leaves is not None else None
                gb, eb = comp.apply(gb, eb)
                gb = strat.sync(gb, axes, dp)
                out_g.append(gb)
                if eb is not None:
                    out_e.append(eb)
            synced = jax.tree_util.tree_unflatten(
                treedef, unbucket_leaves(out_g, plan))
            ef_out = None
            if e_leaves is not None:
                ef_out = _stack(jax.tree_util.tree_unflatten(
                    treedef, unbucket_leaves(out_e, plan)))
            return _stack(loss), synced, ef_out

        def fused_step(params, opt_state, batch, efstack):
            losses, grads, efs = shard_map(
                sync_all_buckets, mesh=self.mesh,
                in_specs=(P(), dspec, dspec),
                out_specs=(dspec, P(), dspec))(params, batch, efstack)
            new_p, new_s, gnorm = opt_lib.apply_updates(
                self.opt, params, grads, opt_state)
            return new_p, new_s, losses, efs, gnorm

        self._fused_fn = jax.jit(fused_step, donate_argnums=(0, 1))

    def _calib_step(self, params, opt_state, batch, ef):
        """Serial-bucketed step: identical numerics to the fused path, but
        each bucket's collective blocks, yielding the per-bucket serial
        comm decomposition the overlap measurement is set against.  Every
        phase is a tracer span; the span wall clocks ARE the measurements
        (``per_bucket_comm_s`` is the ``bucket_sync`` span durations)."""
        plan = self._bucket_plan
        tr = self.tracer
        with tr.span("compute") as sp_c:
            losses, gstack = self._grad_fn(params, batch)
            jax.block_until_ready(jax.tree_util.tree_leaves(gstack)[0])
        per_bucket: List[float] = []
        with tr.span("dist_update", n_buckets=plan.n_buckets) as sp_s:
            g_leaves, treedef = jax.tree_util.tree_flatten(gstack)
            e_leaves = (jax.tree_util.tree_leaves(ef)
                        if ef is not None else None)
            g_buckets = bucket_leaves(g_leaves, plan)
            e_buckets = (bucket_leaves(e_leaves, plan)
                         if e_leaves is not None else [None] * plan.n_buckets)
            out_g: List[Any] = []
            out_e: List[Any] = []
            for k, (gb, eb) in enumerate(zip(g_buckets, e_buckets)):
                with tr.span("bucket_sync",
                             **bucket_span_args(plan, k)) as sp_b:
                    g_syn, ef_out = self._bucket_sync_fn(gb, eb)
                    jax.block_until_ready(g_syn)
                per_bucket.append(sp_b.elapsed_s)
                out_g.append(g_syn)
                if ef_out is not None:
                    out_e.append(ef_out)
        with tr.span("param_update") as sp_u:
            grads = jax.tree_util.tree_unflatten(
                treedef, unbucket_leaves(out_g, plan))
            ef_new = (jax.tree_util.tree_unflatten(
                treedef, unbucket_leaves(out_e, plan)) if out_e else None)
            params, opt_state, gnorm = self._update_fn(
                params, opt_state, grads)
            jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        # the last calibration step is the clean one (step 0 pays compiles)
        self._calib = {"compute": sp_c.elapsed_s, "comm": sp_s.elapsed_s,
                       "update": sp_u.elapsed_s,
                       "per_bucket": tuple(per_bucket)}
        self._publish_phases(sp_c.elapsed_s, sp_s.elapsed_s, sp_u.elapsed_s)
        for t in per_bucket:
            self.metrics.observe("train/bucket_comm_s", t)
        return params, opt_state, losses, ef_new, gnorm, {
            "t_comm": sp_s.elapsed_s, "t_update": sp_u.elapsed_s}

    def _overlap_step(self, params, opt_state, batch, ef):
        """Fused overlapped step, timed as one span; the serial
        calibration decomposition attributes the wall clock to exposed
        comm vs (hidden-under) update/compute."""
        with self.tracer.span("fused_step") as sp:
            params, opt_state, losses, ef_new, gnorm = self._fused_fn(
                params, opt_state, batch, ef)
            jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        wall = sp.elapsed_s
        comm_s = self._calib.get("comm", 0.0)
        comp_s = self._calib.get("compute", 0.0)
        upd_s = self._calib.get("update", 0.0)
        exposed = min(max(wall - comp_s - upd_s, 0.0), comm_s)
        self._fused_steps.append(
            {"wall_s": wall, "exposed_comm_s": exposed,
             "serial_comm_s": comm_s})
        self.metrics.inc("train/steps")
        self.metrics.observe("train/step_s", wall)
        self.metrics.observe("train/fused_step_s", wall)
        self.metrics.observe("train/exposed_comm_s", exposed)
        t_update = min(upd_s, max(wall - exposed, 0.0))
        return params, opt_state, losses, ef_new, gnorm, {
            "t_comm": exposed, "t_update": t_update}

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        """Replicated params + opt state (with per-device EF slots when the
        compressor is stateful)."""
        params = materialize(M.model_specs(self.cfg), jax.random.PRNGKey(seed))
        state = opt_lib.init_state(self.opt, params)
        rep = NamedSharding(self.mesh, P())
        params = jax.device_put(params, rep)
        state = jax.device_put(state, rep)
        if self.compressor.stateful:
            zeros = jax.tree_util.tree_map(
                lambda a: jnp.zeros((self.dp,) + a.shape, jnp.float32), params)
            state["ef"] = jax.device_put(
                zeros, NamedSharding(self.mesh, self._data_spec))
        self._grad_bytes = 4.0 * sum(
            int(np.prod(a.shape))
            for a in jax.tree_util.tree_leaves(params))
        if self.sync_overlap:
            self._ensure_bucket_plan(params)
        return params, state

    def step_fn(self):
        """A loop-compatible step callable: (params, opt_state, batch) ->
        (params, opt_state, metrics). Phase wall-times are attached to
        ``metrics`` as plain floats (``t_comm`` / ``t_update``) after device
        sync, so the loop can split them out of compute.

        With ``sync_overlap`` the first :data:`N_CALIB_STEPS` steps run the
        serial-bucketed calibration path (numerically identical, blocking
        per bucket) and every later step runs the fused overlapped program;
        ``t_comm`` then reports the *exposed* comm only."""

        if self.sync_overlap:
            self._build_overlap_fns()
            counter = {"k": 0}

            def step(params, opt_state, batch):
                ef = opt_state.pop("ef", None)
                k = counter["k"]
                counter["k"] = k + 1
                fn = (self._calib_step if k < self.N_CALIB_STEPS
                      else self._overlap_step)
                params, opt_state, losses, ef, gnorm, phase = fn(
                    params, opt_state, batch, ef)
                if ef is not None:
                    opt_state["ef"] = ef
                metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm,
                           **phase}
                return params, opt_state, metrics

            return step

        def step(params, opt_state, batch):
            ef = opt_state.pop("ef", None)
            tr = self.tracer
            with tr.span("compute") as sp_c:
                losses, gstack = self._grad_fn(params, batch)
                jax.block_until_ready(jax.tree_util.tree_leaves(gstack)[0])
            with tr.span("dist_update") as sp_s:
                grads, ef = self._sync_fn(gstack, ef)
                jax.block_until_ready(jax.tree_util.tree_leaves(grads)[0])
            with tr.span("param_update") as sp_u:
                params, opt_state, gnorm = self._update_fn(
                    params, opt_state, grads)
                jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
            if ef is not None:
                opt_state["ef"] = ef
            self._publish_phases(sp_c.elapsed_s, sp_s.elapsed_s,
                                 sp_u.elapsed_s)
            metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm,
                       "t_comm": sp_s.elapsed_s, "t_update": sp_u.elapsed_s}
            return params, opt_state, metrics

        return step

    def _publish_phases(self, compute_s: float, comm_s: float,
                        update_s: float) -> None:
        """Per-step phase histograms in the shared registry (the
        metrics/v1 ``train/*`` family)."""
        m = self.metrics
        m.inc("train/steps")
        m.observe("train/compute_s", compute_s)
        m.observe("train/dist_update_s", comm_s)
        m.observe("train/param_update_s", update_s)
        m.observe("train/step_s", compute_s + comm_s + update_s)

    # ------------------------------------------------------------------
    def train(self, *, batch: int, seq: int, steps: int, seed: int = 0,
              log_every: int = 10, params=None, opt_state=None,
              ckpt_dir: Optional[str] = None,
              ckpt_every: int = 0) -> loop_lib.TrainResult:
        if batch % self.dp:
            raise ValueError(f"batch {batch} not divisible by dp={self.dp} "
                             "(equal shards are required for exact means)")
        # fresh overlap measurements per run: a second train() (e.g. with
        # carried-over params) must not mix fused-step observations or the
        # serial calibration of the previous run into its report
        self._calib = {}
        self._fused_steps = []
        if params is None or opt_state is None:
            params, opt_state = self.init(seed)
        elif self._grad_bytes == 0:
            self._grad_bytes = 4.0 * sum(
                int(np.prod(a.shape))
                for a in jax.tree_util.tree_leaves(params))
        if self.sync_overlap:
            self._ensure_bucket_plan(params)
        batch_sharding = {
            k: NamedSharding(self.mesh, self._data_spec)
            for k in ("tokens", "labels", "image_embeds")}
        res = loop_lib.train(
            self.cfg, self.run, self.opt, batch=batch, seq=seq, steps=steps,
            seed=seed, log_every=log_every, params=params,
            opt_state=opt_state, step_fn=self.step_fn(),
            batch_sharding=batch_sharding,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, tracer=self.tracer)
        self._times = res.step_times
        return res

    # ------------------------------------------------------------------
    def report(self) -> SyncReport:
        """Close the loop: measured comm vs the Lemma 3.2 prediction.

        For an overlapped run the steady window additionally skips the
        first fused step (its compile), ``measured_comm_s`` is the
        serial-equivalent comm from the bucketed calibration step, and the
        overlap fields report how much of it the fused steps actually
        hid."""
        warmup = (self.N_CALIB_STEPS + 1) if self.sync_overlap else 2
        steady = self._times[warmup:] or self._times
        comm = float(np.mean([t.dist_update for t in steady])) if steady else 0.0
        compute = float(np.mean([t.compute for t in steady])) if steady else 0.0
        upd = float(np.mean([t.param_update for t in steady])) if steady else 0.0
        s_p = self._grad_bytes
        wire_payload = self.compressor.wire_bytes(s_p)
        predicted = self.strategy.predicted_comm_time(
            wire_payload, self.dp, self.link_bw, tier_bws=self._tier_bws)
        r_o = (float(np.mean([t.r_o() for t in steady])) if steady else 0.0)
        bplan = self._bucket_plan
        exposed, frac, fused_wall = comm, 0.0, 0.0
        if self.sync_overlap:
            comm = float(self._calib.get("comm", comm))
            fused = self._fused_steps[1:] or self._fused_steps
            if fused:
                # best-of, like autotune._timeit: host noise inflates
                # individual fused steps, it never deflates them
                exposed = float(min(f["exposed_comm_s"] for f in fused))
                fused_wall = float(min(f["wall_s"] for f in fused))
            else:  # fused path never ran (too few steps): fully exposed
                exposed = comm
            frac = (min(max(1.0 - exposed / comm, 0.0), 1.0)
                    if comm > 0 else 0.0)
        # registry view of the same numbers (the metrics/v1 train family)
        m = self.metrics
        m.set_gauge("train/measured_comm_s", comm)
        m.set_gauge("train/overlap_fraction", frac)
        m.set_gauge("train/exposed_comm_time_s", exposed)
        m.set_gauge("train/n_buckets", bplan.n_buckets if bplan else 1)
        m.set_gauge("train/effective_link_bw",
                    self.strategy.wire_bytes(wire_payload, self.dp) / comm
                    if comm > 0 else 0.0)
        return SyncReport(
            strategy=self.strategy.name, compression=self.compressor.name,
            dp=self.dp, n_servers=self.strategy.n_servers,
            grad_bytes=s_p,
            wire_bytes=self.strategy.wire_bytes(wire_payload, self.dp),
            link_bw=self.link_bw,
            measured_comm_s=comm, predicted_comm_s=predicted,
            measured_compute_s=compute, measured_update_s=upd,
            masked_measured=comm <= compute,
            masked_predicted=predicted <= compute,
            r_o_measured=r_o,
            tiers=self.strategy.tiers,
            wire_bytes_by_tier=(
                self.strategy.wire_bytes_by_tier(wire_payload, self.dp)
                if self.strategy.hierarchical else None),
            sync_overlap=self.sync_overlap,
            bucket_mb=self.bucket_mb if self.sync_overlap else 0.0,
            n_buckets=bplan.n_buckets if bplan else 1,
            bucket_sizes_bytes=bplan.sizes_bytes if bplan else None,
            per_bucket_comm_s=(tuple(self._calib["per_bucket"])
                               if self._calib.get("per_bucket") else None),
            exposed_comm_time=exposed,
            overlap_fraction=frac,
            overlapped_step_s=fused_wall,
        )
