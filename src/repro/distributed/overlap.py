"""Bucketed comm/compute overlap — wait-free backpropagation for the zoo.

The paper's Lemma 3.1/3.2 price a step as compute **plus** communication,
but the standard system remedy (Shi et al.'s wait-free backpropagation;
FireCaffe's bucketed reduction trees) hides gradient sync under the
backward pass: gradients for the *output-side* layers are ready first, so
their collectives can be in flight while the input-side gradients are
still being computed.  This module is the schedule half of that story:

- :class:`BucketPlan` — a size-targeted, reverse-topological partition of
  the gradient pytree's leaves into sync buckets.  "Reverse-topological"
  here means reverse flatten order: the model pytree flattens input-side
  first, so walking it backwards visits parameters roughly in backward-pass
  completion order (the same approximation PyTorch DDP makes with reverse
  registration order).  The plan is pure data (JSON round-trip, no jax at
  import time) so a planner ``Plan`` can carry it.
- :func:`build_bucket_plan` — greedy grouping of leaves into buckets of
  ``bucket_bytes`` target payload each.
- :func:`bucket_leaves` / :func:`unbucket_leaves` — split a leaf list into
  the plan's buckets and reassemble it, the partition property the tests
  hold (every leaf exactly once, order restored).

The *execution* half lives in ``repro.distributed.trainer``: with
``sync_overlap=True`` the trainer emits one XLA program per step in which
each bucket's collective chain is dataflow-independent, so the scheduler
overlaps bucket k+1's collective with bucket k's consumers (and, on
hardware with async collectives, with the remaining backward itself).  The
*pricing* half lives in ``repro.core.ps.overlap_step_time`` —
``T_step = T_fwd + max(T_bwd, T_bwd/n + T_comm) + T_update``, i.e. comm
can hide under all but the first bucket's slice of the backward.

Units: all payload sizes in **bytes** (fp32 gradient bytes, matching
``SyncReport.grad_bytes``); ``bucket_mb`` knobs elsewhere are MiB for CLI
ergonomics and are converted once, here, via :func:`mb_to_bytes`.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

# Default sync-bucket payload target (MiB): small enough that a reduced-run
# gradient still splits into several buckets, large enough that per-bucket
# collective launch overhead stays amortized on real payloads.  One constant
# shared with the cost model (core prices the same bucketing it cannot
# import from here).
from repro.core.ps import DEFAULT_BUCKET_MB


def mb_to_bytes(mb: float) -> float:
    return float(mb) * 2.0 ** 20


@dataclass(frozen=True)
class BucketPlan:
    """A partition of gradient-pytree leaves into dependency-ordered sync
    buckets.

    ``buckets[0]`` holds the *last* leaves of the flatten order (the
    output-side parameters whose gradients the backward pass finishes
    first), so executing buckets in index order launches collectives in
    grad-availability order.  ``leaf_bytes`` records each leaf's fp32
    payload so the plan is self-describing after serialization.
    """

    bucket_bytes: float                       # size target per bucket [bytes]
    buckets: Tuple[Tuple[int, ...], ...]      # leaf indices, availability order
    leaf_bytes: Tuple[float, ...]             # fp32 payload per leaf [bytes]

    def __post_init__(self):
        object.__setattr__(self, "buckets",
                           tuple(tuple(int(i) for i in b)
                                 for b in self.buckets))
        object.__setattr__(self, "leaf_bytes",
                           tuple(float(b) for b in self.leaf_bytes))
        seen = [i for b in self.buckets for i in b]
        if sorted(seen) != list(range(len(self.leaf_bytes))):
            raise ValueError(
                "BucketPlan is not a partition: buckets cover leaf indices "
                f"{sorted(seen)} for {len(self.leaf_bytes)} leaves")
        if self.bucket_bytes <= 0:
            raise ValueError("bucket_bytes must be > 0")

    # -- geometry ----------------------------------------------------------
    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return len(self.leaf_bytes)

    @property
    def total_bytes(self) -> float:
        return sum(self.leaf_bytes)

    @property
    def sizes_bytes(self) -> Tuple[float, ...]:
        """Per-bucket payload, aligned with ``buckets``."""
        return tuple(sum(self.leaf_bytes[i] for i in b) for b in self.buckets)

    # -- serialization (rides inside Plan / SyncReport JSON) ---------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "bucket_bytes": self.bucket_bytes,
            "buckets": [list(b) for b in self.buckets],
            "leaf_bytes": list(self.leaf_bytes),
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BucketPlan":
        return cls(bucket_bytes=float(d["bucket_bytes"]),
                   buckets=tuple(tuple(b) for b in d["buckets"]),
                   leaf_bytes=tuple(d["leaf_bytes"]))

    @classmethod
    def from_json(cls, s: str) -> "BucketPlan":
        return cls.from_dict(json.loads(s))


def bucket_span_args(plan: BucketPlan, k: int) -> Dict[str, Any]:
    """Span args (``repro.obs``) identifying bucket ``k`` in a trace:
    index, wire payload, and leaf count.  Every executor of a BucketPlan
    labels its ``bucket_sync`` spans through this helper, so traces from
    the trainer (or any future executor) are comparable bucket-for-bucket
    and reconcile against ``SyncReport.bucket_sizes_bytes``."""
    return {"bucket": int(k), "bytes": int(plan.sizes_bytes[k]),
            "n_leaves": len(plan.buckets[k])}


def leaf_sizes_bytes(tree) -> Tuple[float, ...]:
    """fp32 payload per leaf of a pytree, in flatten order (the sync wire
    view: every strategy moves gradients as fp32, see collectives)."""
    import jax

    sizes = []
    for leaf in jax.tree_util.tree_leaves(tree):
        n = 1
        for s in getattr(leaf, "shape", ()):
            n *= int(s)
        sizes.append(4.0 * n)
    return tuple(sizes)


def build_bucket_plan(tree, bucket_bytes: float = mb_to_bytes(DEFAULT_BUCKET_MB)
                      ) -> BucketPlan:
    """Greedy size-capped grouping of ``tree``'s leaves, walking the
    flatten order *backwards* so bucket 0 is the backward pass's first
    finished gradients.

    Cap semantics (PyTorch DDP's ``bucket_cap_mb``): a bucket closes
    *before* the leaf that would push it past ``bucket_bytes``, so no
    bucket exceeds the cap unless a single leaf does on its own.  This
    keeps the cost model's size-level count (``ps.bucket_count``, a plain
    ceil) a conservative lower bound on the real bucket count — the model
    never promises a finer overlap granularity than the executable plan
    delivers."""
    sizes = leaf_sizes_bytes(tree)
    if bucket_bytes <= 0:
        raise ValueError("bucket_bytes must be > 0")
    buckets: List[Tuple[int, ...]] = []
    cur: List[int] = []
    cur_bytes = 0.0
    for i in range(len(sizes) - 1, -1, -1):  # reverse-topological walk
        if cur and cur_bytes + sizes[i] > bucket_bytes:
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0.0
        cur.append(i)
        cur_bytes += sizes[i]
    if cur:
        buckets.append(tuple(cur))
    return BucketPlan(bucket_bytes=float(bucket_bytes),
                      buckets=tuple(buckets), leaf_bytes=sizes)


def bucket_leaves(leaves: Sequence[Any], plan: BucketPlan) -> List[List[Any]]:
    """Split a flatten-order leaf list into the plan's buckets (each bucket
    is itself a pytree — a list — so compressors/strategies apply as-is)."""
    if len(leaves) != plan.n_leaves:
        raise ValueError(f"got {len(leaves)} leaves for a {plan.n_leaves}-leaf "
                         "BucketPlan")
    return [[leaves[i] for i in b] for b in plan.buckets]


def unbucket_leaves(bucketed: Sequence[Sequence[Any]], plan: BucketPlan
                    ) -> List[Any]:
    """Inverse of :func:`bucket_leaves`: reassemble flatten-order leaves."""
    out: List[Any] = [None] * plan.n_leaves
    if len(bucketed) != plan.n_buckets:
        raise ValueError(f"got {len(bucketed)} buckets for a "
                         f"{plan.n_buckets}-bucket BucketPlan")
    for idx, vals in zip(plan.buckets, bucketed):
        if len(idx) != len(vals):
            raise ValueError("bucket length mismatch")
        for i, v in zip(idx, vals):
            out[i] = v
    return out
