"""AsyncPSTrainer — bounded-staleness parameter-server data parallelism.

Relaxes the synchronous-worker assumption under Lemma 3.2 (the paper's §2
taxonomy names stragglers and I/O stalls as exactly what breaks it at
scale) along the two axes the Hitchhiker's-Guide survey maps:

**Bounded staleness** (``staleness = s``): the replicated "server" copy of
the parameters advances every step, but each worker refreshes its private
copy only on its scheduled slot — worker ``w`` pulls at steps where
``(t + w) % (s + 1) == 0`` — so a worker's gradients are computed against
parameters at most ``s`` steps stale, the pull traffic in Eq. 7 amortizes
over ``s + 1`` steps, and refreshes stagger across workers instead of
thundering in the same step.  ``s = 0`` degenerates to every worker
pulling every step: the refresh is a byte-exact ``jnp.where`` copy of the
server params and the gradient graph is the same per-shard program the
synchronous trainer runs, so the run is **bit-identical** to
``DataParallelTrainer`` with the ``parameter_server`` strategy (pinned by
``tests/test_checkpoint.py``).

**Backup workers** (``backup_workers = k``): each step drops the slowest
``k`` of ``dp`` gradients (simulated per-step delays, seeded exponential —
this container has no real stragglers) and averages the survivors,
pre-scaled by ``dp / (dp - k)`` so the inherited ``psum/dp`` sync yields
the survivor mean.  ``k = 0`` multiplies by exactly 1.0 (IEEE-exact), so
the synchronous path is the same code path, not a special case.

The server update itself is the inherited 3-phase machinery — same
``parameter_server`` collective, same optimizer — which is what makes the
bit-identity claim testable rather than aspirational.  :meth:`async_report`
sets the measured refresh/drop/age counters against the cost model's
``T_step(s, k)`` (``repro.core.ps.async_step_time``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core import ps as ps_lib
from repro.distributed.collectives import SyncStrategy
from repro.distributed.trainer import (DataParallelTrainer, DEFAULT_LINK_BW,
                                       _stack, _unstack)
from repro.launch.steps import build_grad_fn
from repro.models.blocks import RunConfig
from repro.optim import adamw as opt_lib
from repro.train import loop as loop_lib


@dataclass
class AsyncPSReport:
    """Measured async-PS behaviour vs the relaxed-lemma step model."""

    staleness: int
    backup_workers: int
    dp: int
    steps: int
    refreshes: int              # total worker pulls actually performed
    mean_age: float             # mean params age (steps) at grad time
    max_age: int                # never exceeds `staleness` by construction
    drops: int                  # total gradients dropped (= steps * k)
    drop_counts: Tuple[int, ...]  # per-worker drop totals
    pull_amortization: float    # 1 / (s + 1): Eq. 7 pull traffic factor
    t_step_model: Dict[str, float]  # repro.core.ps.async_step_time terms

    def as_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


class AsyncPSTrainer(DataParallelTrainer):
    """Bounded-staleness + backup-worker variant of the PS trainer.

    Parameters
    ----------
    staleness:
        Max age ``s`` (in steps) of the params a worker may compute
        gradients against.  0 = fully synchronous.
    backup_workers:
        Slowest ``k`` gradients dropped per step, ``0 <= k < dp``.
    mean_delay_s:
        Mean of the seeded exponential per-worker delay used to *rank*
        workers each step (and to price the straggler model); the
        simulation never sleeps.
    """

    def __init__(self, cfg: ModelConfig, run: RunConfig,
                 opt: opt_lib.OptConfig, *,
                 staleness: int = 0,
                 backup_workers: int = 0,
                 mean_delay_s: float = 0.01,
                 strategy: Union[str, SyncStrategy] = "parameter_server",
                 devices: Optional[List] = None,
                 link_bw: float = DEFAULT_LINK_BW,
                 delay_seed: int = 0,
                 **kwargs):
        if kwargs.pop("sync_overlap", False):
            raise ValueError("AsyncPSTrainer: sync_overlap is a synchronous-"
                             "schedule optimization; staleness already "
                             "amortizes the pull traffic")
        super().__init__(cfg, run, opt, strategy=strategy, devices=devices,
                         link_bw=link_bw, **kwargs)
        if self.strategy.hierarchical:
            raise ValueError("AsyncPSTrainer needs a flat strategy (the "
                             "worker refresh schedule assumes one data axis)")
        if self.compressor.stateful:
            raise ValueError("AsyncPSTrainer: error-feedback compressors "
                             "assume every gradient lands; incompatible "
                             "with backup-worker drops")
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if not 0 <= backup_workers < self.dp:
            raise ValueError(f"need 0 <= backup_workers < dp={self.dp}, "
                             f"got {backup_workers}")
        self.staleness = int(staleness)
        self.backup_workers = int(backup_workers)
        self.mean_delay_s = float(mean_delay_s)
        self.delay_seed = int(delay_seed)
        self._workers = None          # stacked (dp,)+shape private copies
        self._ages = np.zeros(self.dp, np.int64)
        self._refreshes = 0
        self._age_sum = 0
        self._age_max = 0
        self._drop_counts = np.zeros(self.dp, np.int64)
        self._steps_run = 0
        self._build_async_phases()

    # ------------------------------------------------------------------
    def _build_async_phases(self):
        mesh, dspec = self.mesh, self._data_spec

        def bcast(p):
            # replicated logical tree -> (dp,)+shape worker stack (each
            # shard gets its own byte-copy of the server params)
            return _stack(p)

        self._bcast_fn = jax.jit(shard_map(
            bcast, mesh=mesh, in_specs=(P(),), out_specs=dspec))

        def refresh(mask, server, workers):
            # mask shard: (1,) bool; jnp.where copies bytes exactly, so a
            # refreshed worker holds the server params bit-for-bit
            def sel(s, w):
                m = mask.reshape((1,) + (1,) * (w.ndim - 1))
                return jnp.where(m, s[None], w)
            return jax.tree_util.tree_map(sel, server, workers)

        self._refresh_fn = jax.jit(shard_map(
            refresh, mesh=mesh,
            in_specs=(dspec, P(), dspec), out_specs=dspec))

        grads_of = build_grad_fn(self.cfg, self.run)

        def wgrad(pstack, batch):
            # per-shard program identical to the synchronous grad phase —
            # the params just arrive as this worker's (1,)+shape slice
            loss, _, grads = grads_of(_unstack(pstack), batch)
            return _stack((loss, grads))

        self._wgrad_fn = jax.jit(shard_map(
            wgrad, mesh=mesh, in_specs=(dspec, dspec), out_specs=dspec))

        def weight(gstack, w):
            # w shard: (1,) float32 — 1.0 for survivors scaled dp/(dp-k),
            # 0.0 for dropped; the *1.0 path (k=0) is IEEE-exact
            def mul(x):
                return x * w.reshape((1,) + (1,) * (x.ndim - 1))
            return jax.tree_util.tree_map(mul, gstack)

        self._weight_fn = jax.jit(shard_map(
            weight, mesh=mesh, in_specs=(dspec, dspec), out_specs=dspec))

    # ------------------------------------------------------------------
    def _refresh_mask(self, t: int) -> np.ndarray:
        """Worker w pulls at steps with (t + w) % (s + 1) == 0 — every
        worker's age stays <= s and refreshes stagger across the window."""
        return ((t + np.arange(self.dp)) % (self.staleness + 1)) == 0

    def _step_weights(self, rng: np.random.Generator) -> np.ndarray:
        """Per-worker gradient weights for this step: drop the k slowest
        (by simulated seeded delay), scale survivors so psum/dp is the
        survivor mean.  k=0 -> all exactly 1.0."""
        dp, k = self.dp, self.backup_workers
        delays = rng.exponential(self.mean_delay_s, dp)
        w = np.full(dp, dp / (dp - k) if k else 1.0, np.float32)
        if k:
            dropped = np.argsort(delays)[-k:]
            w[dropped] = 0.0
            self._drop_counts[dropped] += 1
        return w

    # ------------------------------------------------------------------
    def step_fn(self):
        """Loop-compatible step: refresh scheduled workers from the server
        copy, compute per-worker grads at their (possibly stale) params,
        drop/rescale, then the inherited sync + server update."""
        counter = {"t": 0}
        rng = np.random.default_rng(self.delay_seed)
        wspec = NamedSharding(self.mesh, self._data_spec)

        def step(params, opt_state, batch):
            t = counter["t"]
            counter["t"] = t + 1
            if self._workers is None:
                self._workers = self._bcast_fn(params)
                self._ages[:] = 0
            tr = self.tracer
            mask = self._refresh_mask(t)
            with tr.span("compute") as sp_c:
                if mask.any():
                    dev_mask = jax.device_put(mask, wspec)
                    self._workers = self._refresh_fn(dev_mask, params,
                                                     self._workers)
                    self._refreshes += int(mask.sum())
                    self._ages[mask] = 0
                losses, gstack = self._wgrad_fn(self._workers, batch)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(gstack)[0])
            self._age_sum += int(self._ages.sum())
            self._age_max = max(self._age_max, int(self._ages.max()))
            self._ages += 1
            with tr.span("dist_update") as sp_s:
                w = self._step_weights(rng)
                gstack = self._weight_fn(gstack, jax.device_put(w, wspec))
                grads, _ = self._sync_fn(gstack, None)
                jax.block_until_ready(jax.tree_util.tree_leaves(grads)[0])
            with tr.span("param_update") as sp_u:
                params, opt_state, gnorm = self._update_fn(
                    params, opt_state, grads)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(params)[0])
            self._steps_run += 1
            self._publish_phases(sp_c.elapsed_s, sp_s.elapsed_s,
                                 sp_u.elapsed_s)
            self.metrics.observe("train/refreshes", float(mask.sum()))
            metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm,
                       "t_comm": sp_s.elapsed_s, "t_update": sp_u.elapsed_s}
            return params, opt_state, metrics

        return step

    # ------------------------------------------------------------------
    def train(self, **kw) -> loop_lib.TrainResult:
        # fresh worker copies + counters per run: a resumed run rebuilds
        # the worker stack from the restored server params (the stack is
        # derived state, deliberately absent from checkpoints — all
        # workers restart fresh, ages 0)
        self._workers = None
        self._ages = np.zeros(self.dp, np.int64)
        self._refreshes = 0
        self._age_sum = 0
        self._age_max = 0
        self._drop_counts = np.zeros(self.dp, np.int64)
        self._steps_run = 0
        return super().train(**kw)

    # ------------------------------------------------------------------
    def async_report(self) -> AsyncPSReport:
        """Measured staleness/straggler counters + the T_step(s, k) model
        evaluated at this run's measured compute time."""
        steady = self._times[2:] or self._times
        t_c = (float(np.mean([t.compute for t in steady]))
               if steady else 0.0)
        n_ps = self.strategy.n_servers or self.dp
        model = ps_lib.async_step_time(
            self._grad_bytes, self.dp, n_ps, self.link_bw, t_c,
            staleness=self.staleness, backup_workers=self.backup_workers,
            mean_delay=self.mean_delay_s)
        steps = self._steps_run
        return AsyncPSReport(
            staleness=self.staleness,
            backup_workers=self.backup_workers,
            dp=self.dp,
            steps=steps,
            refreshes=self._refreshes,
            mean_age=(self._age_sum / (steps * self.dp)) if steps else 0.0,
            max_age=self._age_max,
            drops=int(self._drop_counts.sum()),
            drop_counts=tuple(int(c) for c in self._drop_counts),
            pull_amortization=1.0 / (self.staleness + 1),
            t_step_model=model,
        )
