"""Gradient-sync strategies over the mesh data axis (Lemma 3.2, executable).

Every strategy is a pure function on a gradient pytree that runs *inside*
``shard_map`` over the ``data`` axis: it receives this device's local
gradients and must return the data-axis **mean**, replicated on every
device. The three members of the zoo differ only in which collectives move
the bytes — which is exactly the degree of freedom the paper's Lemma 3.2
prices:

- ``all_reduce``      — one fused all-reduce; wire 2*S_p*(dp-1)/dp per chip.
- ``reduce_scatter_all_gather`` — explicit reduce-scatter of the flat
  gradient followed by an all-gather (the ZeRO "N_ps = dp" mapping: each
  device acts as the parameter server for its 1/dp shard). Same wire bytes
  as all-reduce, but the two phases are separable/overlappable.
- ``parameter_server`` — sharded PS push/pull emulation: the flat gradient
  is split into ``n_servers`` buckets (the count Lemma 3.2 sizes) and each
  bucket is synchronized by its own collective, emulating one server's
  push+reduce+pull round. Worker-side wire is the lemma's 2*S_p.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ps as ps_lib


# ---------------------------------------------------------------------------
# Flat-vector helpers (PS sharding and reduce-scatter need a 1-D view)
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> Tuple[jnp.ndarray, Any]:
    """Concatenate all leaves (as f32) into one 1-D vector. Returns
    (vector, treedef-with-shapes) for :func:`unflatten_tree`."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(-1) for l in leaves]) if leaves else (
        jnp.zeros((0,), jnp.float32))
    return flat, (treedef, shapes)


def unflatten_tree(flat: jnp.ndarray, meta) -> Any:
    treedef, shapes = meta
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Strategy zoo
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SyncStrategy:
    """A named gradient-sync schedule, executable inside shard_map."""

    name: str
    # (local_grads, axis_name, dp) -> mean grads, replicated over the axis
    _sync: Callable[[Any, str, int], Any]
    n_servers: Optional[int] = None  # parameter_server only

    def sync(self, grads, axis: str, dp: int):
        return self._sync(grads, axis, dp)

    def wire_bytes(self, s_p: float, dp: int) -> float:
        """Per-worker wire bytes for one sync of s_p gradient bytes."""
        if self.name == "parameter_server":
            return 2.0 * s_p  # push everything out + pull everything back
        frac = (dp - 1) / dp if dp > 1 else 0.0
        return 2.0 * s_p * frac  # ring all-reduce == RS + AG

    def predicted_comm_time(self, s_p: float, dp: int, link_bw: float) -> float:
        """Lemma 3.2's comm-time prediction for this schedule."""
        return ps_lib.predicted_comm_time(self.name, s_p, dp, link_bw,
                                          n_ps=self.n_servers or 0)


def _all_reduce(grads, axis: str, dp: int):
    return jax.tree_util.tree_map(
        lambda g: jax.lax.pmean(g.astype(jnp.float32), axis), grads)


def _reduce_scatter_all_gather(grads, axis: str, dp: int):
    """ZeRO mapping: RS the flat gradient (each device owns 1/dp of the sum),
    scale locally, AG the shards back. Bitwise the same mean as all_reduce
    up to reduction order."""
    flat, meta = flatten_tree(grads)
    pad = (-flat.size) % dp
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
    shard = shard / dp  # each "server" averages its shard (the 1/dp opt work)
    full = jax.lax.all_gather(shard, axis, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
    return unflatten_tree(full, meta)


def _parameter_server(n_servers: int):
    def sync(grads, axis: str, dp: int):
        flat, meta = flatten_tree(grads)
        n = max(min(n_servers, flat.size), 1)
        # static near-equal bucket sizes (np.array_split semantics)
        base, rem = divmod(int(flat.size), n)
        sizes = [base + 1] * rem + [base] * (n - rem)
        out, off = [], 0
        for sz in sizes:
            if sz == 0:
                continue
            bucket = flat[off:off + sz]
            off += sz
            # one collective per server: the push+reduce+pull round-trip of
            # Lemma 3.2's Eq. 7, with the 1/N_ps bucket as the payload
            out.append(jax.lax.psum(bucket, axis) / dp)
        return unflatten_tree(jnp.concatenate(out), meta)

    return sync


def get_strategy(name: str, *, n_servers: Optional[int] = None) -> SyncStrategy:
    """Resolve a schedule name (as stored in ``Plan.sync_schedule``) to an
    executable strategy. ``n_servers`` defaults to dp at sync time for the
    parameter-server emulation; size it with Lemma 3.2
    (:func:`repro.core.ps.n_parameter_servers`) for a faithful run."""
    if name == "all_reduce":
        return SyncStrategy("all_reduce", _all_reduce)
    if name == "reduce_scatter_all_gather":
        return SyncStrategy("reduce_scatter_all_gather",
                            _reduce_scatter_all_gather)
    if name == "parameter_server":
        n = n_servers or 0
        return SyncStrategy(
            "parameter_server",
            _parameter_server(n) if n else _ps_dynamic, n_servers=n or None)
    raise KeyError(f"unknown sync strategy {name!r}; known: {STRATEGIES}")


def _ps_dynamic(grads, axis: str, dp: int):
    # n_servers unspecified: default to dp (ZeRO's N_ps = dp choice)
    return _parameter_server(dp)(grads, axis, dp)


STRATEGIES: Tuple[str, ...] = (
    "all_reduce", "reduce_scatter_all_gather", "parameter_server",
)
