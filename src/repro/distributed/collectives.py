"""Gradient-sync strategies over the mesh data axis (Lemma 3.2, executable).

Every strategy is a pure function on a gradient pytree that runs *inside*
``shard_map`` over the data axis (or, for the hierarchical strategy, over
nested ``(nodes, data)`` axes): it receives this device's local gradients
and must return the data-axis **mean**, replicated on every device. The
members of the zoo differ only in which collectives move the bytes — which
is exactly the degree of freedom the paper's Lemma 3.2 prices:

- ``all_reduce``      — one fused all-reduce; wire 2*S_p*(dp-1)/dp per chip.
- ``reduce_scatter_all_gather`` — explicit reduce-scatter of the flat
  gradient followed by an all-gather (the ZeRO "N_ps = dp" mapping: each
  device acts as the parameter server for its 1/dp shard). Same wire bytes
  as all-reduce, but the two phases are separable/overlappable.
- ``parameter_server`` — sharded PS push/pull emulation: the flat gradient
  is split into ``n_servers`` buckets (the count Lemma 3.2 sizes) and each
  bucket is synchronized by its own collective, emulating one server's
  push+reduce+pull round. Worker-side wire is the lemma's 2*S_p.
- ``hier_all_reduce`` — the FireCaffe-style reduction tree over the cluster
  topology: reduce-scatter *inside* each node (fast tier), all-reduce only
  the surviving 1/node shard *across* nodes (slow tier), all-gather back
  in-node. Executed via nested shard_map axes ``(nodes, data)``; per-tier
  wire bytes come from :func:`repro.core.ps.hier_wire_bytes`.

Equation map (units: payload ``s_p`` and wire bytes in **bytes**,
bandwidths in **bytes/s**, times in **seconds**; see ``docs/paper_map.md``):

- :meth:`SyncStrategy.wire_bytes`          — Lemma 3.2's per-worker wire
  volume for this schedule: 2*S_p (parameter_server, Eq. 7's push+pull),
  2*S_p*(dp-1)/dp (ring AR / RS+AG), or the tier sum of
  :func:`repro.core.ps.hier_wire_bytes` (hierarchical)
- :meth:`SyncStrategy.wire_bytes_by_tier`  — the same volume attributed to
  each topology tier (flat schedules pay full payload on every spanning
  tier; the tree only moves the surviving shard outward)
- :meth:`SyncStrategy.predicted_comm_time` — Eq. (7)'s comm time for this
  schedule/payload, delegating to :func:`repro.core.ps.predicted_comm_time`
- :func:`get_strategy`                     — name -> executable schedule;
  ``parameter_server`` takes Eq. (8)'s ``n_servers``
  (:func:`repro.core.ps.n_parameter_servers`)

The autotuner (``repro.core.autotune``) closes the measured loop: a
``SyncReport``'s ``effective_link_bw`` (wire bytes / measured sync time)
re-prices these predictions on the bandwidth the wire actually delivered.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import ps as ps_lib
from repro.core.hardware import Tier

# a strategy's axis argument: one shard_map axis name, or (outer..., inner)
# nested axis names for the hierarchical strategies
AxisArg = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Flat-vector helpers (PS sharding and reduce-scatter need a 1-D view)
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> Tuple[jnp.ndarray, Any]:
    """Concatenate all leaves (as f32) into one 1-D vector. Returns
    (vector, treedef-with-shapes) for :func:`unflatten_tree`."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(-1) for l in leaves]) if leaves else (
        jnp.zeros((0,), jnp.float32))
    return flat, (treedef, shapes)


def unflatten_tree(flat: jnp.ndarray, meta) -> Any:
    treedef, shapes = meta
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Strategy zoo
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SyncStrategy:
    """A named gradient-sync schedule, executable inside shard_map."""

    name: str
    # (local_grads, axis-or-axes, dp) -> mean grads, replicated over the axis
    _sync: Callable[[Any, AxisArg, int], Any]
    n_servers: Optional[int] = None  # parameter_server only
    tiers: Optional[Tuple[int, ...]] = None  # hier only: sizes, innermost first

    @property
    def hierarchical(self) -> bool:
        return self.name == "hier_all_reduce"

    def sync(self, grads, axis: AxisArg, dp: int):
        return self._sync(grads, axis, dp)

    def _tier_sizes(self, dp: int) -> Tuple[int, ...]:
        return self.tiers if self.tiers else (dp,)

    def wire_bytes(self, s_p: float, dp: int) -> float:
        """Per-worker wire bytes for one sync of s_p gradient bytes."""
        if dp <= 1:
            return 0.0  # nothing crosses the wire without a second worker
        if self.name == "parameter_server":
            return 2.0 * s_p  # push everything out + pull everything back
        if self.hierarchical:
            return sum(ps_lib.hier_wire_bytes(s_p, self._tier_sizes(dp)))
        return ps_lib.flat_wire_bytes(s_p, dp)  # ring all-reduce == RS + AG

    def wire_bytes_by_tier(self, s_p: float, dp: int) -> Tuple[float, ...]:
        """Per-worker wire bytes attributed to each topology tier
        (innermost first).  Flat strategies push their full payload across
        every spanning tier (a ring is blind to the hierarchy); the
        hierarchical schedule only moves the surviving shard outward."""
        if dp <= 1:
            return tuple(0.0 for _ in self._tier_sizes(dp))
        sizes = self._tier_sizes(dp)
        if self.hierarchical:
            return ps_lib.hier_wire_bytes(s_p, sizes)
        total = self.wire_bytes(s_p, dp)
        return tuple(total if d > 1 else 0.0 for d in sizes)

    def predicted_comm_time(self, s_p: float, dp: int, link_bw: float,
                            *, tier_bws: Optional[Sequence[float]] = None
                            ) -> float:
        """Lemma 3.2's comm-time prediction for this schedule.  For the
        hierarchical strategy pass ``tier_bws`` (aligned with ``tiers``) to
        price each phase on its own link; a scalar ``link_bw`` prices a
        degenerate uniform hierarchy."""
        if dp <= 1:
            return 0.0
        tiers = None
        if self.hierarchical:
            sizes = self._tier_sizes(dp)
            bws = tuple(tier_bws) if tier_bws else (link_bw,) * len(sizes)
            tiers = tuple(Tier(f"t{i}", d, bw)
                          for i, (d, bw) in enumerate(zip(sizes, bws)))
        return ps_lib.predicted_comm_time(self.name, s_p, dp, link_bw,
                                          n_ps=self.n_servers or 0,
                                          tiers=tiers)


def _all_reduce(grads, axis: AxisArg, dp: int):
    return jax.tree_util.tree_map(
        lambda g: jax.lax.pmean(g.astype(jnp.float32), axis), grads)


def _reduce_scatter_all_gather(grads, axis: AxisArg, dp: int):
    """ZeRO mapping: RS the flat gradient (each device owns 1/dp of the sum),
    scale locally, AG the shards back. Bitwise the same mean as all_reduce
    up to reduction order."""
    flat, meta = flatten_tree(grads)
    pad = (-flat.size) % dp
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
    shard = shard / dp  # each "server" averages its shard (the 1/dp opt work)
    full = jax.lax.all_gather(shard, axis, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
    return unflatten_tree(full, meta)


def _hier_all_reduce(grads, axis: AxisArg, dp: int):
    """Reduction tree over nested axes ``(outer, inner)``: reduce-scatter
    in-node, all-reduce the 1/d_inner shard across nodes, all-gather back
    in-node.  On a single (string) axis it degenerates to RS+AG."""
    if isinstance(axis, str) or len(axis) == 1:
        return _reduce_scatter_all_gather(
            grads, axis if isinstance(axis, str) else axis[0], dp)
    outer, inner = axis[:-1], axis[-1]
    outer = outer[0] if len(outer) == 1 else outer
    flat, meta = flatten_tree(grads)
    d_inner = jax.lax.psum(1, inner)  # static inner-axis size
    pad = (-flat.size) % d_inner
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    # phase 1 (fast tier): in-node reduce, each chip keeps a 1/d_inner shard
    shard = jax.lax.psum_scatter(flat, inner, scatter_dimension=0, tiled=True)
    # phase 2 (slow tier): only the shard crosses nodes
    shard = jax.lax.psum(shard, outer) / dp
    # phase 3 (fast tier): in-node broadcast of the synced shards
    full = jax.lax.all_gather(shard, inner, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
    return unflatten_tree(full, meta)


def _parameter_server(n_servers: int):
    def sync(grads, axis: AxisArg, dp: int):
        flat, meta = flatten_tree(grads)
        n = max(min(n_servers, flat.size), 1)
        # static near-equal bucket sizes (np.array_split semantics)
        base, rem = divmod(int(flat.size), n)
        sizes = [base + 1] * rem + [base] * (n - rem)
        out, off = [], 0
        for sz in sizes:
            if sz == 0:
                continue
            bucket = flat[off:off + sz]
            off += sz
            # one collective per server: the push+reduce+pull round-trip of
            # Lemma 3.2's Eq. 7, with the 1/N_ps bucket as the payload
            out.append(jax.lax.psum(bucket, axis) / dp)
        return unflatten_tree(jnp.concatenate(out), meta)

    return sync


def get_strategy(name: str, *, n_servers: Optional[int] = None,
                 tiers: Optional[Sequence[int]] = None) -> SyncStrategy:
    """Resolve a schedule name (as stored in ``Plan.sync_schedule``) to an
    executable strategy.

    ``n_servers`` (parameter_server): ``None`` defers to the dynamic
    ``N_ps = dp`` default at sync time; an explicit non-positive count is an
    error — size it with Lemma 3.2 (:func:`repro.core.ps.n_parameter_servers`)
    for a faithful run.  ``tiers`` (hier_all_reduce): per-tier fan-out,
    innermost first, e.g. ``(4, 2)`` for 2 nodes x 4 chips; without it the
    strategy treats the whole axis as one node.
    """
    if name == "all_reduce":
        return SyncStrategy("all_reduce", _all_reduce)
    if name == "reduce_scatter_all_gather":
        return SyncStrategy("reduce_scatter_all_gather",
                            _reduce_scatter_all_gather)
    if name == "hier_all_reduce":
        t = tuple(int(d) for d in tiers) if tiers else None
        if t and any(d < 1 for d in t):
            raise ValueError(f"hier_all_reduce tiers must be >= 1, got {t}")
        return SyncStrategy("hier_all_reduce", _hier_all_reduce, tiers=t)
    if name == "parameter_server":
        if n_servers is None:
            return SyncStrategy("parameter_server", _ps_dynamic)
        if n_servers < 1:
            raise ValueError(
                f"parameter_server needs n_servers >= 1, got {n_servers}; "
                "pass None to defer to the dynamic N_ps = dp default")
        return SyncStrategy("parameter_server", _parameter_server(n_servers),
                            n_servers=n_servers)
    raise KeyError(f"unknown sync strategy {name!r}; known: {STRATEGIES}")


def _ps_dynamic(grads, axis: AxisArg, dp: int):
    # n_servers unspecified: default to dp (ZeRO's N_ps = dp choice)
    return _parameter_server(dp)(grads, axis, dp)


STRATEGIES: Tuple[str, ...] = (
    "all_reduce", "reduce_scatter_all_gather", "parameter_server",
    "hier_all_reduce",
)
