"""Eq. (6) — per-layer algorithm selection as an ILP.

    min  sum_k sum_l x_{k,l} * T_{k,l}
    s.t. sum_k sum_l x_{k,l} * M_{k,l} <= M_bound,   sum_l x_{k,l} = 1 (all k)

This is a multiple-choice knapsack. The paper points at GLPK; offline we
solve exactly with (a) Lagrangian-free branch-and-bound over layers with
a greedy lower bound, exact for the layer counts here (<= 128 groups), and
(b) a dynamic program over discretized memory as a cross-check.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)


@dataclass(frozen=True)
class Choice:
    name: str
    time: float
    memory: float


@dataclass
class ILPSolution:
    choices: List[int]  # chosen l per layer k
    time: float
    memory: float
    feasible: bool


def solve_ilp(layers: Sequence[Sequence[Choice]], m_bound: float) -> ILPSolution:
    """Exact branch-and-bound. ``layers[k][l]`` = Choice."""
    n = len(layers)
    # per-layer minima for bounds
    min_time_suffix = [0.0] * (n + 1)
    min_mem_suffix = [0.0] * (n + 1)
    for k in range(n - 1, -1, -1):
        min_time_suffix[k] = min_time_suffix[k + 1] + min(c.time for c in layers[k])
        min_mem_suffix[k] = min_mem_suffix[k + 1] + min(c.memory for c in layers[k])

    if min_mem_suffix[0] > m_bound:
        # infeasible even with the most memory-frugal choice everywhere
        picks = [min(range(len(ch)), key=lambda l: ch[l].memory) for ch in layers]
        t = sum(layers[k][picks[k]].time for k in range(n))
        m = sum(layers[k][picks[k]].memory for k in range(n))
        return ILPSolution(picks, t, m, feasible=False)

    best_time = float("inf")
    best_picks: Optional[List[int]] = None
    # DFS with (time_so_far + optimistic suffix) pruning; layers sorted by
    # "regret" (time spread) so impactful decisions come first.
    order = sorted(range(n),
                   key=lambda k: -(max(c.time for c in layers[k])
                                   - min(c.time for c in layers[k])))

    def dfs(idx: int, t_acc: float, m_acc: float, picks: List[int]):
        nonlocal best_time, best_picks
        if idx == n:
            if t_acc < best_time and m_acc <= m_bound:
                best_time, best_picks = t_acc, picks.copy()
            return
        k = order[idx]
        # optimistic bounds over the *remaining* (by order) layers
        rem = order[idx:]
        t_lb = t_acc + sum(min(c.time for c in layers[j]) for j in rem)
        m_lb = m_acc + sum(min(c.memory for c in layers[j]) for j in rem)
        if t_lb >= best_time or m_lb > m_bound:
            return
        for l in sorted(range(len(layers[k])), key=lambda l: layers[k][l].time):
            c = layers[k][l]
            picks.append(l)
            dfs(idx + 1, t_acc + c.time, m_acc + c.memory, picks)
            picks.pop()

    dfs(0, 0.0, 0.0, [])
    assert best_picks is not None
    # unpermute
    final = [0] * n
    for pos, k in enumerate(order):
        final[k] = best_picks[pos]
    t = sum(layers[k][final[k]].time for k in range(n))
    m = sum(layers[k][final[k]].memory for k in range(n))
    return ILPSolution(final, t, m, feasible=True)


def solve_ilp_dp(layers: Sequence[Sequence[Choice]], m_bound: float,
                 buckets: int = 4096) -> ILPSolution:
    """Memory-discretized DP cross-check (pseudo-polynomial)."""
    n = len(layers)
    max_mem = max(m_bound, 1.0)
    unit = max_mem / buckets

    def q(m: float) -> int:  # conservative rounding UP keeps feasibility
        return min(buckets, int(-(-m / unit)))

    INF = float("inf")
    dp = [INF] * (buckets + 1)
    back: List[List[Tuple[int, int]]] = []
    dp[0] = 0.0
    for k in range(n):
        ndp = [INF] * (buckets + 1)
        nback = [(-1, -1)] * (buckets + 1)
        for m_idx in range(buckets + 1):
            if dp[m_idx] == INF:
                continue
            for l, c in enumerate(layers[k]):
                nm = m_idx + q(c.memory)
                if nm > buckets:
                    continue
                nt = dp[m_idx] + c.time
                if nt < ndp[nm]:
                    ndp[nm] = nt
                    nback[nm] = (m_idx, l)
        dp = ndp
        back.append(nback)
    best_idx = min(range(buckets + 1), key=lambda i: dp[i])
    if dp[best_idx] == INF:
        picks = [min(range(len(ch)), key=lambda l: ch[l].memory) for ch in layers]
        t = sum(layers[k][picks[k]].time for k in range(n))
        m = sum(layers[k][picks[k]].memory for k in range(n))
        return ILPSolution(picks, t, m, feasible=False)
    picks = [0] * n
    idx = best_idx
    for k in range(n - 1, -1, -1):
        prev, l = back[k][idx]
        picks[k] = l
        idx = prev
    t = sum(layers[k][picks[k]].time for k in range(n))
    m = sum(layers[k][picks[k]].memory for k in range(n))
    return ILPSolution(picks, t, m, feasible=True)


# ---------------------------------------------------------------------------
# Generic branch-and-bound over configuration dimensions (the unified
# auto-parallel search: Eq. 6 generalized from per-layer algorithms to the
# planner's whole (pipe, microbatch, attention, remat, ...) grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Dim:
    """One search dimension: a name and its candidate values, in the order
    they should be tried (ties in predicted time resolve to the earliest
    enumerated config, exactly like exhaustive enumeration with strict <)."""

    name: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"dim {self.name!r} has no candidate values")


@dataclass
class SearchResult:
    """Outcome of :func:`search_bnb`.  When no config is feasible,
    ``feasible`` is False and ``config`` is the memory-frugal pick (the
    same contract as :func:`solve_ilp`'s infeasible path)."""

    config: Dict[str, Any]
    time: float
    memory: float
    feasible: bool
    n_evaluated: int = 0
    n_pruned: int = 0
    notes: List[str] = field(default_factory=list)


def search_bnb(dims: Sequence[Dim],
               evaluate: Callable[[Dict[str, Any]], Tuple[float, float, bool]],
               *,
               lower_bound: Optional[Callable[[Dict[str, Any]], float]] = None
               ) -> SearchResult:
    """Branch-and-bound over the cross product of ``dims``.

    ``evaluate(config)`` prices a complete assignment and returns
    ``(time, memory, feasible)``.  ``lower_bound(partial)``, if given, must
    be *admissible*: a value <= the time of every completion of the partial
    assignment — only then is the search exact (equal to exhaustive
    enumeration, which the property tests assert).  Subtrees are pruned
    when the bound cannot beat the incumbent.

    If nothing is feasible, no incumbent ever forms, so no subtree is
    pruned — the full grid is priced and the minimum-memory config is
    returned with ``feasible=False`` (memory-frugal, like
    :func:`solve_ilp`)."""
    n = len(dims)
    best_time = float("inf")
    best_cfg: Optional[Dict[str, Any]] = None
    best_mem = 0.0
    frugal_mem = float("inf")
    frugal_cfg: Optional[Dict[str, Any]] = None
    frugal_time = 0.0
    stats = {"evaluated": 0, "pruned": 0}

    def dfs(idx: int, partial: Dict[str, Any]):
        nonlocal best_time, best_cfg, best_mem
        nonlocal frugal_mem, frugal_cfg, frugal_time
        if idx == n:
            stats["evaluated"] += 1
            t, mem, ok = evaluate(dict(partial))
            if ok and t < best_time:
                best_time, best_cfg, best_mem = t, dict(partial), mem
            if mem < frugal_mem:
                frugal_mem, frugal_cfg, frugal_time = mem, dict(partial), t
            return
        if lower_bound is not None and best_time < float("inf"):
            if lower_bound(dict(partial)) >= best_time:
                stats["pruned"] += 1
                return
        for v in dims[idx].values:
            partial[dims[idx].name] = v
            dfs(idx + 1, partial)
            del partial[dims[idx].name]

    dfs(0, {})
    if best_cfg is not None:
        return SearchResult(best_cfg, best_time, best_mem, feasible=True,
                            n_evaluated=stats["evaluated"],
                            n_pruned=stats["pruned"])
    assert frugal_cfg is not None
    return SearchResult(frugal_cfg, frugal_time, frugal_mem, feasible=False,
                        n_evaluated=stats["evaluated"],
                        n_pruned=stats["pruned"])


def search_exhaustive(dims: Sequence[Dim],
                      evaluate: Callable[[Dict[str, Any]],
                                         Tuple[float, float, bool]]
                      ) -> SearchResult:
    """Reference enumeration with the same tie-break (strict <, dim-order
    traversal) — the oracle the optimality property tests compare
    :func:`search_bnb` against."""
    return search_bnb(dims, evaluate, lower_bound=None)
