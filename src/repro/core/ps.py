"""Lemma 3.2 — parameter-server sizing, its TPU mapping, and tier-aware forms.

Paper form:  N_ps >= 2 * S_p * N_w / (B_ps * T_C)
(total pull+push traffic 2*S_p per worker per step, spread over N_ps servers
of bandwidth B_ps, hidden behind compute T_C).

Equation map (see ``docs/paper_map.md``; units per symbol: S_p / wire
bytes in **bytes**, B_ps / bw in **bytes/s**, T_C / comm times in
**seconds**, N_w / N_ps / dp dimensionless counts):

- :func:`n_parameter_servers`        — Eq. (8), the lemma's N_ps ceiling
- :func:`io_time`                    — Eq. (7) LHS, one pull+push round [s]
- :func:`masked`                     — Eq. (7) as a predicate (io <= T_C)
- :func:`ps_placement_bw`,
  :func:`n_parameter_servers_tiered`,
  :func:`ps_placement_plan`          — Eq. (8) with B_ps read off a
  topology tier (in-node vs cross-node server placement)
- :func:`flat_wire_bytes`            — ring AR / RS+AG wire volume
  2*S_p*(dp-1)/dp per worker [bytes]
- :func:`hier_wire_bytes`,
  :func:`hier_comm_time`             — the FireCaffe reduction-tree
  analogue: per-tier wire bytes and summed per-phase time
- :func:`predicted_comm_time`        — Lemma 3.2's comm-time prediction
  for any runnable schedule in :data:`SCHEDULES`
- :func:`async_step_time`,
  :func:`straggler_wait`,
  :func:`staleness_efficiency`       — Eq. 7 with the lemma's synchrony
  assumption relaxed: bounded-staleness pull amortization + backup-worker
  straggler model T_step(s, k)
- :func:`tpu_grad_sync_plan`,
  :func:`grad_sync_plan`             — the lemma as a *decision*: pick the
  schedule whose comm time masks behind T_C on this topology

TPU mapping (DESIGN.md §2): the "PS cluster" is the data axis itself with
ZeRO-sharded optimizer state. The same inequality decides whether gradient
synchronization (reduce-scatter + all-gather == pull+push) hides behind
compute, and therefore which collective schedule the planner picks.

Tier-aware forms: on a hierarchical cluster (chip -> node -> cluster, see
:mod:`repro.core.hardware`) the lemma's ``B_ps`` is a *choice* — a server
colocated in-node talks over the fast intra-node links, a cross-node server
over the slow tier — and the collective analogue is the FireCaffe-style
reduction tree: reduce inside each node first, exchange only 1/node_size of
the payload across the slow tier, broadcast back in-node
(``hier_all_reduce``). :func:`hier_comm_time` prices that schedule per tier;
:func:`grad_sync_plan` picks flat vs hierarchical for a given topology.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from repro.core.hardware import ClusterSpec, Tier


def n_parameter_servers(s_p: float, n_w: int, b_ps: float, t_c: float) -> int:
    """Lemma 3.2 (Eq. 8), ceil'd. s_p bytes, b_ps bytes/s, t_c seconds."""
    if t_c <= 0 or b_ps <= 0:
        raise ValueError("t_c, b_ps > 0")
    return max(1, math.ceil(2.0 * s_p * n_w / (b_ps * t_c)))


def io_time(s_p: float, n_w: int, n_ps: int, b_ps: float) -> float:
    """Communication time for one pull+push round (Eq. 7 LHS)."""
    return 2.0 * s_p * n_w / (n_ps * b_ps)


def masked(s_p: float, n_w: int, n_ps: int, b_ps: float, t_c: float) -> bool:
    """True iff I/O hides behind compute (the ideal-pipeline condition)."""
    return io_time(s_p, n_w, n_ps, b_ps) <= t_c


# ---------------------------------------------------------------------------
# Tier-aware Lemma 3.2: B_ps depends on where the servers sit
# ---------------------------------------------------------------------------

PS_PLACEMENTS = ("in_node", "cross_node")


def ps_placement_bw(cluster: ClusterSpec, placement: str) -> float:
    """The ``B_ps`` a parameter server sees on this cluster.

    ``in_node``: the PS shard is colocated with its workers' node, so
    push/pull rides the innermost (fastest) tier.  ``cross_node``: the PS
    pool lives across the slow tier (the paper's dedicated-PS deployment),
    so every byte crosses the narrowest spanning link.
    """
    if placement == "in_node":
        return cluster.tiers[0].bw
    if placement == "cross_node":
        return cluster.min_bw
    raise KeyError(f"unknown placement {placement!r}; known: {PS_PLACEMENTS}")


def n_parameter_servers_tiered(s_p: float, n_w: int, cluster: ClusterSpec,
                               t_c: float, *,
                               placement: str = "cross_node") -> int:
    """Lemma 3.2 with ``B_ps`` read off the topology tier the servers sit
    on, instead of a flat scalar."""
    return n_parameter_servers(s_p, n_w, ps_placement_bw(cluster, placement),
                               t_c)


def ps_placement_plan(s_p: float, n_w: int, cluster: ClusterSpec,
                      t_c: float) -> Dict[str, Dict[str, float]]:
    """Both Lemma 3.2 regimes side by side: the N_ps you need when servers
    are in-node vs across the slow tier, and which placement is cheaper
    (fewer servers for the same maskability)."""
    out: Dict[str, Dict[str, float]] = {}
    for placement in PS_PLACEMENTS:
        bw = ps_placement_bw(cluster, placement)
        n_ps = n_parameter_servers(s_p, n_w, bw, t_c)
        out[placement] = {
            "b_ps": bw,
            "n_ps": n_ps,
            "io_time_s": io_time(s_p, n_w, n_ps, bw),
        }
    out["recommended"] = min(
        PS_PLACEMENTS, key=lambda p: out[p]["n_ps"])  # type: ignore[assignment]
    return out


# ---------------------------------------------------------------------------
# Bounded-staleness async PS: Lemma 3.2 with its synchrony assumption relaxed
# ---------------------------------------------------------------------------
# Eq. 7 prices ONE pull + ONE push per worker per step.  Bounded staleness
# (refresh window s) keeps the push every step but amortizes the pull over
# s+1 steps — each worker re-pulls only when its copy would exceed age s —
# so the per-step server traffic drops from 2*S_p to S_p*(1 + 1/(s+1)).
# Backup workers drop the slowest k of dp gradients: the synchronization
# barrier waits for order statistic (dp-k) instead of dp.  With exponential
# per-worker delay of mean ``mean_delay`` the expected barrier wait is
# mean_delay * (H_dp - H_k) (max of dp exponentials minus the k tail terms),
# so k > 0 shaves exactly the slow tail the paper's §2 taxonomy flags.
# Staleness is not free: stale gradients dilute progress-per-step, modeled
# as the standard hyperbolic discount 1/(1 + gamma*s) on statistical
# efficiency (Hitchhiker's-Guide-style SSP analyses).

# statistical-efficiency discount per unit staleness in 1/(1 + gamma*s);
# calibrated SSP studies put the knee near s~4-8, gamma 0.05-0.2
DEFAULT_STALENESS_GAMMA = 0.1


def _harmonic(n: int) -> float:
    """H_n = sum_{i<=n} 1/i (H_0 = 0)."""
    return sum(1.0 / i for i in range(1, max(n, 0) + 1))


def straggler_wait(dp: int, k: int, mean_delay: float) -> float:
    """Expected barrier wait [s] when the sync waits for dp-k of dp workers
    whose per-step delays are iid exponential(mean_delay).

    E[max of dp] = mean_delay * H_dp; dropping the slowest k removes the
    k largest gap terms, leaving mean_delay * (H_dp - H_k).  k = 0 is the
    full synchronous barrier, k = dp-1 waits only for the fastest worker.
    """
    if not 0 <= k < max(dp, 1):
        raise ValueError(f"need 0 <= k < dp, got k={k} dp={dp}")
    if dp <= 1 or mean_delay <= 0:
        return 0.0
    return mean_delay * (_harmonic(dp) - _harmonic(k))


def staleness_efficiency(s: int, gamma: float = DEFAULT_STALENESS_GAMMA) -> float:
    """Statistical efficiency in (0, 1]: progress per step relative to the
    synchronous baseline under bounded staleness s (1/(1 + gamma*s);
    s = 0 is exactly 1)."""
    if s < 0:
        raise ValueError(f"staleness must be >= 0, got {s}")
    return 1.0 / (1.0 + gamma * max(s, 0))


def async_step_time(s_p: float, n_w: int, n_ps: int, b_ps: float, t_c: float,
                    *, staleness: int = 0, backup_workers: int = 0,
                    mean_delay: float = 0.0,
                    gamma: float = DEFAULT_STALENESS_GAMMA) -> Dict[str, float]:
    """T_step(s, k): the bounded-staleness/backup-worker step-time model.

    Per-step PS traffic is ``push + pull/(s+1)`` (push every step, pull
    amortized over the refresh window); the barrier waits
    ``straggler_wait(dp, k, mean_delay)``; and ``effective_step`` divides
    the wall clock by :func:`staleness_efficiency` so plans that trade
    synchrony for throughput still pay the statistical-progress price.
    With ``staleness=0, backup_workers=0, mean_delay=0`` the ``io`` term is
    exactly Eq. 7's :func:`io_time` and the model degenerates to the
    synchronous lemma.
    """
    push = s_p * n_w / (n_ps * b_ps)
    pull = push / (staleness + 1)
    wait = straggler_wait(n_w, backup_workers, mean_delay)
    eff = staleness_efficiency(staleness, gamma)
    io = push + pull
    exposed_io = max(io - t_c, 0.0)
    wall = t_c + exposed_io + wait
    return {
        "t_compute": t_c,
        "io": io,
        "push": push,
        "pull": pull,
        "pull_amortization": 1.0 / (staleness + 1),
        "straggler_wait": wait,
        "efficiency": eff,
        "wall_step": wall,
        "effective_step": wall / eff,
    }


# ---------------------------------------------------------------------------
# Runnable schedules and their comm-time forms
# ---------------------------------------------------------------------------

# Runnable schedules (executed by repro.distributed.collectives; the planner
# stores one of these in Plan.sync_schedule and Plan.resolve_sync turns it
# into the executable strategy).
SCHEDULES = ("all_reduce", "reduce_scatter_all_gather", "parameter_server",
             "hier_all_reduce")


def flat_wire_bytes(s_p: float, dp: int) -> float:
    """Per-worker wire bytes of a ring all-reduce / RS+AG over dp workers."""
    frac = (dp - 1) / dp if dp > 1 else 0.0
    return 2.0 * s_p * frac


def hier_wire_bytes(s_p: float, tier_sizes: Sequence[int]) -> Tuple[float, ...]:
    """Per-worker wire bytes at each tier of the hierarchical schedule.

    Tier 0 (in-node) reduce-scatters and later all-gathers the full payload:
    2*S_p*(d0-1)/d0.  Tier k exchanges only the 1/prod(d_<k) shard that
    survived the inner reductions: 2*(S_p/prod)*(d_k-1)/d_k — the
    FireCaffe reduction-tree saving.
    """
    out, shard = [], s_p
    for d in tier_sizes:
        out.append(flat_wire_bytes(shard, d))
        shard /= max(d, 1)
    return tuple(out)


def hier_comm_time(s_p: float, tiers: Sequence[Tier]) -> Tuple[float, Tuple[Dict, ...]]:
    """Total comm time and the per-tier breakdown of ``hier_all_reduce``.

    Phases are sequential (reduce in, exchange across, broadcast out), so
    the total is the *sum* of per-tier times — but each tier only carries
    its shard, which is what beats a flat ring priced at the min bandwidth.
    """
    wires = hier_wire_bytes(s_p, [t.size for t in tiers])
    per_tier = tuple(
        {"tier": t.name, "size": t.size, "bw": t.bw,
         "wire_bytes": w, "time_s": w / t.bw + (t.latency if t.size > 1 else 0.0)}
        for t, w in zip(tiers, wires))
    return sum(p["time_s"] for p in per_tier), per_tier


def predicted_comm_time(schedule: str, s_p: float, dp: int, link_bw: float,
                        *, n_ps: int = 0,
                        tiers: Optional[Sequence[Tier]] = None) -> float:
    """Lemma 3.2's comm-time prediction for a runnable schedule.

    Ring all-reduce and RS+AG move 2*S_p*(dp-1)/dp per worker over the
    narrowest link; the sharded parameter-server emulation is Eq. 7's
    server-side bottleneck 2*S_p*N_w/(N_ps*B_ps) with N_w = dp workers;
    ``hier_all_reduce`` sums the per-tier phases (pass ``tiers``; without a
    topology it degenerates to the flat form at ``link_bw``).
    """
    if schedule == "parameter_server":
        return io_time(s_p, dp, n_ps or dp, link_bw)
    if schedule in ("all_reduce", "reduce_scatter_all_gather"):
        return flat_wire_bytes(s_p, dp) / link_bw
    if schedule == "hier_all_reduce":
        if not tiers:
            tiers = (Tier("flat", dp, link_bw),)
        return hier_comm_time(s_p, tiers)[0]
    raise KeyError(f"unknown schedule {schedule!r}; known: {SCHEDULES}")


# ---------------------------------------------------------------------------
# Overlap-aware step pricing (bucketed comm/compute pipelining)
# ---------------------------------------------------------------------------

# fraction of the compute step spent in the forward pass under the standard
# 1:2 fwd:bwd FLOP split (the backward differentiates both matmul operands)
FWD_FRACTION = 1.0 / 3.0

# Default sync-bucket payload target (MiB) shared by the cost model and the
# executable bucketing (repro.distributed.overlap imports it from here —
# core stays import-light and never imports distributed).
DEFAULT_BUCKET_MB = 4.0


def bucket_count(grad_bytes: float, bucket_mb: float) -> int:
    """Size-level sync-bucket count: ceil(payload / cap).

    The executable leaf-level plan (``repro.distributed.overlap.
    build_bucket_plan``) packs whole leaves under the same cap, so its
    bucket count is >= this (unless a single leaf exceeds the cap on its
    own) — the modeled hideable window ``(n-1)/n`` stays a conservative
    estimate of the real schedule's granularity."""
    mb = bucket_mb if bucket_mb > 0 else DEFAULT_BUCKET_MB
    if grad_bytes <= 0:
        return 1
    return max(math.ceil(grad_bytes / (mb * 2.0 ** 20)), 1)


def overlap_exposed_comm(t_comm: float, t_bwd: float, n_buckets: int, *,
                         overlap_efficiency: float = 1.0) -> float:
    """Comm time left *outside* compute after bucketed overlap [s].

    With ``n_buckets`` dependency-ordered sync buckets, the first bucket's
    gradients are ready after ~``t_bwd / n_buckets`` of the backward pass,
    so up to ``t_bwd * (n_buckets - 1) / n_buckets`` of backward compute can
    hide collectives (Shi et al.'s wait-free backpropagation window).
    ``overlap_efficiency`` in [0, 1] derates the window to the *achieved*
    overlap (``SyncReport.overlap_fraction``, calibrated by the autotuner);
    0 — or a single bucket, whose gradients only complete with the backward
    itself — degrades exactly to the serial ``t_comm``.
    """
    if t_comm <= 0:
        return 0.0
    if n_buckets <= 1 or overlap_efficiency <= 0 or t_bwd <= 0:
        return t_comm
    window = t_bwd * (n_buckets - 1) / n_buckets
    window *= min(max(overlap_efficiency, 0.0), 1.0)
    return max(t_comm - window, 0.0)


def overlap_step_time(t_fwd: float, t_bwd: float, t_comm: float,
                      n_buckets: int, *,
                      overlap_efficiency: float = 1.0) -> Dict[str, float]:
    """The overlapped step-time model (units: seconds):

        T_step = T_fwd + max(T_bwd, T_bwd_tail + T_comm * (1 - f) ...)
               = T_fwd + T_bwd + T_exposed

    where ``T_exposed = max(T_comm - window, 0)`` with the hideable window
    ``(T_bwd - T_bwd/n) * efficiency`` — comm launched per bucket as its
    gradients complete, only the residual sticking out past the backward.
    Returns the breakdown; ``total`` with ``n_buckets <= 1`` or zero
    efficiency is exactly the serial ``T_fwd + T_bwd + T_comm``.
    """
    exposed = overlap_exposed_comm(t_comm, t_bwd, n_buckets,
                                   overlap_efficiency=overlap_efficiency)
    hidden = t_comm - exposed
    return {
        "t_fwd": t_fwd, "t_bwd": t_bwd, "t_comm": t_comm,
        "n_buckets": float(max(n_buckets, 1)),
        "hidden_comm": hidden, "exposed_comm": exposed,
        "overlap_fraction": hidden / t_comm if t_comm > 0 else 0.0,
        "total": t_fwd + t_bwd + exposed,
    }


@dataclass(frozen=True)
class SyncPlan:
    schedule: str  # one of SCHEDULES (PS only via explicit request)
    comm_time: float
    compute_time: float
    masked: bool
    note: str
    bottleneck_tier: str = ""
    per_tier: Tuple[Dict, ...] = field(default_factory=tuple)


def tpu_grad_sync_plan(param_bytes: float, dp: int, link_bw: float,
                       t_c: float, *, zero_sharded: bool = True) -> SyncPlan:
    """Lemma 3.2 on the TPU data axis.

    all-reduce moves ~2*S_p*(dp-1)/dp per chip; reduce-scatter + all-gather
    moves the same wire bytes but splits the optimizer work 1/dp per chip
    (the ZeRO '"N_ps = dp parameter servers'" mapping) and lets the
    all-gather overlap the next step's first layers.
    """
    wire = flat_wire_bytes(param_bytes, dp)
    comm = wire / link_bw
    schedule = "reduce_scatter_all_gather" if zero_sharded else "all_reduce"
    return SyncPlan(
        schedule=schedule,
        comm_time=comm,
        compute_time=t_c,
        masked=comm <= t_c,
        note=(f"wire {wire/1e9:.2f} GB over dp={dp}; "
              + ("hidden behind compute" if comm <= t_c else
                 "NOT maskable - increase T_C (bigger microbatch) or shrink S_p")),
    )


def grad_sync_plan(param_bytes: float, dp_tiers: Sequence[Tier], t_c: float,
                   *, zero_sharded: bool = True) -> SyncPlan:
    """Tier-aware Lemma 3.2: pick the cheapest schedule for this topology.

    On a uniform (single spanning tier) view this reduces exactly to
    :func:`tpu_grad_sync_plan`.  On a hierarchy it prices the flat ring at
    the bottleneck bandwidth against the hierarchical reduce/exchange/
    broadcast and returns whichever masks better, with the per-tier
    breakdown and the bottleneck tier named either way.
    """
    spanning = [t for t in dp_tiers if t.size > 1]
    dp = math.prod(t.size for t in dp_tiers) if dp_tiers else 1
    if len(spanning) <= 1:
        bw = spanning[0].bw if spanning else dp_tiers[0].bw
        flat = tpu_grad_sync_plan(param_bytes, dp, bw, t_c,
                                  zero_sharded=zero_sharded)
        lat = spanning[0].latency if spanning else 0.0
        if lat:
            comm = flat.comm_time + lat
            flat = dataclasses.replace(flat, comm_time=comm,
                                       masked=comm <= t_c)
        name = spanning[0].name if spanning else dp_tiers[0].name
        return dataclasses.replace(flat, bottleneck_tier=name)

    min_bw = min(t.bw for t in spanning)
    # the flat ring spans every tier, so it pays each spanning tier's
    # latency too — without this the comparison would be biased flat-ward
    flat_time = (flat_wire_bytes(param_bytes, dp) / min_bw
                 + sum(t.latency for t in spanning))
    hier_time, per_tier = hier_comm_time(param_bytes, dp_tiers)
    if hier_time < flat_time:
        bottleneck = max((p for p in per_tier if p["size"] > 1),
                         key=lambda p: p["time_s"])["tier"]
        return SyncPlan(
            schedule="hier_all_reduce",
            comm_time=hier_time,
            compute_time=t_c,
            masked=hier_time <= t_c,
            note=(f"hierarchical {'x'.join(str(t.size) for t in dp_tiers)}: "
                  f"{hier_time:.3f}s vs flat {flat_time:.3f}s at bottleneck "
                  f"tier '{bottleneck}'; "
                  + ("hidden behind compute" if hier_time <= t_c
                     else "NOT maskable")),
            bottleneck_tier=bottleneck,
            per_tier=per_tier,
        )
    flat = tpu_grad_sync_plan(param_bytes, dp, min_bw, t_c,
                              zero_sharded=zero_sharded)
    if flat_time != flat.comm_time:  # carry the latency hops priced above
        flat = dataclasses.replace(flat, comm_time=flat_time,
                                   masked=flat_time <= t_c)
    bottleneck = min(spanning, key=lambda t: t.bw).name
    return dataclasses.replace(flat, bottleneck_tier=bottleneck)


# ---------------------------------------------------------------------------
# Lemma 3.2 for inference — replica sizing against a latency SLO
# ---------------------------------------------------------------------------
# The training lemma sizes servers so I/O hides behind compute.  Serving has
# the same structure with the roles renamed: the "step time" is one decode
# step (HBM-bound weight + KV traffic), the "budget" is the latency SLO, and
# the sized resource is replicas instead of parameter servers.
#
# Model: each replica is an M/D/1 queue (Poisson arrivals at rate
# lambda/N_rep, deterministic service T_svc / batch).  Mean wait
# W_q = rho * T_svc / (2 * (1 - rho)); requiring W_q <= slack = SLO - T_svc
# gives the utilization ceiling rho* = x / (1 + x) with x = 2*slack/T_svc,
# and hence  N_rep = ceil(lambda * T_svc / (batch * rho*)).


def decode_step_time(param_bytes: float, kv_bytes: float, hbm_bw: float) -> float:
    """One decode step is HBM-bound: stream weights + resident KV once.
    param_bytes/kv_bytes in bytes, hbm_bw in bytes/s -> seconds."""
    if hbm_bw <= 0:
        raise ValueError("hbm_bw > 0")
    return (param_bytes + kv_bytes) / hbm_bw


def service_time(t_prefill: float, n_new: int, t_step: float) -> float:
    """End-to-end service time for one request: prefill + n_new decode steps.
    (The prefill samples the first token, so n_new-1 further steps would be
    exact; we keep n_new as a half-step of slack for sampling overhead.)"""
    return t_prefill + n_new * t_step


def md1_wait(rho: float, t_svc: float) -> float:
    """M/D/1 mean queueing delay at utilization rho (0 <= rho < 1)."""
    if not 0 <= rho < 1:
        raise ValueError("0 <= rho < 1")
    return rho * t_svc / (2.0 * (1.0 - rho))


def serve_utilization_bound(slo_s: float, t_svc: float) -> float:
    """Largest per-replica utilization rho* with W_q(rho*) <= SLO - T_svc.
    Returns 0.0 when the SLO is not attainable even on an idle replica
    (slack <= 0) -- callers must treat 0 as "no finite replica count"."""
    slack = slo_s - t_svc
    if slack <= 0 or t_svc <= 0:
        return 0.0
    x = 2.0 * slack / t_svc
    return x / (1.0 + x)


def n_replicas(arrival_rate: float, t_svc: float, batch: int,
               rho_star: float) -> int:
    """Replica count so each replica runs at <= rho*; ceil'd like Eq. 8."""
    if rho_star <= 0:
        raise ValueError("SLO unattainable: rho* <= 0")
    per_replica = batch * rho_star / t_svc  # sustainable req/s per replica
    return max(1, math.ceil(arrival_rate / per_replica))


def serve_replica_plan(*, arrival_rate: float, t_prefill_s: float,
                       t_step_s: float, n_new: int, batch: int,
                       slo_s: float) -> Dict[str, object]:
    """The inference lemma as a decision, JSON-safe (no inf/nan).

    arrival_rate in requests/s offered to the fleet; slo_s is the p-mean
    end-to-end latency target.  Returns predicted replicas, the service
    time, the utilization ceiling, and whether the SLO is attainable at
    all (slack > 0).
    """
    t_svc = service_time(t_prefill_s, n_new, t_step_s)
    rho_star = serve_utilization_bound(slo_s, t_svc)
    attainable = rho_star > 0
    replicas = n_replicas(arrival_rate, t_svc, batch, rho_star) if attainable else 0
    plan: Dict[str, object] = {
        "t_service_s": t_svc,
        "t_step_s": t_step_s,
        "utilization_bound": rho_star,
        "replicas": replicas,
        "attainable": attainable,
        "arrival_rate": arrival_rate,
        "slo_s": slo_s,
    }
    if attainable:
        rho = arrival_rate * t_svc / (batch * replicas)
        plan["utilization"] = rho
        plan["wait_s"] = md1_wait(min(rho, rho_star), t_svc)
    return plan
