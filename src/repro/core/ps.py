"""Lemma 3.2 — parameter-server sizing, and its TPU mapping.

Paper form:  N_ps >= 2 * S_p * N_w / (B_ps * T_C)
(total pull+push traffic 2*S_p per worker per step, spread over N_ps servers
of bandwidth B_ps, hidden behind compute T_C).

TPU mapping (DESIGN.md §2): the "PS cluster" is the data axis itself with
ZeRO-sharded optimizer state. The same inequality decides whether gradient
synchronization (reduce-scatter + all-gather == pull+push) hides behind
compute, and therefore which collective schedule the planner picks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def n_parameter_servers(s_p: float, n_w: int, b_ps: float, t_c: float) -> int:
    """Lemma 3.2 (Eq. 8), ceil'd. s_p bytes, b_ps bytes/s, t_c seconds."""
    if t_c <= 0 or b_ps <= 0:
        raise ValueError("t_c, b_ps > 0")
    return max(1, math.ceil(2.0 * s_p * n_w / (b_ps * t_c)))


def io_time(s_p: float, n_w: int, n_ps: int, b_ps: float) -> float:
    """Communication time for one pull+push round (Eq. 7 LHS)."""
    return 2.0 * s_p * n_w / (n_ps * b_ps)


def masked(s_p: float, n_w: int, n_ps: int, b_ps: float, t_c: float) -> bool:
    """True iff I/O hides behind compute (the ideal-pipeline condition)."""
    return io_time(s_p, n_w, n_ps, b_ps) <= t_c


# Runnable schedules (executed by repro.distributed.collectives; the planner
# stores one of these in Plan.sync_schedule and Plan.resolve_sync turns it
# into the executable strategy).
SCHEDULES = ("all_reduce", "reduce_scatter_all_gather", "parameter_server")


def predicted_comm_time(schedule: str, s_p: float, dp: int, link_bw: float,
                        *, n_ps: int = 0) -> float:
    """Lemma 3.2's comm-time prediction for a runnable schedule.

    Ring all-reduce and RS+AG move 2*S_p*(dp-1)/dp per worker; the sharded
    parameter-server emulation is Eq. 7's server-side bottleneck
    2*S_p*N_w/(N_ps*B_ps) with N_w = dp workers.
    """
    if schedule == "parameter_server":
        return io_time(s_p, dp, n_ps or dp, link_bw)
    if schedule in ("all_reduce", "reduce_scatter_all_gather"):
        frac = (dp - 1) / dp if dp > 1 else 0.0
        return 2.0 * s_p * frac / link_bw
    raise KeyError(f"unknown schedule {schedule!r}; known: {SCHEDULES}")


@dataclass(frozen=True)
class SyncPlan:
    schedule: str  # one of SCHEDULES (PS only via explicit request)
    comm_time: float
    compute_time: float
    masked: bool
    note: str


def tpu_grad_sync_plan(param_bytes: float, dp: int, link_bw: float,
                       t_c: float, *, zero_sharded: bool = True) -> SyncPlan:
    """Lemma 3.2 on the TPU data axis.

    all-reduce moves ~2*S_p*(dp-1)/dp per chip; reduce-scatter + all-gather
    moves the same wire bytes but splits the optimizer work 1/dp per chip
    (the ZeRO '"N_ps = dp parameter servers'" mapping) and lets the
    all-gather overlap the next step's first layers.
    """
    frac = (dp - 1) / dp if dp > 1 else 0.0
    wire = 2.0 * param_bytes * frac
    comm = wire / link_bw
    schedule = "reduce_scatter_all_gather" if zero_sharded else "all_reduce"
    return SyncPlan(
        schedule=schedule,
        comm_time=comm,
        compute_time=t_c,
        masked=comm <= t_c,
        note=(f"wire {wire/1e9:.2f} GB over dp={dp}; "
              + ("hidden behind compute" if comm <= t_c else
                 "NOT maskable - increase T_C (bigger microbatch) or shrink S_p")),
    )
