"""Closed-loop autotuner — the paper's §3 procedure driven by measurements.

The abstract promises "a procedure for setting minibatch size and choosing
computation algorithms".  Until this module the planner priced every step
from datasheet constants (:class:`~repro.core.hardware.Chip` /
:class:`~repro.core.hardware.ClusterSpec`) and the user picked ``batch`` and
kernel variants by hand.  This module closes the loop, in the
measured-vs-modeled style of Shi et al.:

1. **Microbenchmark** — time the kernel algorithm variants
   (:func:`repro.kernels.ops.tune_candidates`: pallas flash vs jnp dense
   attention, decode attention, ssd_scan chunk sizes), the Table-2 conv
   algorithms (GEMM vs FFT feasibility under Eq. 5's ``M_bound``), host
   microkernels (matmul FLOP/s, triad bandwidth), and short trainer steps.
2. **Calibrate** — fit a :class:`Calibration` overlay on the cluster:
   achieved FLOP/s per chip (from measured ``StepTimes``), achieved
   memory-system bandwidth (triad), and effective data-axis link bandwidth
   (from a measured ``SyncReport`` when ``dp >= 2``).  Persisted to a JSON
   cache keyed by ``backend/cluster/executed-config`` so later sessions and sweeps reuse it.
3. **Procedure** — binary-search the largest memory-feasible minibatch
   (Eq. 5 ``m_bound`` for the paper's CNN form,
   :func:`repro.core.memory_model.max_microbatch` for the transformer
   generalization), pick the fastest measured-feasible algorithm per op,
   and re-plan with :func:`Calibration.apply` so ``estimate_step_time`` and
   ``grad_sync_plan`` price from measurements instead of datasheet numbers.

Everything heavier than dataclass math imports jax lazily, so this module
(like the rest of ``repro.core``) stays importable without a backend.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import memory_model as mm
from repro.core.hardware import ClusterSpec, MeshSpec
from repro.core.planner import (Plan, estimate_step_time, plan as plan_fn,
                                train_flops_per_step)
from repro.obs import MetricsRegistry, Tracer  # stdlib-only, import-light
from repro.obs.trace import monotonic

# Schema id of the tuning section a Session.tune() Report carries under
# ``measured["tuning"]`` (validated by repro.api.report.validate_report).
TUNING_SCHEMA_ID = "repro.api/tuning/v1"

# Default on-disk calibration cache (keyed by backend/cluster/executed-config).
DEFAULT_CACHE_PATH = "results/calibration_cache.json"
CACHE_SCHEMA_ID = "repro.core/autotune-cache/v1"


# ---------------------------------------------------------------------------
# Timing helpers
# ---------------------------------------------------------------------------


def _timeit(fn, *args, repeats: int = 2) -> float:
    """Best-of-``repeats`` wall time of ``fn(*args)`` (seconds), after one
    untimed warmup call that absorbs tracing/compilation."""
    import jax

    jax.block_until_ready(fn(*args))
    best = math.inf
    for _ in range(max(repeats, 1)):
        t0 = monotonic()
        jax.block_until_ready(fn(*args))
        best = min(best, monotonic() - t0)
    return best


def host_microbench(*, n: int = 512, copy_mb: int = 32,
                    repeats: int = 3) -> Dict[str, float]:
    """Achieved host constants: matmul FLOP/s and triad-style bytes/s."""
    import jax
    import jax.numpy as jnp

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (n, n), jnp.float32)
    b = jax.random.normal(k2, (n, n), jnp.float32)
    t_mm = _timeit(jax.jit(lambda x, y: x @ y), a, b, repeats=repeats)
    matmul_flops = 2.0 * n ** 3 / t_mm

    m = max(copy_mb * 2 ** 20 // 4, 1)
    x = jnp.ones((m,), jnp.float32)
    y = jnp.full((m,), 2.0, jnp.float32)
    t_triad = _timeit(jax.jit(lambda u, v: u + 2.0 * v), x, y,
                      repeats=repeats)
    triad_bw = 3.0 * 4.0 * m / t_triad  # 2 reads + 1 write per element
    return {"matmul_flops": matmul_flops, "triad_bw": triad_bw,
            "matmul_n": float(n), "copy_mb": float(copy_mb)}


# ---------------------------------------------------------------------------
# Kernel-variant benchmarking (the "choosing computation algorithms" half)
# ---------------------------------------------------------------------------


def bench_kernels(*, seq: int = 128, repeats: int = 2,
                  ssd_chunks: Tuple[int, ...] = (32, 64, 128)
                  ) -> Dict[str, Dict[str, Any]]:
    """Time every registered variant of every tunable op and pick the
    fastest one that runs.  Variants that raise are recorded (not fatal) —
    an algorithm that cannot execute on this backend is infeasible, which
    is exactly what the paper's procedure prunes on."""
    from repro.kernels import ops

    out: Dict[str, Dict[str, Any]] = {}
    for op in ops.TUNABLE_OPS:
        inputs = ops.tune_inputs(op, seq=seq)
        times: Dict[str, float] = {}
        errors: Dict[str, str] = {}
        for name, fn in ops.tune_candidates(op, ssd_chunks=ssd_chunks).items():
            try:
                times[name] = _timeit(fn, *inputs, repeats=repeats)
            except Exception as e:  # infeasible variant: record, keep going
                errors[name] = f"{type(e).__name__}: {e}"
        chosen = min(times, key=times.get) if times else ""
        out[op] = {"chosen": chosen, "times_s": times, "errors": errors,
                   "seq": seq}
    return out


def choose_conv_algs(x_mini: int, m_gpu_bytes: float) -> Dict[str, Any]:
    """Table 2's algorithm choice under Eq. 5: per AlexNet conv layer, FFT
    when its (larger) working set fits ``M_bound``, else GEMM.  The paper's
    premise is that FFT is the faster algorithm whenever it fits — memory
    feasibility *is* the selection rule."""
    budget = mm.m_bound(mm.ALEXNET, x_mini, m_gpu_bytes)
    layers: List[Dict[str, Any]] = []
    for i, (row, paper_ratio) in enumerate(mm.TABLE2_ROWS):
        gemm, fft = mm.conv_alg_memory(x_mini, *row[1:])
        chosen = "fft" if fft <= budget else (
            "gemm" if gemm <= budget else "none")
        layers.append({
            "layer": f"conv{i + 1}", "gemm_bytes": gemm, "fft_bytes": fft,
            "ratio": fft / gemm, "paper_ratio": paper_ratio,
            "chosen": chosen, "feasible": chosen != "none",
        })
    return {"x_mini": x_mini, "m_gpu_bytes": m_gpu_bytes,
            "m_bound_bytes": budget, "layers": layers}


# ---------------------------------------------------------------------------
# Measured trainer steps (the StepTimes/SyncReport feedback path)
# ---------------------------------------------------------------------------


def measure_train_steps(cfg: ModelConfig, *, batch: int, seq: int,
                        steps: int = 3, dp: int = 0, seed: int = 0,
                        topology: Optional[ClusterSpec] = None
                        ) -> Dict[str, Any]:
    """Run a short instrumented training burst and distill the timings the
    calibration fit needs.  ``dp >= 2`` uses the explicit data-parallel
    trainer (measuring the sync phase too); otherwise the single-process
    loop.  Best-of-steps is reported next to the steady mean so the jit
    compile in step 0 cannot poison the fit."""
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig
    from repro.train import loop as loop_lib

    run = RunConfig(attn_impl="auto", remat="none")
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=max(steps, 1))
    sync_report = None
    if dp >= 2:
        import jax

        from repro.distributed.trainer import DataParallelTrainer

        devs = jax.devices()
        if len(devs) < dp:
            raise RuntimeError(f"dp={dp} but only {len(devs)} devices; set "
                               "XLA_FLAGS=--xla_force_host_platform_device_"
                               f"count={dp}")
        tr = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                                 devices=devs[:dp], topology=topology)
        res = tr.train(batch=batch, seq=seq, steps=steps, seed=seed,
                       log_every=0)
        sync_report = tr.report().as_dict()
    else:
        res = loop_lib.train(cfg, run, opt, batch=batch, seq=seq, steps=steps,
                             seed=seed, log_every=0)
    ts = res.step_times
    step_total = [t.compute + t.param_update + t.dist_update for t in ts]
    steady = ts[2:] or ts
    mean = lambda xs: float(sum(xs) / len(xs)) if xs else 0.0
    out: Dict[str, Any] = {
        "steps": len(ts),
        "batch": batch, "seq": seq, "dp": dp,
        "best_step_s": float(min(step_total)) if step_total else 0.0,
        "best_compute_s": float(min(t.compute for t in ts)) if ts else 0.0,
        "mean_step_s": mean([t.compute + t.param_update + t.dist_update
                             for t in steady]),
        "mean_compute_s": mean([t.compute for t in steady]),
        "mean_comm_s": mean([t.dist_update for t in steady]),
        "tokens_per_s": float(res.tokens_per_s),
        "r_o": float(res.mean_r_o),
    }
    if sync_report is not None:
        out["sync"] = sync_report
    return out


# default bucket-size candidates for the overlap sweep [MiB]; callers with
# tiny (test-scale) gradients pass their own
DEFAULT_OVERLAP_BUCKET_MBS = (1.0, 4.0, 16.0)


def tune_overlap(cfg: ModelConfig, *, batch: int, seq: int, dp: int,
                 steps: int = 8, seed: int = 0,
                 bucket_mbs: Tuple[float, ...] = DEFAULT_OVERLAP_BUCKET_MBS,
                 topology: Optional[ClusterSpec] = None) -> Dict[str, Any]:
    """Measure the achieved comm/compute overlap and its bucket-size sweet
    spot: one short overlapped trainer burst per candidate ``bucket_mb``,
    chosen on fused-step wall clock.  The winner's measured
    ``overlap_fraction`` calibrates the cost model's hideable window
    (:func:`repro.core.ps.overlap_exposed_comm`) the same way the measured
    ``effective_link_bw`` calibrates Lemma 3.2's bandwidth."""
    import jax

    from repro.distributed.trainer import DataParallelTrainer
    from repro.models.blocks import RunConfig
    from repro.optim.adamw import OptConfig

    devs = jax.devices()
    if dp < 2 or len(devs) < dp:
        return {"measured": False,
                "note": f"needs dp >= 2 visible devices (dp={dp}, "
                        f"visible={len(devs)})"}
    run = RunConfig(attn_impl="auto", remat="none")
    steps = max(steps, DataParallelTrainer.N_CALIB_STEPS + 3)
    candidates: Dict[str, Dict[str, float]] = {}
    best_mb, best_wall = 0.0, math.inf
    for mb in bucket_mbs:
        opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=steps)
        tr = DataParallelTrainer(cfg, run, opt, strategy="all_reduce",
                                 devices=devs[:dp], topology=topology,
                                 sync_overlap=True, bucket_mb=mb)
        tr.train(batch=batch, seq=seq, steps=steps, seed=seed, log_every=0)
        rep = tr.report()
        wall = rep.overlapped_step_s or math.inf
        candidates[f"{mb:g}"] = {
            "bucket_mb": mb,
            "n_buckets": rep.n_buckets,
            "overlap_fraction": rep.overlap_fraction,
            "exposed_comm_s": rep.exposed_comm_time,
            "serial_comm_s": rep.measured_comm_s,
            "fused_step_s": rep.overlapped_step_s,
        }
        if wall < best_wall:
            best_mb, best_wall = mb, wall
    chosen = candidates.get(f"{best_mb:g}", {})
    return {
        "measured": True,
        "dp": dp,
        "steps": steps,
        "candidates": candidates,
        "chosen_bucket_mb": best_mb,
        "overlap_fraction": float(chosen.get("overlap_fraction", 0.0)),
        "exposed_comm_s": float(chosen.get("exposed_comm_s", 0.0)),
        "serial_comm_s": float(chosen.get("serial_comm_s", 0.0)),
    }


# ---------------------------------------------------------------------------
# Calibration — the measured overlay on Chip/ClusterSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Calibration:
    """Measured hardware constants for one ``backend/cluster/executed-config`` triple.

    ``achieved_flops`` is the per-chip FLOP/s the *trainer* achieves (the
    model-flops-over-measured-compute fit — framework overhead included,
    which is what makes the re-planned ``estimate_step_time`` land near the
    wall clock).  ``matmul_flops``/``triad_bw`` are the raw microkernel
    ceilings kept for provenance and as the fallback when no trainer
    measurement exists.  ``link_bw`` is the effective per-worker data-axis
    bandwidth fitted from a measured ``SyncReport`` (0 = unmeasured)."""

    backend: str
    cluster: str
    achieved_flops: float           # FLOP/s per chip, trainer-fitted
    matmul_flops: float = 0.0       # FLOP/s, microkernel ceiling
    hbm_bw: float = 0.0             # bytes/s, triad microkernel
    link_bw: float = 0.0            # bytes/s per worker (0 = unmeasured)
    # achieved comm/compute overlap (SyncReport.overlap_fraction of the
    # best measured bucket size): derates the overlap model's hideable
    # window the same way link_bw re-prices Lemma 3.2.  ``bucket_mb > 0``
    # marks that the sweep actually ran — a fraction of 0.0 with a set
    # bucket_mb is a real measurement (no hiding achieved), not "unknown"
    overlap_fraction: float = 0.0
    bucket_mb: float = 0.0          # measured bucket-size sweet spot [MiB]
    arch: str = ""                  # executed config the wall clock belongs to
    measured: Dict[str, float] = field(default_factory=dict)
    created: str = ""

    @property
    def key(self) -> str:
        # the arch is part of the key: achieved FLOP/s is fitted *through*
        # a model, and the cached wall clock (replan's reference) is only
        # comparable to predictions for that same executed config
        base = f"{self.backend}/{self.cluster}"
        return f"{base}/{self.arch}" if self.arch else base

    def flops_efficiency(self, chip) -> float:
        """Achieved/peak — the fraction of the datasheet the measured
        trainer actually sustains on this backend."""
        return self.achieved_flops / chip.peak_flops if chip.peak_flops else 0.0

    # -- overlay ----------------------------------------------------------
    def apply(self, mesh: MeshSpec) -> MeshSpec:
        """Re-price a mesh on measured constants: the chip's peak FLOP/s and
        HBM bandwidth become the achieved ones, and every topology tier's
        bandwidth is rescaled so the bottleneck tier matches the measured
        link bandwidth (relative hierarchy preserved).  The chip keeps its
        name plus a ``+cal`` marker so plans record their provenance."""
        chip = mesh.chip.scaled(
            peak_flops=self.achieved_flops or self.matmul_flops or None,
            hbm_bw=self.hbm_bw or None)
        cluster = mesh.cluster
        tiers = cluster.tiers
        if self.link_bw > 0 and cluster.min_bw > 0:
            r = self.link_bw / cluster.min_bw
            tiers = tuple(replace(t, bw=t.bw * r) for t in tiers)
        topo = ClusterSpec(name=cluster.name, chip=chip, tiers=tiers)
        return dataclasses.replace(mesh, chip=chip, topology=topo)

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Calibration":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def cfg_cache_key(cfg: ModelConfig) -> str:
    """The executed-config component of a calibration-cache key.  The name
    alone is not enough: a reduced family member shares its name with the
    full config but measures a very different wall clock."""
    return f"{cfg.name}@d{cfg.d_model}L{cfg.num_layers}"


def fit_calibration(cfg: ModelConfig, *, batch: int, seq: int,
                    measured: Dict[str, Any], micro: Dict[str, float],
                    backend: str, cluster_name: str,
                    remat: str = "none") -> Calibration:
    """Distill measurements into a :class:`Calibration`.

    The FLOP/s fit divides the step-time model's FLOP count for the
    *executed* config/shape by the best measured compute-phase time; the
    link fit divides the SyncReport's per-worker wire bytes by the measured
    sync-phase time."""
    exec_shape = ShapeConfig("tune-exec", seq, batch, "train")
    dp = max(int(measured.get("dp") or 0), 1)
    flops_step = train_flops_per_step(cfg, exec_shape, remat) / dp
    t_comp = measured.get("best_compute_s") or measured.get("mean_compute_s")
    achieved = flops_step / t_comp if t_comp else 0.0
    # the trainer's feedback path: SyncReport.effective_link_bw is the
    # measured bytes/s the sync phase delivered (0.0 when nothing moved)
    sync = measured.get("sync") or {}
    link_bw = float(sync.get("effective_link_bw") or 0.0)
    return Calibration(
        backend=backend, cluster=cluster_name, arch=cfg_cache_key(cfg),
        achieved_flops=achieved,
        matmul_flops=micro.get("matmul_flops", 0.0),
        hbm_bw=micro.get("triad_bw", 0.0),
        link_bw=link_bw,
        measured={"best_compute_s": float(t_comp or 0.0),
                  "best_step_s": float(measured.get("best_step_s") or 0.0),
                  "flops_per_step": float(flops_step),
                  "batch": float(batch), "seq": float(seq), "dp": float(dp)},
        created=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )


# -- JSON cache (keyed by backend/cluster/executed-config) ----------------------------------


def load_cache(path) -> Dict[str, Dict[str, Any]]:
    p = Path(path)
    if not p.exists():
        return {}
    try:
        d = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return {}
    if d.get("schema") != CACHE_SCHEMA_ID:
        return {}
    return dict(d.get("calibrations", {}))


def cached_calibration(path, key: str) -> Optional[Calibration]:
    entry = load_cache(path).get(key)
    return Calibration.from_dict(entry) if entry else None


def save_calibration(path, cal: Calibration) -> Path:
    p = Path(path)
    cals = load_cache(p)
    cals[cal.key] = cal.to_dict()
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {"schema": CACHE_SCHEMA_ID, "calibrations": cals}, indent=2))
    return p


# ---------------------------------------------------------------------------
# The procedure end to end
# ---------------------------------------------------------------------------


@dataclass
class TuneResult:
    """Everything one autotune pass decided, measured, and re-planned."""

    backend: str
    cluster: str
    minibatch: Dict[str, Any]
    kernels: Dict[str, Any]
    conv_alg: Dict[str, Any]
    calibration: Calibration
    measured: Dict[str, Any]
    replan: Dict[str, Any]
    tuned_plan: Plan
    cache_path: str = ""
    # the measured comm/compute-overlap sweep (tune_overlap): bucket-size
    # candidates, the sweet spot, and the achieved overlap_fraction
    overlap: Dict[str, Any] = field(default_factory=dict)

    @property
    def chosen_minibatch(self) -> int:
        return int(self.minibatch["chosen"])

    @property
    def chosen_microbatch(self) -> int:
        return int(self.minibatch["microbatch"]["chosen"])

    def attn_impl(self) -> str:
        """The executable attention choice: ``dense`` when the jnp reference
        beat the pallas kernel on this backend, ``auto`` (flash) otherwise."""
        chosen = self.kernels.get("flash_attention", {}).get("chosen", "")
        return "dense" if chosen == "ref" else "auto"

    def ssd_chunk(self) -> Optional[int]:
        chosen = self.kernels.get("ssd_scan", {}).get("chosen", "")
        if chosen.startswith("pallas_chunk"):
            return int(chosen[len("pallas_chunk"):])
        return None

    def section(self) -> Dict[str, Any]:
        """The ``repro.api/tuning/v1`` section of a Report."""
        return {
            "schema": TUNING_SCHEMA_ID,
            "backend": self.backend,
            "cluster": self.cluster,
            "minibatch": self.minibatch,
            "kernels": self.kernels,
            "conv_alg": self.conv_alg,
            "calibration": self.calibration.to_dict(),
            "measured": self.measured,
            "replan": self.replan,
            "cache_path": self.cache_path,
            "overlap": self.overlap,
        }


def tune_minibatch(cfg_full: ModelConfig, shape: ShapeConfig,
                   mesh: MeshSpec, base_plan: Plan) -> Dict[str, Any]:
    """The paper's minibatch procedure, both forms:

    - CNN (Eq. 5): the largest ``X_mini`` with ``m_bound >= 0`` on this
      chip's memory — ``chosen`` is exactly that binary-search result.
    - Transformer: the largest per-replica microbatch whose
      ``train_memory`` total fits, under the plan's algorithm choices.
    """
    hbm = mesh.chip.hbm_bytes
    x_star = mm.max_x_mini(mm.ALEXNET, hbm)
    mb_star = mm.max_microbatch(
        cfg_full, shape, dp=mesh.dp, tp=mesh.tp, fsdp=base_plan.fsdp,
        attn_impl=base_plan.attn_impl, remat=base_plan.remat,
        seq_parallel=base_plan.seq_parallel, hbm_bytes=hbm,
        opt_kind=base_plan.opt_kind)
    return {
        "chosen": x_star,
        "bound": "m_bound",
        "search": "binary",
        "m_gpu_bytes": hbm,
        "m_bound_at_chosen": mm.m_bound(mm.ALEXNET, max(x_star, 1), hbm),
        "m_bound_at_next": mm.m_bound(mm.ALEXNET, x_star + 1, hbm),
        "microbatch": {
            "chosen": mb_star,
            "bound": "train_memory",
            "b_rep": max(shape.global_batch // mesh.dp, 1),
            "plan_microbatch": base_plan.microbatch,
            "attn_impl": base_plan.attn_impl,
            "remat": base_plan.remat,
        },
    }


def autotune(cfg_exec: ModelConfig, cfg_full: ModelConfig,
             shape: ShapeConfig, mesh: MeshSpec, *,
             batch: int, seq: int, steps: int = 3, dp: int = 0,
             seed: int = 0, cache_path: str = "", use_cache: bool = True,
             bench_seq: int = 128, repeats: int = 2,
             overlap_bucket_mbs: Tuple[float, ...] = DEFAULT_OVERLAP_BUCKET_MBS,
             tracer: Optional[Tracer] = None,
             metrics: Optional[MetricsRegistry] = None
             ) -> TuneResult:
    """Run the whole closed loop once and return the :class:`TuneResult`.

    ``cfg_exec`` is what actually executes (the reduced member on this
    container); ``cfg_full``/``shape``/``mesh`` name the production job the
    re-plan prices.  ``cache_path`` ("" = no persistence) is the JSON
    calibration cache; a cached entry for this backend/cluster/config skips the
    trainer measurement unless ``use_cache`` is False.  ``tracer``/``metrics``
    (repro.obs) record the pass: one span per stage (``bench_kernels`` /
    ``measure`` / ``tune_overlap`` / ``replan``) and the ``tune/*`` metric
    family the Session's ``metrics/v1`` section carries."""
    import jax

    if tracer is None:
        tracer = Tracer(enabled=True)
    if metrics is None:
        metrics = MetricsRegistry()
    backend = jax.default_backend()
    cluster = mesh.cluster
    cluster_name = cluster.name or f"flat{cluster.n_chips}"
    key = f"{backend}/{cluster_name}/{cfg_cache_key(cfg_exec)}"

    # 1) algorithm microbenchmarks
    with tracer.span("bench_kernels", seq=bench_seq) as sp_k:
        kernels = bench_kernels(seq=bench_seq, repeats=repeats)
        conv = choose_conv_algs(128, mesh.chip.hbm_bytes)  # Table 2's X_mini
    metrics.observe("tune/bench_kernels_s", sp_k.elapsed_s)
    for op, entry in kernels.items():
        for name, t in entry.get("times_s", {}).items():
            metrics.observe(f"tune/kernel/{op}/{name}_s", t)

    # 2) calibration: cached, or measured fresh
    cal = cached_calibration(cache_path, key) if (cache_path and use_cache) \
        else None
    measured: Dict[str, Any]
    overlap: Dict[str, Any] = {}
    metrics.set_gauge("tune/calibration_from_cache", float(cal is not None))
    if cal is not None:
        measured = {"from_cache": True, "cache_key": key,
                    **{k: v for k, v in cal.measured.items()}}
        if cal.bucket_mb > 0:  # the sweep ran (a measured 0.0 fraction counts)
            overlap = {"measured": True, "from_cache": True,
                       "chosen_bucket_mb": cal.bucket_mb,
                       "overlap_fraction": cal.overlap_fraction}
    else:
        with tracer.span("measure", steps=steps, dp=dp) as sp_m:
            measured = measure_train_steps(cfg_exec, batch=batch, seq=seq,
                                           steps=steps, dp=dp, seed=seed,
                                           topology=mesh.topology)
            micro = host_microbench()
        metrics.observe("tune/measure_s", sp_m.elapsed_s)
        cal = fit_calibration(cfg_exec, batch=batch, seq=seq,
                              measured=measured, micro=micro,
                              backend=backend, cluster_name=cluster_name)
        # achieved comm/compute overlap + bucket sweet spot, calibrated
        # like the effective link bandwidth (dp >= 2 only: overlap needs
        # a data axis to hide anything under)
        with tracer.span("tune_overlap", dp=dp) as sp_o:
            overlap = tune_overlap(cfg_exec, batch=batch, seq=seq, dp=dp,
                                   seed=seed, bucket_mbs=overlap_bucket_mbs,
                                   topology=mesh.topology)
        metrics.observe("tune/tune_overlap_s", sp_o.elapsed_s)
        if overlap.get("measured"):
            cal = replace(cal,
                          overlap_fraction=float(overlap["overlap_fraction"]),
                          bucket_mb=float(overlap["chosen_bucket_mb"]))
        if cache_path:
            save_calibration(cache_path, cal)
    metrics.set_gauge("tune/achieved_flops", cal.achieved_flops)
    metrics.set_gauge("tune/link_bw", cal.link_bw)
    if overlap.get("measured"):
        metrics.set_gauge("tune/overlap_fraction",
                          float(overlap.get("overlap_fraction", 0.0)))

    # 3) the paper's procedure on the production job + 4) re-plan on
    # measured constants
    with tracer.span("replan") as sp_r:
        base_plan = plan_fn(cfg_full, shape, mesh)
        minibatch = tune_minibatch(cfg_full, shape, mesh, base_plan)
        cal_mesh = cal.apply(mesh)
        tuned_plan = plan_fn(cfg_full, shape, cal_mesh)
    metrics.observe("tune/replan_s", sp_r.elapsed_s)

    # prediction check on the *executed* job: does the calibrated model land
    # nearer the wall clock than the datasheet one?  (With a cached
    # calibration the wall clock is the cached run's, so the check re-uses
    # that run's batch/seq/dp.)
    b_chk, s_chk, dp_chk = batch, seq, dp
    if measured.get("from_cache"):
        b_chk = int(cal.measured.get("batch") or batch)
        s_chk = int(cal.measured.get("seq") or seq)
        dp_chk = int(cal.measured.get("dp") or max(dp, 1))
    exec_shape = ShapeConfig("tune-exec", s_chk, b_chk, "train")
    n_dev = max(dp_chk, 1)
    exec_mesh = MeshSpec(chips=n_dev, dp=n_dev, tp=1, chip=mesh.chip)
    mb_exec = max(b_chk // n_dev, 1)
    uncal_t = estimate_step_time(cfg_exec, exec_shape, exec_mesh,
                                 "none", mb_exec)["total"]
    cal_t = estimate_step_time(cfg_exec, exec_shape, cal.apply(exec_mesh),
                               "none", mb_exec)["total"]
    meas_t = float(measured.get("best_step_s", 0.0) or 0.0)
    replan = {
        "measured_step_s": meas_t,
        "est_step_time_uncalibrated_s": uncal_t,
        "est_step_time_calibrated_s": cal_t,
        "abs_err_uncalibrated_s": abs(uncal_t - meas_t),
        "abs_err_calibrated_s": abs(cal_t - meas_t),
        "calibrated_closer": abs(cal_t - meas_t) <= abs(uncal_t - meas_t),
        "flops_efficiency": cal.flops_efficiency(mesh.chip),
        "production": {
            "uncalibrated": {
                "est_step_time": base_plan.est_step_time,
                "sync_schedule": base_plan.sync_schedule,
                "microbatch": base_plan.microbatch,
            },
            "calibrated": {
                "est_step_time": tuned_plan.est_step_time,
                "sync_schedule": tuned_plan.sync_schedule,
                "microbatch": tuned_plan.microbatch,
            },
        },
    }
    metrics.set_gauge("tune/measured_step_s", meas_t)
    metrics.set_gauge("tune/est_step_calibrated_s", cal_t)
    metrics.set_gauge("tune/est_step_uncalibrated_s", uncal_t)
    return TuneResult(
        backend=backend, cluster=cluster_name, minibatch=minibatch,
        kernels=kernels, conv_alg=conv, calibration=cal, measured=measured,
        replan=replan, tuned_plan=tuned_plan, cache_path=str(cache_path),
        overlap=overlap)
