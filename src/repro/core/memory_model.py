"""Memory models.

Part 1 — the paper's CNN model, implemented VERBATIM from Eqs. (1)-(5):
feature-map memory ``M_FM``, model parameters ``M_MP`` (gradients = 2x
params), classifier ``M_C``, and the budget
``M_bound = M_GPU - M_FM - M_MP - M_C``. Includes the AlexNet definition
and the GEMM/FFT per-layer memory models that reproduce Table 2.

Part 2 — the transformer generalization used by the planner: params, grads,
optimizer state, remat-dependent saved activations, logits, KV cache.
All byte counts are *totals*; the planner divides by the sharding degrees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.common import param_count

BITS = 32  # the paper assumes fp32 everywhere


# ---------------------------------------------------------------------------
# Part 1 — faithful CNN model (Eqs. 1-5)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayer:
    kind: str  # "conv" | "pool"
    f: int  # filter size F_i
    s: int  # stride S_i
    p: int  # padding P_i
    k: int  # num filters K_i (0 for pooling, per the paper's convention)


@dataclass(frozen=True)
class CNN:
    input_bhd: Tuple[int, int, int]  # (B_0, H_0, D_0)
    features: Tuple[ConvLayer, ...]
    fc: Tuple[int, ...]  # L_j neuron counts, incl. the first FC input? no —
    # L_j are the FC layer widths; the flattened feature size feeds L_1.


def feature_shapes(cnn: CNN) -> List[Tuple[int, int, int]]:
    """Apply Eq. (1) through the feature extractor; returns [(B_i,H_i,D_i)]."""
    shapes = [cnn.input_bhd]
    b, h, d = cnn.input_bhd
    for layer in cnn.features:
        b = (b - layer.f + 2 * layer.p) // layer.s + 1
        h = (h - layer.f + 2 * layer.p) // layer.s + 1
        d = layer.k if layer.kind == "conv" else d
        shapes.append((b, h, d))
    return shapes


def m_fm(cnn: CNN, x_mini: int) -> float:
    """Eq. (2): input + all feature maps, bits."""
    return sum(b * h * d * x_mini * BITS for b, h, d in feature_shapes(cnn))


def m_mp(cnn: CNN) -> float:
    """Eq. (3): conv weights+biases, x3 (params + 2x gradients), bits."""
    shapes = feature_shapes(cnn)
    total = 0.0
    for i, layer in enumerate(cnn.features):
        if layer.kind != "conv":
            continue
        d_in = shapes[i][2]
        total += layer.f * layer.f * d_in * layer.k * 3 * BITS  # weights
        total += layer.k * 3 * BITS  # biases
    return total


def m_c(cnn: CNN) -> float:
    """Eq. (4): classifier outputs + weights (+2x grads) + biases."""
    out_bits = sum(l * BITS for l in cnn.fc)
    w_bits = sum(
        cnn.fc[j] * cnn.fc[j + 1] * 3 * BITS for j in range(len(cnn.fc) - 1)
    )
    b_bits = (len(cnn.fc) - 1) * 3 * BITS
    return out_bits + w_bits + b_bits


def m_bound(cnn: CNN, x_mini: int, m_gpu_bytes: float) -> float:
    """Eq. (5), returned in BYTES.  Negative when ``x_mini`` is infeasible
    on a device with ``m_gpu_bytes`` of memory."""
    used_bits = m_fm(cnn, x_mini) + m_mp(cnn) + m_c(cnn)
    return m_gpu_bytes - used_bits / 8.0


def max_x_mini(cnn: CNN, m_gpu_bytes: float, *, x_max: int = 1 << 20) -> int:
    """The paper's minibatch procedure, step 1: the largest ``X_mini`` with
    ``M_bound >= 0`` (Eq. 5), found by binary search — ``m_fm`` is linear in
    ``X_mini`` so feasibility is monotone.  Returns 0 when not even
    ``X_mini = 1`` fits (the model alone exceeds device memory)."""
    if m_bound(cnn, 1, m_gpu_bytes) < 0:
        return 0
    lo, hi = 1, 2
    while hi <= x_max and m_bound(cnn, hi, m_gpu_bytes) >= 0:
        lo, hi = hi, hi * 2
    hi = min(hi, x_max)
    while lo + 1 < hi:  # invariant: lo feasible, hi infeasible (or > x_max)
        mid = (lo + hi) // 2
        if m_bound(cnn, mid, m_gpu_bytes) >= 0:
            lo = mid
        else:
            hi = mid
    if hi == x_max and m_bound(cnn, hi, m_gpu_bytes) >= 0:
        return x_max
    return lo


# AlexNet feature extractor (paper Table 2 parameters) + classifier
ALEXNET = CNN(
    input_bhd=(224, 224, 3),
    features=(
        ConvLayer("conv", 11, 4, 2, 96),    # -> 55x55x96
        ConvLayer("pool", 3, 2, 0, 0),      # -> 27x27x96
        ConvLayer("conv", 5, 1, 2, 256),    # -> 27x27x256
        ConvLayer("pool", 3, 2, 0, 0),      # -> 13x13x256
        ConvLayer("conv", 3, 1, 1, 384),    # -> 13x13x384
        ConvLayer("conv", 3, 1, 1, 384),    # -> 13x13x384
        ConvLayer("conv", 3, 1, 1, 256),    # -> 13x13x256
        ConvLayer("pool", 3, 2, 0, 0),      # -> 6x6x256
    ),
    fc=(9216, 4096, 4096, 1000),
)


def conv_alg_memory(x_mini: int, bi: int, hi: int, bo: int, ho: int,
                    d_in: int, d_out: int, f: int) -> Tuple[float, float]:
    """(GEMM_bytes, FFT_bytes) for one conv layer — the Table-2 model.

    GEMM (tiled/implicit cuDNN lowering): input + output + filters.
    FFT: everything lives at the *padded* input resolution (filters are
    padded to the input size; feature maps transformed in place).
    """
    by = BITS // 8
    gemm = (x_mini * d_in * bi * hi + x_mini * d_out * bo * ho
            + f * f * d_in * d_out) * by
    fft = (x_mini * d_in + x_mini * d_out + d_in * d_out) * bi * hi * by
    return gemm, fft


# Paper Table 2 rows: (X_mini, B_i, H_i, B_o, H_o, D_i, D_o, F) and ratio
TABLE2_ROWS = [
    ((128, 224, 224, 55, 55, 3, 96, 11), 11.6),
    ((128, 27, 27, 27, 27, 96, 256, 5), 1.6),
    ((128, 13, 13, 13, 13, 256, 384, 3), 2.3),
    ((128, 13, 13, 13, 13, 384, 384, 3), 2.7),
    ((128, 13, 13, 13, 13, 384, 256, 3), 2.3),
]


# ---------------------------------------------------------------------------
# Part 2 — transformer memory model (per-chip, given sharding degrees)
# ---------------------------------------------------------------------------


@dataclass
class TransformerMemory:
    params: float
    grads: float
    opt_state: float
    activations: float
    logits: float
    kv_cache: float

    @property
    def total(self) -> float:
        return (self.params + self.grads + self.opt_state + self.activations
                + self.logits + self.kv_cache)


def n_params(cfg: ModelConfig) -> int:
    return param_count(M.model_specs(cfg))


def n_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared of the routed experts)."""
    total = n_params(cfg)
    if not cfg.has_moe:
        return total
    # routed expert params across the stack
    moe_layers = sum(
        1 for s in cfg.pattern for _ in range(1)
        if s.mlp in ("moe", "moe_dense")
    ) * M.main_cycles(cfg)
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed = moe_layers * cfg.num_experts * per_expert
    active_routed = moe_layers * cfg.top_k * per_expert
    return total - routed + active_routed


def train_memory(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
                 fsdp: bool, microbatch: int, attn_impl: str,
                 remat: str, seq_parallel: bool,
                 opt_kind: str = "adamw", pipe: int = 1,
                 n_microbatch: int = 0) -> TransformerMemory:
    """Per-chip bytes for one training step.

    With ``pipe > 1`` the stack is cut into ``pipe`` contiguous stage
    groups: params/grads/opt shrink by ``pipe`` (each chip holds one
    stage), the per-microbatch activation slice is ``B_rep / m`` rows, and
    the 1F1B schedule keeps ``min(pipe - s, m)`` microbatches in flight on
    stage ``s`` — this returns the stage-0 worst case (the KC107 contract
    checks every stage via :func:`stage_activation_bytes`).  ``dp`` is the
    data-parallel degree only; pass ``world // (tp * pipe)`` for a fixed
    chip budget."""
    N = n_params(cfg)
    chips = dp * tp
    p_shard = chips if fsdp else tp
    pipe = max(int(pipe), 1)
    params = (2 * N / p_shard + 4 * N / chips) / pipe  # bf16 + fp32 master
    grads = 4 * N / p_shard / pipe
    opt_per = {"adamw": 8, "momentum": 4}[opt_kind]
    opt_state = opt_per * N / chips / pipe  # ZeRO-1: always fully sharded

    B_rep = max(shape.global_batch // dp, 1)
    if pipe > 1:
        m = max(int(n_microbatch) or pipe, pipe)
        mb = max((microbatch or B_rep) // m, 1)
        in_flight = min(pipe, m)  # stage 0 holds the most under 1F1B
    else:
        mb = microbatch or B_rep
        in_flight = 1
    S = shape.seq_len
    D = cfg.d_model
    seq_shard = tp if seq_parallel else 1

    n_saved = cfg.num_layers if remat == "block" else 4 * cfg.num_layers
    n_saved /= pipe  # each stage saves only its own layers' activations
    activations = n_saved * mb * S * D * 2 / seq_shard * in_flight
    # live working set inside one block (attention blocks, mlp ff transient)
    ff = max(cfg.d_ff, cfg.moe_d_ff)
    work = mb * S * max(ff // tp, D) * 2 * 4 / seq_shard
    if attn_impl == "dense":
        heads_shard = tp if (cfg.num_heads % tp == 0) else 1
        work += 4 * mb * (cfg.num_heads / heads_shard) * S * S / seq_shard
    activations += work

    logits = mb * S * cfg.padded_vocab * 4 * 2 / tp / seq_shard  # f32 + grad
    return TransformerMemory(params, grads, opt_state, activations, logits, 0.0)


def stage_activation_bytes(cfg: ModelConfig, shape: ShapeConfig, *, dp: int,
                           tp: int, pipe: int, n_microbatch: int, stage: int,
                           stage_cycles: int, attn_impl: str, remat: str,
                           seq_parallel: bool) -> float:
    """Per-chip activation working set of pipeline stage ``stage`` under
    1F1B — the Eq.-5 feasibility term the KC107 contract prices: saved
    activations for the stage's ``stage_cycles`` layer cycles times its
    in-flight microbatch count ``min(pipe - stage, m)``, plus one live
    block working set, plus the logits buffer on the last stage."""
    pipe = max(int(pipe), 1)
    m = max(int(n_microbatch) or pipe, pipe)
    if not 0 <= stage < pipe:
        raise ValueError(f"stage {stage} outside [0, {pipe})")
    B_rep = max(shape.global_batch // dp, 1)
    mb = max(B_rep // m, 1)
    S, D = shape.seq_len, cfg.d_model
    seq_shard = tp if seq_parallel else 1
    in_flight = min(pipe - stage, m)

    layers = stage_cycles * max(len(cfg.pattern), 1)
    n_saved = layers if remat == "block" else 4 * layers
    act = n_saved * mb * S * D * 2 / seq_shard * in_flight
    ff = max(cfg.d_ff, cfg.moe_d_ff)
    act += mb * S * max(ff // tp, D) * 2 * 4 / seq_shard
    if attn_impl == "dense":
        heads_shard = tp if (cfg.num_heads % tp == 0) else 1
        act += 4 * mb * (cfg.num_heads / heads_shard) * S * S / seq_shard
    if stage == pipe - 1:
        act += mb * S * cfg.padded_vocab * 4 * 2 / tp / seq_shard
    return act


def max_microbatch(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
                   fsdp: bool, attn_impl: str, remat: str,
                   seq_parallel: bool, hbm_bytes: float,
                   opt_kind: str = "adamw", frac: float = 0.9) -> int:
    """The paper's minibatch procedure on the transformer memory model: the
    largest microbatch in ``[1, B/dp]`` whose :func:`train_memory` total
    stays under ``frac * hbm_bytes`` — activations/logits are linear in the
    microbatch, so feasibility is monotone and binary search applies.
    Returns 0 when even microbatch 1 does not fit."""
    budget = frac * hbm_bytes

    def fits(mb: int) -> bool:
        mem = train_memory(cfg, shape, dp=dp, tp=tp, fsdp=fsdp,
                           microbatch=mb, attn_impl=attn_impl, remat=remat,
                           seq_parallel=seq_parallel, opt_kind=opt_kind)
        return mem.total <= budget

    b_rep = max(shape.global_batch // dp, 1)
    if not fits(1):
        return 0
    lo, hi = 1, b_rep
    if fits(hi):
        return hi
    while lo + 1 < hi:  # invariant: lo fits, hi does not
        mid = (lo + hi) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid
    return lo


def decode_memory(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
                  fsdp: bool, window_override: int = 0) -> TransformerMemory:
    """Per-chip bytes for one decode step with a full cache."""
    N = n_params(cfg)
    chips = dp * tp
    params = 2 * N / (chips if fsdp else tp)
    B, S = shape.global_batch, shape.seq_len
    batch_shard = min(B, dp)
    seq_shard = tp * (dp if B < dp else 1)

    kv = 0.0
    cycles = M.main_cycles(cfg)
    for s in cfg.pattern:
        if s.mixer == "mamba":
            kv += cycles * B * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 2
            kv += cycles * B * (cfg.ssm_conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * 2
            continue
        win = cfg.sliding_window if s.mixer == "swa" else (window_override or 0)
        s_eff = min(S, win) if win else S
        kv += cycles * B * s_eff * cfg.kv_cache_width * 2
    # cache sharded over batch (dp, when it covers it) and seq (tp [+dp if B<dp])
    kv_per_chip = kv / (batch_shard * seq_shard)
    logits = B / batch_shard * cfg.padded_vocab * 4 / tp
    act = B / batch_shard * cfg.d_model * 2 * 8
    return TransformerMemory(params, 0.0, 0.0, act, logits, kv_per_chip)


# ---------------------------------------------------------------------------
# Part 3 — serving memory bound (Eq. 5 for the paged KV cache)
# ---------------------------------------------------------------------------
# Training sizes the minibatch as the largest x_mini with
# M(x_mini) <= M_bound (Eq. 5 / max_x_mini / max_microbatch).  Serving has
# the same shape: KV blocks are the unit of allocation, so the admission
# bound is the largest block count whose pool fits what is left of HBM
# after weights, per-request recurrent state, and decode workspace.


def kv_token_bytes(cfg: ModelConfig, *, dtype_bytes: int = 2) -> float:
    """Paged-cache bytes per cached token position across the stack
    (attention-like slots; a *paged* cache stores every position linearly,
    so sliding windows don't discount — the window bounds reads, not
    residency)."""
    cycles = M.main_cycles(cfg)
    per = 0.0
    for s in cfg.pattern:
        if s.mixer == "mamba":
            continue
        per += cycles * cfg.kv_cache_width * dtype_bytes
    if cfg.first_k_dense and cfg.pattern[0].mixer != "mamba":
        per += cfg.first_k_dense * cfg.kv_cache_width * dtype_bytes
    return per


def request_state_bytes(cfg: ModelConfig, *, dtype_bytes: int = 2) -> float:
    """Per-request bytes that are NOT paged: Mamba recurrent state and conv
    tail are constant-size per sequence, resident for the whole request."""
    cycles = M.main_cycles(cfg)
    per = 0.0
    for s in cfg.pattern:
        if s.mixer != "mamba":
            continue
        per += cycles * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * dtype_bytes
        per += cycles * (cfg.ssm_conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * dtype_bytes
    return per


def kv_block_bytes(cfg: ModelConfig, block_size: int) -> float:
    """Bytes of one KV block across every paged pool."""
    return block_size * kv_token_bytes(cfg)


def max_kv_blocks(cfg: ModelConfig, hbm_bytes: float, *, block_size: int,
                  max_batch: int = 1, frac: float = 0.9) -> int:
    """Eq. 5 for serving: the largest KV block-pool size that fits.

        n_blocks = floor((frac·HBM − M_params − M_state − M_work) / M_block)

    with bf16 weights resident, ``max_batch`` requests of recurrent state,
    and a decode workspace (f32 logits row + activation slack) per row.
    Returns 0 when even the fixed costs exceed the budget or the config has
    no paged (attention) cache at all.
    """
    bb = kv_block_bytes(cfg, block_size)
    if bb <= 0:
        return 0
    params = 2.0 * n_params(cfg)
    state = max_batch * request_state_bytes(cfg)
    work = max_batch * (cfg.padded_vocab * 4.0 + cfg.d_model * 2.0 * 8)
    bound = frac * hbm_bytes - params - state - work
    return max(int(bound // bb), 0)
