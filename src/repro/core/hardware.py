"""Hardware constants.

TPU v5e-class chip (the reproduction target, per the brief) and the paper's
2017 evaluation hardware (AWS P2 / NVIDIA K80) used by the faithful
benchmark reproductions.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float  # FLOP/s at the training dtype
    hbm_bytes: float
    hbm_bw: float  # bytes/s
    link_bw: float  # bytes/s per ICI/interconnect link
    vmem_bytes: float = 0.0


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,  # bf16
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    link_bw=50e9,
    vmem_bytes=128 * 2**20,
)

# Paper-era: NVIDIA GK210 (one half of a K80), AWS P2 instances (Table 1)
K80_GK210 = Chip(
    name="k80-gk210",
    peak_flops=2.91e12,  # fp32 with boost off ~2.9 TFLOP/s
    hbm_bytes=12 * 2**30,
    hbm_bw=240e9,
    link_bw=10e9 / 8,  # 10 Gbit Ethernet (p2.8xlarge "network" as PS link)
)


@dataclass(frozen=True)
class MeshSpec:
    """Mesh geometry + per-axis bandwidth used by the planner."""

    chips: int
    dp: int  # data-parallel degree (pod*data)
    tp: int  # model-parallel degree
    chip: Chip = TPU_V5E
    dcn_bw: float = 25e9  # inter-pod (pod axis) bytes/s per chip

    @property
    def total_flops(self) -> float:
        return self.chips * self.chip.peak_flops

    @property
    def total_hbm(self) -> float:
        return self.chips * self.chip.hbm_bytes


SINGLE_POD = MeshSpec(chips=256, dp=16, tp=16)
MULTI_POD = MeshSpec(chips=512, dp=32, tp=16)
