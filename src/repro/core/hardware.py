"""Hardware constants and cluster topology — the paper's symbol table.

Every symbol of §3 that names a hardware quantity reads off one of these
classes (units noted per field; see ``docs/paper_map.md`` for the full
equation-to-module map):

- ``Chip.hbm_bytes``  -> Eq. (5)'s device memory ``M_GPU``        [bytes]
- ``Chip.peak_flops`` -> the ``T_C`` denominator in the step-time
  roofline (``planner.estimate_step_time``)                       [FLOP/s]
- ``Tier.bw``         -> Lemma 3.2's server bandwidth ``B_ps`` and the
  collective wire bandwidth, per interconnect tier                [bytes/s]
- ``Tier.latency``    -> the per-phase constant added to each collective
  hop at that tier                                                [s]

Two layers:

1. :class:`Chip` — the accelerator itself (TPU v5e-class reproduction
   target, plus the paper's 2017 evaluation hardware, AWS P2 / NVIDIA K80).
   Datasheet constants; :meth:`Chip.scaled` produces the *calibrated*
   overlay (``repro.core.autotune`` replaces peak FLOP/s and HBM bandwidth
   with measured ones, marking the chip name with ``+cal``).
2. :class:`ClusterSpec` — *where the chips sit*: a hierarchy of
   :class:`Tier` levels (chip -> node -> cluster), each with its own
   bandwidth/latency and fan-out.  The paper's guidelines (how many GPUs,
   how many parameter servers, which sync algorithm) are priced against a
   heterogeneous interconnect — PCIe/NVLink inside a node vs Ethernet/IB
   across nodes — and FireCaffe-style reduction trees only pay off when the
   cost model can see that hierarchy.  Every planner/collective consumer
   reads bandwidths through a ``ClusterSpec`` now; the old scalar
   ``chip.link_bw`` survives only as the bandwidth of a single-tier
   ("flat") cluster.

:class:`MeshSpec` keeps the logical mesh geometry (dp x tp) and gains an
optional ``topology``; omitting it yields a flat single-tier cluster
equivalent to the old behaviour.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float  # FLOP/s at the training dtype
    hbm_bytes: float
    hbm_bw: float  # bytes/s
    link_bw: float  # bytes/s per ICI/interconnect link
    vmem_bytes: float = 0.0

    CAL_SUFFIX = "+cal"

    def scaled(self, *, peak_flops: Optional[float] = None,
               hbm_bw: Optional[float] = None,
               link_bw: Optional[float] = None) -> "Chip":
        """A *calibrated* overlay of this chip: same identity, datasheet
        constants replaced by measured ones (``repro.core.autotune``).
        The name gains a ``+cal`` marker so plans priced on measurements
        are distinguishable from datasheet plans."""
        name = (self.name if self.name.endswith(self.CAL_SUFFIX)
                else self.name + self.CAL_SUFFIX)
        return replace(
            self, name=name,
            peak_flops=peak_flops if peak_flops else self.peak_flops,
            hbm_bw=hbm_bw if hbm_bw else self.hbm_bw,
            link_bw=link_bw if link_bw else self.link_bw)

    @property
    def calibrated(self) -> bool:
        return self.name.endswith(self.CAL_SUFFIX)


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,  # bf16
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    link_bw=50e9,
    vmem_bytes=128 * 2**20,
)

# Paper-era: NVIDIA GK210 (one half of a K80), AWS P2 instances (Table 1)
K80_GK210 = Chip(
    name="k80-gk210",
    peak_flops=2.91e12,  # fp32 with boost off ~2.9 TFLOP/s
    hbm_bytes=12 * 2**30,
    hbm_bw=240e9,
    link_bw=10e9 / 8,  # 10 Gbit Ethernet (p2.8xlarge "network" as PS link)
)


# ---------------------------------------------------------------------------
# Topology: tiers of the interconnect hierarchy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tier:
    """One level of the interconnect hierarchy.

    ``size`` is the fan-out at this level: the innermost tier groups
    ``size`` chips into a node; the next tier groups ``size`` nodes, and so
    on.  ``bw`` is bytes/s available to each chip for traffic crossing
    *this* tier's links (ICI/NVLink in-node, Ethernet/IB/DCN across).
    """

    name: str
    size: int
    bw: float  # bytes/s per chip across this tier's links
    latency: float = 0.0  # seconds per collective phase at this tier

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"tier {self.name!r}: size must be >= 1")
        if self.bw <= 0:
            raise ValueError(f"tier {self.name!r}: bw must be > 0")
        if self.latency < 0:
            raise ValueError(f"tier {self.name!r}: latency must be >= 0")


@dataclass(frozen=True)
class ClusterSpec:
    """A hierarchy of tiers, innermost first (chip -> node -> cluster).

    ``tiers[0]`` groups chips, ``tiers[1]`` groups the resulting nodes, ...
    The total chip count is the product of the tier sizes.
    """

    name: str
    chip: Chip = TPU_V5E
    tiers: Tuple[Tier, ...] = (Tier("pod", 1, TPU_V5E.link_bw),)

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("ClusterSpec needs at least one tier")
        object.__setattr__(self, "tiers", tuple(self.tiers))

    # -- geometry ----------------------------------------------------------
    @property
    def n_chips(self) -> int:
        return math.prod(t.size for t in self.tiers)

    @property
    def tier_sizes(self) -> Tuple[int, ...]:
        return tuple(t.size for t in self.tiers)

    @property
    def tier_bws(self) -> Tuple[float, ...]:
        return tuple(t.bw for t in self.tiers)

    @property
    def uniform(self) -> bool:
        """True when there is no bandwidth hierarchy to exploit: at most
        one tier actually spans more than one group (the flat-mesh case)."""
        return sum(1 for t in self.tiers if t.size > 1) <= 1

    @property
    def min_bw(self) -> float:
        """Bandwidth of the narrowest *spanning* tier (size > 1); this is
        what a flat (topology-blind) collective is priced at."""
        spanning = [t.bw for t in self.tiers if t.size > 1]
        return min(spanning) if spanning else self.tiers[0].bw

    @property
    def bottleneck_tier(self) -> str:
        spanning = [t for t in self.tiers if t.size > 1] or list(self.tiers)
        return min(spanning, key=lambda t: t.bw).name

    def tier(self, name: str) -> Tier:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"no tier {name!r} in cluster {self.name!r}; "
                       f"tiers: {[t.name for t in self.tiers]}")

    def dp_view(self, dp: int, tp: int) -> Tuple[Tier, ...]:
        """The tiers as seen by the data axis when ``tp`` model-parallel
        ranks are packed into the innermost tiers first (the standard
        placement: TP wants the fastest links).  Consumes ``tp`` from the
        inside out and returns the residual per-tier dp fan-out."""
        if dp * tp != self.n_chips:
            raise ValueError(f"dp*tp = {dp * tp} != n_chips = {self.n_chips} "
                             f"for cluster {self.name!r}")
        out: List[Tier] = []
        rem_tp = tp
        for t in self.tiers:
            take = math.gcd(t.size, rem_tp)
            rem_tp //= take
            out.append(replace(t, size=t.size // take))
        if rem_tp != 1:  # tp does not factor along tiers: flat fallback
            return (Tier(self.bottleneck_tier, dp, self.min_bw),)
        return tuple(out)

    # -- serialization (Plan carries this instead of a scalar link_bw) -----
    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "chip": self.chip.name,
            "tiers": [{"name": t.name, "size": t.size, "bw": t.bw,
                       "latency": t.latency} for t in self.tiers],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ClusterSpec":
        chips = {c.name: c for c in (TPU_V5E, K80_GK210)}
        chip_name = d.get("chip", TPU_V5E.name)
        # calibrated overlays serialize as "<chip>+cal"; the measured
        # constants live in the tier bandwidths / the calibration cache, so
        # deserialization falls back to the datasheet base chip
        if chip_name.endswith(Chip.CAL_SUFFIX):
            chip_name = chip_name[:-len(Chip.CAL_SUFFIX)]
        if chip_name not in chips:
            raise KeyError(f"unknown chip {chip_name!r} in serialized "
                           f"cluster {d.get('name')!r}; known: {sorted(chips)}")
        return cls(
            name=d["name"],
            chip=chips[chip_name],
            tiers=tuple(Tier(t["name"], int(t["size"]), float(t["bw"]),
                             float(t.get("latency", 0.0)))
                        for t in d["tiers"]),
        )

    @classmethod
    def flat(cls, chips: int, bw: Optional[float] = None, *,
             chip: Chip = TPU_V5E, name: str = "") -> "ClusterSpec":
        """Single-tier cluster — exactly the pre-topology mesh model."""
        return cls(name=name or f"flat{chips}", chip=chip,
                   tiers=(Tier("pod", chips, bw or chip.link_bw),))


@dataclass(frozen=True)
class MeshSpec:
    """Mesh geometry (dp x tp) + the cluster topology it maps onto."""

    chips: int
    dp: int  # data-parallel degree (pod*data)
    tp: int  # model-parallel degree
    chip: Chip = TPU_V5E
    topology: Optional[ClusterSpec] = None  # None => flat single tier
    # (inter-pod DCN bandwidth lives on the topology's tier now — see
    # MULTI_POD's "dcn" tier — not on a scalar mesh field)

    @property
    def total_flops(self) -> float:
        return self.chips * self.chip.peak_flops

    @property
    def total_hbm(self) -> float:
        return self.chips * self.chip.hbm_bytes

    @property
    def cluster(self) -> ClusterSpec:
        """The topology — or its flat single-tier equivalent when omitted
        (backward compatibility with the scalar-``link_bw`` model)."""
        if self.topology is not None:
            return self.topology
        return ClusterSpec.flat(self.chips, self.chip.link_bw, chip=self.chip)

    @classmethod
    def from_cluster(cls, cluster: ClusterSpec, *, tp: int = 1) -> "MeshSpec":
        n = cluster.n_chips
        if n % tp:
            raise ValueError(f"tp={tp} does not divide {n} chips")
        return cls(chips=n, dp=n // tp, tp=tp, chip=cluster.chip,
                   topology=cluster)


SINGLE_POD = MeshSpec(chips=256, dp=16, tp=16)
MULTI_POD = MeshSpec(
    chips=512, dp=32, tp=16,
    topology=ClusterSpec(
        "2pod-dcn", TPU_V5E,
        (Tier("pod", 256, TPU_V5E.link_bw), Tier("dcn", 2, 25e9))))


# ---------------------------------------------------------------------------
# Named clusters (JobSpec.topology / Session.sweep address these by name)
# ---------------------------------------------------------------------------

CLUSTERS: Dict[str, ClusterSpec] = {
    # flat N-chip meshes: the pre-topology behaviour, spelled explicitly
    "flat8": ClusterSpec.flat(8, name="flat8"),
    "flat16": ClusterSpec.flat(16, name="flat16"),
    # 2 nodes x 4 chips: fast ICI in-node, 20 Gbit/s-class Ethernet across —
    # the acceptance-criteria topology where hierarchy starts to matter
    "2x4": ClusterSpec("2x4", TPU_V5E,
                       (Tier("node", 4, TPU_V5E.link_bw),
                        Tier("cluster", 2, 2.5e9))),
    # 4 nodes x 4 chips over 100 Gbit InfiniBand-class links
    "4x4-ib": ClusterSpec("4x4-ib", TPU_V5E,
                          (Tier("node", 4, TPU_V5E.link_bw),
                           Tier("cluster", 4, 12.5e9))),
    # paper-era: 2 x p2.8xlarge (8 GK210s behind PCIe, 10 GbE between)
    "p2-2x8": ClusterSpec("p2-2x8", K80_GK210,
                          (Tier("node", 8, 10e9),
                           Tier("cluster", 2, 10e9 / 8))),
    # the default pods, addressable by name for sweeps
    "pod": ClusterSpec.flat(256, name="pod"),
    "2pod-dcn": MULTI_POD.topology,
}


def get_cluster(name: str) -> ClusterSpec:
    try:
        return CLUSTERS[name]
    except KeyError:
        raise KeyError(f"unknown cluster {name!r}; known: "
                       f"{sorted(CLUSTERS)}") from None
