"""Lemma 3.1 — multi-accelerator efficiency under Amdahl's law.

    alpha(G, R_O) = (1 + R_O) / (1 + G * R_O)

where R_O = T_O / T_C is the ratio of non-hidden overhead to computation.
Also the inverse forms the paper uses operationally: the G needed for a
target speedup, and the R_O budget admissible for a target efficiency.
"""
from __future__ import annotations

import math


def efficiency(g: int, r_o: float) -> float:
    """Lemma 3.1: efficiency alpha given G accelerators and overhead ratio."""
    if g < 1:
        raise ValueError("G >= 1")
    return (1.0 + r_o) / (1.0 + g * r_o)


def speedup(g: int, r_o: float) -> float:
    """alpha * G — the actual speedup factor (Fig. 4's estimated curve)."""
    return g * efficiency(g, r_o)


def max_overhead_for(g: int, alpha: float) -> float:
    """Eq. (12): R_O admissible for target efficiency alpha with G devices."""
    if not (0 < alpha <= 1):
        raise ValueError("alpha in (0, 1]")
    if g * alpha <= 1:
        return math.inf
    return (1.0 - alpha) / (alpha * g - 1.0)


def devices_for_speedup(target: float, r_o: float, g_max: int = 4096) -> int:
    """Smallest G achieving ``target``x speedup; paper's example: R_O=10%,
    3x target -> G=4. Returns g_max if saturation caps below target."""
    for g in range(1, g_max + 1):
        if speedup(g, r_o) >= target:
            return g
    return g_max


def speedup_saturation(r_o: float) -> float:
    """lim_{G->inf} speedup = (1 + R_O)/R_O — the Amdahl ceiling."""
    return math.inf if r_o == 0 else (1.0 + r_o) / r_o
