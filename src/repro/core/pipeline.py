"""The 7-step mini-batch pipeline (paper Fig. 1) — timing model + simulator.

Steps: (1) parameter refresh, (2) data loading, (3) data preparation,
(4) host->device transfer, (5) device compute, (6) parameter update,
(7) distributed update. Step 5 is compute T_C; the pipeline hides steps
2-4 behind step 5 of the previous batch (double buffering) and steps 6-7
behind the next step's early layers when the sync plan allows.

Used in three places: measuring R_O from real timings (train loop emits
per-step durations), simulating multi-device speedup for Fig. 4, and
feeding Lemma 3.1/3.2 in the planner.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

STEP_NAMES = (
    "param_refresh", "data_load", "data_prep", "h2d", "compute",
    "param_update", "dist_update",
)


@dataclass
class StepTimes:
    """Per-step durations (seconds) of one mini-batch round."""

    param_refresh: float = 0.0
    data_load: float = 0.0
    data_prep: float = 0.0
    h2d: float = 0.0
    compute: float = 0.0
    param_update: float = 0.0
    dist_update: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return {k: getattr(self, k) for k in STEP_NAMES}

    @property
    def t_c(self) -> float:
        return self.compute

    def overhead(self, *, pipelined: bool = True) -> float:
        """Non-hidden overhead T_O.

        Un-pipelined: every step serializes.  Pipelined: steps 2-4 prefetch
        behind the previous compute (hidden iff their sum <= T_C); steps 1,
        6, 7 serialize unless the distributed-update plan masks them.
        """
        io = self.data_load + self.data_prep + self.h2d
        sync = self.param_refresh + self.param_update + self.dist_update
        if not pipelined:
            return io + sync
        return max(io - self.compute, 0.0) + sync

    def r_o(self, *, pipelined: bool = True) -> float:
        """The paper's R_O = T_O / T_C."""
        return self.overhead(pipelined=pipelined) / max(self.compute, 1e-12)


def simulate_epoch(times: StepTimes, n_batches: int, *, pipelined: bool = True,
                   jitter: float = 0.0, seed: int = 0) -> float:
    """Wall-clock of n_batches rounds under the pipeline model. ``jitter``
    adds lognormal noise to each step (the paper notes real overheads are
    stochastic while the lemma treats R_O as constant)."""
    import random

    rng = random.Random(seed)

    def j(x: float) -> float:
        if jitter <= 0 or x == 0:
            return x
        return x * rng.lognormvariate(0.0, jitter)

    total = 0.0
    first_io = None
    for i in range(n_batches):
        io = j(times.data_load) + j(times.data_prep) + j(times.h2d)
        sync = j(times.param_refresh) + j(times.param_update) + j(times.dist_update)
        comp = j(times.compute)
        if not pipelined:
            total += io + comp + sync
            continue
        if first_io is None:
            first_io = io
            total += io  # pipeline warm-up: first batch's data is not hidden
        # double buffering: batch i+1's I/O overlaps batch i's compute;
        # sync steps serialize after compute (unless a SyncPlan masks them)
        total += max(io, comp) + sync
    return total


def multi_device_speedup(times: StepTimes, g: int, *, bus_shared: bool = True,
                         pipelined: bool = True) -> float:
    """Fig. 4 'actual' model: with G devices the compute splits G ways, but
    shared-bus steps (2-4) scale their demand by G, and parameter traffic
    (1, 6, 7) grows with G. Returns speedup vs G=1."""
    t1 = simulate_epoch(times, 64, pipelined=pipelined)
    scaled = StepTimes(
        param_refresh=times.param_refresh * (g if bus_shared else 1),
        data_load=times.data_load * g if bus_shared else times.data_load,
        data_prep=times.data_prep,  # CPU-bound, assume enough cores
        h2d=times.h2d * g if bus_shared else times.h2d,
        compute=times.compute,  # per-device batch kept constant (weak scaling)
        param_update=times.param_update * (g if bus_shared else 1),
        dist_update=times.dist_update,
    )
    tg = simulate_epoch(scaled, 64, pipelined=pipelined)
    # weak scaling: G devices process G batches in tg vs 1 batch in t1
    return g * t1 / tg if tg > 0 else float(g)
