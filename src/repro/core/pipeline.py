"""The 7-step mini-batch pipeline (paper Fig. 1) — timing model + simulator.

Steps: (1) parameter refresh, (2) data loading, (3) data preparation,
(4) host->device transfer, (5) device compute, (6) parameter update,
(7) distributed update. Step 5 is compute T_C; the pipeline hides steps
2-4 behind step 5 of the previous batch (double buffering) and steps 6-7
behind the next step's early layers when the sync plan allows.

Used in three places: measuring R_O from real timings (train loop emits
per-step durations), simulating multi-device speedup for Fig. 4, and
feeding Lemma 3.1/3.2 in the planner.

The second half of this module is the *pipeline-parallel* schedule model:
a non-interleaved 1F1B schedule over ``p`` stages and ``m`` microbatches,
its analytic bubble fraction ``(p-1)/(m+p-1)``, and an event-driven
simulator that replays measured per-op times through the schedule's
dependency DAG.  The executable counterpart lives in
``repro.distributed.pipeline.PipelineTrainer``, which feeds its traced
per-(stage, microbatch) span durations back into :func:`simulate_1f1b`
to reconcile measured bubble against the model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

STEP_NAMES = (
    "param_refresh", "data_load", "data_prep", "h2d", "compute",
    "param_update", "dist_update",
)


@dataclass
class StepTimes:
    """Per-step durations (seconds) of one mini-batch round."""

    param_refresh: float = 0.0
    data_load: float = 0.0
    data_prep: float = 0.0
    h2d: float = 0.0
    compute: float = 0.0
    param_update: float = 0.0
    dist_update: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return {k: getattr(self, k) for k in STEP_NAMES}

    @property
    def t_c(self) -> float:
        return self.compute

    def overhead(self, *, pipelined: bool = True) -> float:
        """Non-hidden overhead T_O.

        Un-pipelined: every step serializes.  Pipelined: steps 2-4 prefetch
        behind the previous compute (hidden iff their sum <= T_C); steps 1,
        6, 7 serialize unless the distributed-update plan masks them.
        """
        io = self.data_load + self.data_prep + self.h2d
        sync = self.param_refresh + self.param_update + self.dist_update
        if not pipelined:
            return io + sync
        return max(io - self.compute, 0.0) + sync

    def r_o(self, *, pipelined: bool = True) -> float:
        """The paper's R_O = T_O / T_C."""
        return self.overhead(pipelined=pipelined) / max(self.compute, 1e-12)


def simulate_epoch(times: StepTimes, n_batches: int, *, pipelined: bool = True,
                   jitter: float = 0.0, seed: int = 0) -> float:
    """Wall-clock of n_batches rounds under the pipeline model. ``jitter``
    adds lognormal noise to each step (the paper notes real overheads are
    stochastic while the lemma treats R_O as constant)."""
    import random

    rng = random.Random(seed)

    def j(x: float) -> float:
        if jitter <= 0 or x == 0:
            return x
        return x * rng.lognormvariate(0.0, jitter)

    total = 0.0
    first_io = None
    for i in range(n_batches):
        io = j(times.data_load) + j(times.data_prep) + j(times.h2d)
        sync = j(times.param_refresh) + j(times.param_update) + j(times.dist_update)
        comp = j(times.compute)
        if not pipelined:
            total += io + comp + sync
            continue
        if first_io is None:
            first_io = io
            total += io  # pipeline warm-up: first batch's data is not hidden
        # double buffering: batch i+1's I/O overlaps batch i's compute;
        # sync steps serialize after compute (unless a SyncPlan masks them)
        total += max(io, comp) + sync
    return total


def multi_device_speedup(times: StepTimes, g: int, *, bus_shared: bool = True,
                         pipelined: bool = True) -> float:
    """Fig. 4 'actual' model: with G devices the compute splits G ways, but
    shared-bus steps (2-4) scale their demand by G, and parameter traffic
    (1, 6, 7) grows with G. Returns speedup vs G=1."""
    t1 = simulate_epoch(times, 64, pipelined=pipelined)
    scaled = StepTimes(
        param_refresh=times.param_refresh * (g if bus_shared else 1),
        data_load=times.data_load * g if bus_shared else times.data_load,
        data_prep=times.data_prep,  # CPU-bound, assume enough cores
        h2d=times.h2d * g if bus_shared else times.h2d,
        compute=times.compute,  # per-device batch kept constant (weak scaling)
        param_update=times.param_update * (g if bus_shared else 1),
        dist_update=times.dist_update,
    )
    tg = simulate_epoch(scaled, 64, pipelined=pipelined)
    # weak scaling: G devices process G batches in tg vs 1 batch in t1
    return g * t1 / tg if tg > 0 else float(g)


# ---------------------------------------------------------------------------
# 1F1B pipeline-parallel schedule (Fig. 1 generalized to p stages)
# ---------------------------------------------------------------------------


def pipeline_bubble(p: int, m: int) -> float:
    """Analytic bubble fraction of the non-interleaved 1F1B schedule:
    ``(p-1)/(m+p-1)`` — the fill/drain idle share with ``p`` stages and
    ``m`` microbatches, exact when every stage's fwd (resp. bwd) takes the
    same time."""
    if p <= 1:
        return 0.0
    if m < 1:
        raise ValueError(f"n_microbatch must be >= 1, got {m}")
    return (p - 1) / (m + p - 1)


def balanced_stage_cut(n_cycles: int, p: int) -> Tuple[int, ...]:
    """Contiguous cut of ``n_cycles`` layer cycles into ``p`` stages:
    boundaries ``(0, c_1, ..., n_cycles)`` of length ``p + 1``, remainder
    cycles assigned to the earliest stages."""
    if not 1 <= p <= n_cycles:
        raise ValueError(f"need 1 <= pipe <= n_cycles, got pipe={p} "
                         f"over {n_cycles} cycles")
    base, rem = divmod(n_cycles, p)
    cuts = [0]
    for s in range(p):
        cuts.append(cuts[-1] + base + (1 if s < rem else 0))
    return tuple(cuts)


def stage_sequence_1f1b(p: int, m: int, s: int) -> List[Tuple[str, int]]:
    """Stage ``s``'s op order under non-interleaved 1F1B: ``p - 1 - s``
    warm-up forwards, a steady one-forward-one-backward phase, then the
    cool-down backwards.  Microbatches complete in index order on every
    stage."""
    w = min(p - 1 - s, m)
    seq: List[Tuple[str, int]] = [("fwd", j) for j in range(w)]
    for j in range(m - w):
        seq.append(("fwd", w + j))
        seq.append(("bwd", j))
    seq += [("bwd", j) for j in range(m - w, m)]
    return seq


def schedule_1f1b(p: int, m: int) -> List[Tuple[int, str, int]]:
    """A deterministic topological execution order ``(stage, kind, micro)``
    of the 1F1B DAG — what a host-orchestrated runtime executes serially.

    Dependencies: ``fwd(s, j)`` needs ``fwd(s-1, j)``; ``bwd(s, j)`` needs
    ``bwd(s+1, j)`` and ``fwd(s, j)``; plus each stage runs its own ops in
    :func:`stage_sequence_1f1b` order."""
    seqs = [stage_sequence_1f1b(p, m, s) for s in range(p)]
    ptr = [0] * p
    done: set = set()
    order: List[Tuple[int, str, int]] = []
    total = sum(len(sq) for sq in seqs)
    while len(order) < total:
        progressed = False
        for s in range(p):
            if ptr[s] >= len(seqs[s]):
                continue
            kind, j = seqs[s][ptr[s]]
            if kind == "fwd":
                ready = s == 0 or (s - 1, "fwd", j) in done
            else:
                ready = ((s, "fwd", j) in done
                         and (s == p - 1 or (s + 1, "bwd", j) in done))
            if ready:
                order.append((s, kind, j))
                done.add((s, kind, j))
                ptr[s] += 1
                progressed = True
        if not progressed:  # pragma: no cover - the 1F1B DAG is deadlock-free
            raise RuntimeError("1F1B schedule deadlocked")
    return order


@dataclass
class PipelineSim:
    """Event-driven replay of per-op times through the 1F1B DAG."""

    makespan: float
    stage_busy: Tuple[float, ...]
    op_start: Dict[Tuple[int, str, int], float]
    op_finish: Dict[Tuple[int, str, int], float]

    @property
    def bubble_fraction(self) -> float:
        p = len(self.stage_busy)
        denom = p * self.makespan
        if denom <= 0:
            return 0.0
        return 1.0 - sum(self.stage_busy) / denom


def _op_time(times: Sequence[Sequence[float]], s: int, j: int) -> float:
    t = float(times[s][j])
    if t < 0:
        raise ValueError(f"negative op time {t} at stage {s} micro {j}")
    return t


def simulate_1f1b(fwd_times: Sequence[Sequence[float]],
                  bwd_times: Sequence[Sequence[float]]) -> PipelineSim:
    """Simulate the 1F1B schedule with per-op durations
    ``fwd_times[s][j]`` / ``bwd_times[s][j]`` (``p`` stages x ``m``
    microbatches).  Each op starts at max(stage free, deps finished);
    returns makespan, per-stage busy time, and the bubble fraction
    ``1 - sum(busy) / (p * makespan)``.

    With uniform ``f`` and ``b`` the makespan is ``(m+p-1)(f+b)`` and the
    bubble equals :func:`pipeline_bubble` exactly.
    """
    p = len(fwd_times)
    if p == 0 or len(bwd_times) != p:
        raise ValueError("fwd_times/bwd_times must have one row per stage")
    m = len(fwd_times[0])
    if any(len(row) != m for row in fwd_times) or \
            any(len(row) != m for row in bwd_times):
        raise ValueError("ragged microbatch rows")
    start: Dict[Tuple[int, str, int], float] = {}
    finish: Dict[Tuple[int, str, int], float] = {}
    avail = [0.0] * p
    busy = [0.0] * p
    for (s, kind, j) in schedule_1f1b(p, m):
        ready = 0.0
        if kind == "fwd":
            if s > 0:
                ready = finish[(s - 1, "fwd", j)]
            dur = _op_time(fwd_times, s, j)
        else:
            ready = finish[(s, "fwd", j)]
            if s < p - 1:
                ready = max(ready, finish[(s + 1, "bwd", j)])
            dur = _op_time(bwd_times, s, j)
        t0 = max(avail[s], ready)
        start[(s, kind, j)] = t0
        finish[(s, kind, j)] = t0 + dur
        avail[s] = t0 + dur
        busy[s] += dur
    return PipelineSim(makespan=max(avail), stage_busy=tuple(busy),
                       op_start=start, op_finish=finish)


def simulate_serial(fwd_times: Sequence[Sequence[float]],
                    bwd_times: Sequence[Sequence[float]]) -> PipelineSim:
    """The no-overlap reference schedule: one op at a time, each microbatch
    forwarded through every stage then backwarded — what a pipeline without
    microbatch interleaving costs.  Its bubble approaches ``1 - 1/p``; 1F1B
    must beat it (the fig4 ``--quick`` assertion)."""
    p, m = len(fwd_times), len(fwd_times[0])
    t = 0.0
    busy = [0.0] * p
    start: Dict[Tuple[int, str, int], float] = {}
    finish: Dict[Tuple[int, str, int], float] = {}
    for j in range(m):
        for s in list(range(p)) + list(range(p - 1, -1, -1)):
            kind = "fwd" if (s, "fwd", j) not in start else "bwd"
            dur = _op_time(fwd_times if kind == "fwd" else bwd_times, s, j)
            start[(s, kind, j)] = t
            t += dur
            finish[(s, kind, j)] = t
            busy[s] += dur
    return PipelineSim(makespan=t, stage_busy=tuple(busy),
                       op_start=start, op_finish=finish)
