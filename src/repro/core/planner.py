"""End-to-end configurator — the paper's methodology automated for the
assigned architectures on the TPU mesh.

Given (arch config, input shape, mesh spec) it:
  1. builds the memory model (M_bound analogue, §3.1.3),
  2. runs a branch-and-bound search (``repro.core.ilp.search_bnb``, the
     Eq.-6 machinery generalized) over the unified candidate grid —
     pipeline stages × microbatch count (the X_mini knob) × attention impl
     {dense, flash/chunked} × remat {save, recompute} — priced by the
     roofline under the HBM bound,
  3. estimates step time from a napkin roofline (compute/memory/collective,
     plus the 1F1B bubble and p2p terms when a pipeline cut is searched),
  4. applies Lemma 3.1 to report efficiency/speedup for the mesh size and
     Lemma 3.2 (TPU mapping) to pick the gradient-sync schedule,
  5. emits a Plan with every runtime knob the launcher needs.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import amdahl, memory_model as mm, ps
from repro.core.hardware import ClusterSpec, MeshSpec, SINGLE_POD, Tier
from repro.core.ilp import Dim, search_bnb
from repro.core.pipeline import balanced_stage_cut, pipeline_bubble
from repro.models import model as M


@dataclass
class Plan:
    arch: str
    shape: str
    mesh: Tuple[int, int]  # (dp, tp)
    fsdp: bool
    microbatch: int
    attn_impl: str
    remat: str
    seq_parallel: bool
    opt_kind: str
    sync_schedule: str
    est_step_time: float
    est_memory_gb: float
    fits: bool
    efficiency: float
    grad_bytes: float = 0.0  # S_p: fp32 grad payload per TP shard
    # serialized ClusterSpec (tiers with bandwidths) the plan was priced on;
    # replaces the old scalar `link_bw` field
    topology: Optional[Dict] = None
    bottleneck_tier: str = ""  # slowest spanning tier for the sync schedule
    # True when the mesh carried measured (autotune-calibrated) constants
    # instead of datasheet numbers — see repro.core.autotune.Calibration
    calibrated: bool = False
    # bucketed comm/compute overlap (repro.distributed.overlap): whether the
    # plan was priced with sync hidden under the backward pass, the bucket
    # size target [MiB] (0 = the shared default), and — when a trainer or
    # test attached one — the serialized leaf-level BucketPlan dict
    sync_overlap: bool = False
    bucket_mb: float = 0.0
    bucket_plan: Optional[Dict] = None
    # pipeline parallelism (1F1B): stage count, microbatch count per step,
    # and the contiguous layer-cycle cut boundaries (len pipe + 1).  Legacy
    # plan dicts predate these fields and migrate to the defaults (no
    # pipelining) through from_dict's known-field filter.
    pipe: int = 1
    n_microbatch: int = 1
    stage_cut: Optional[List[int]] = None
    # bounded-staleness async PS (repro.distributed.async_ps): max worker
    # params age in steps (0 = synchronous) and slowest-k gradient drops
    # per step.  Legacy plan dicts migrate to the synchronous defaults
    # through from_dict's known-field filter.
    staleness: int = 0
    backup_workers: int = 0
    notes: List[str] = field(default_factory=list)

    def run_config_kwargs(self) -> Dict:
        return dict(attn_impl=self.attn_impl, remat=self.remat,
                    microbatch=self.microbatch)

    def to_job_kwargs(self) -> Dict:
        """Every runtime knob a Session/launcher adopts from this plan:
        the RunConfig knobs plus optimizer kind, the sync schedule, the
        overlap knobs, and the pipeline shape."""
        return dict(self.run_config_kwargs(), opt_kind=self.opt_kind,
                    sync=self.sync_schedule, sync_overlap=self.sync_overlap,
                    bucket_mb=self.bucket_mb, pipe=self.pipe,
                    n_microbatch=self.n_microbatch, staleness=self.staleness,
                    backup_workers=self.backup_workers)

    # -- topology view -----------------------------------------------------
    @property
    def cluster(self) -> Optional[ClusterSpec]:
        return ClusterSpec.from_dict(self.topology) if self.topology else None

    @property
    def link_bw(self) -> float:
        """Bandwidth of the topology's narrowest spanning tier — what the
        flat (topology-blind) schedules are priced at.  Kept as a property
        for consumers of the pre-topology scalar field."""
        c = self.cluster
        return c.min_bw if c is not None else 0.0

    def dp_tiers(self) -> Tuple[Tier, ...]:
        """The data axis's per-tier fan-out (TP packed innermost)."""
        c = self.cluster
        dp = self.mesh[0]
        if c is None:
            return (Tier("flat", dp, 1.0),)
        try:
            return c.dp_view(dp, self.mesh[1])
        except ValueError:  # mesh geometry disagrees with the topology
            return (Tier(c.bottleneck_tier, dp, c.min_bw),)

    # -- round-trip serialization (benchmark artifacts carry the plan) -----
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict) -> "Plan":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        kw["mesh"] = tuple(kw["mesh"])
        kw["notes"] = list(kw.get("notes", []))
        # pre-topology plans carried a scalar link_bw: rebuild the
        # equivalent flat single-tier cluster so pricing still works
        if not kw.get("topology") and d.get("link_bw"):
            dp, tp = kw["mesh"]
            kw["topology"] = ClusterSpec.flat(
                dp * tp, float(d["link_bw"])).to_dict()
        return cls(**kw)

    @classmethod
    def from_json(cls, s: str) -> "Plan":
        return cls.from_dict(json.loads(s))

    def resolve_sync(self, *, link_bw: Optional[float] = None):
        """Resolve ``sync_schedule`` to a runnable strategy
        (:class:`repro.distributed.collectives.SyncStrategy`) instead of a
        string. For the parameter-server schedule the shard count comes from
        Lemma 3.2 (``ps.n_parameter_servers``) sized for this plan's mesh,
        payload, and estimated step time; for ``hier_all_reduce`` the tier
        fan-out comes from the plan's topology."""
        from repro.distributed.collectives import get_strategy

        if self.sync_schedule in ("-", ""):
            raise ValueError(f"plan for {self.arch}/{self.shape} has no "
                             "gradient sync (decode plan?)")
        if self.sync_schedule == "hier_all_reduce":
            sizes = tuple(t.size for t in self.dp_tiers())
            return get_strategy("hier_all_reduce", tiers=sizes)
        n_servers = None
        if self.sync_schedule == "parameter_server" and self.grad_bytes:
            dp = self.mesh[0]
            bw = link_bw or self.link_bw
            if bw <= 0:
                raise ValueError("resolve_sync: no link bandwidth on this "
                                 "Plan; pass link_bw=")
            t_c = self.est_step_time if math.isfinite(self.est_step_time) else 1.0
            n_servers = ps.n_parameter_servers(self.grad_bytes, dp, bw, t_c)
        return get_strategy(self.sync_schedule, n_servers=n_servers)


# ---------------------------------------------------------------------------
# Napkin step-time model
# ---------------------------------------------------------------------------


def train_flops_per_step(cfg: ModelConfig, shape: ShapeConfig, remat: str) -> float:
    """6*N_active*D (+ remat recompute ~2*N*D) + attention quadratic part."""
    tokens = shape.global_batch * shape.seq_len
    n_act = mm.n_active_params(cfg)
    mult = 8.0 if remat == "block" else 6.0
    base = mult * n_act * tokens
    # causal attention: 2 * 0.5 * S^2 * width, fwd+bwd(2x) [+remat fwd]
    attn = 0.0
    cycles = M.main_cycles(cfg)
    for s in cfg.pattern:
        if s.mixer == "mamba":
            attn += cycles * tokens * cfg.ssm_state * cfg.d_inner * 2 * 3
            continue
        win = cfg.sliding_window if s.mixer == "swa" else cfg.attn_window_override
        s_eff = min(shape.seq_len, win) if win else shape.seq_len
        width = cfg.num_heads * cfg.head_dim if not cfg.is_mla else (
            cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                             + cfg.v_head_dim))
        fwd = 2 * 0.5 * s_eff * tokens * width * 2  # qk + pv
        attn += cycles * fwd * (4.0 if remat == "block" else 3.0) / 2
    return base + attn


def _dp_tiers(mesh: MeshSpec) -> Tuple[Tier, ...]:
    """Data-axis tier view of the mesh's cluster, with a flat fallback when
    the logical dp x tp geometry does not factor along the topology."""
    c = mesh.cluster
    try:
        return c.dp_view(mesh.dp, mesh.tp)
    except ValueError:
        return (Tier(c.bottleneck_tier, mesh.dp, c.min_bw),)


def r_o_from_terms(terms: Dict[str, float]) -> float:
    """Lemma 3.1's overhead ratio R_O from the roofline terms — the one
    place the accounting lives (plan_train and Session._predicted both
    call it): only the *effective* (post-overlap) collective share counts
    as overhead on top of compute."""
    return (max(terms["collective_effective"] + terms["memory"]
                - terms["compute"], 0.0)
            / max(terms["compute"], 1e-9))


def grad_sync_time(s_p: float, dp_tiers: Tuple[Tier, ...]) -> Tuple[float, str]:
    """Cheapest gradient-sync comm time for a payload of ``s_p`` bytes per
    worker over the tiered data axis, and the winning schedule — one call
    into :func:`ps.grad_sync_plan` so the step-time model and the plan's
    stored ``sync_schedule`` share one selection rule.  (With nonzero
    per-tier latency the winner can still depend on the payload size; the
    plan's stored schedule — selected on the sync payload — is the
    authoritative one.)"""
    if not any(t.size > 1 for t in dp_tiers):
        return 0.0, "none"
    plan = ps.grad_sync_plan(s_p, dp_tiers, t_c=1.0)
    return plan.comm_time, plan.schedule


def estimate_step_time(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                       remat: str, microbatch: int, *,
                       sync_overlap: bool = False, bucket_mb: float = 0.0,
                       overlap_efficiency: float = 1.0,
                       pipe: int = 1,
                       n_microbatch: int = 0,
                       staleness: int = 0,
                       backup_workers: int = 0,
                       mean_delay: float = 0.0) -> Dict[str, float]:
    """Napkin roofline terms [s].  With ``sync_overlap`` the gradient-sync
    collective is priced through the bucketed-overlap model
    (:func:`repro.core.ps.overlap_exposed_comm`): only the comm that sticks
    out past the backward pass counts against the step.  ``collective``
    always reports the serial sum; ``collective_effective`` is what the
    ``total`` uses and degrades to ``collective`` exactly when
    ``sync_overlap`` is off (or the payload yields a single bucket).
    ``overlap_efficiency`` derates the hideable window to a *measured*
    overlap fraction (autotune calibration).

    With ``pipe > 1`` the mesh's data axis is split ``pipe x (dp/pipe)``:
    compute stretches by the 1F1B fill/drain factor ``(m+p-1)/m``
    (``pipeline_bubble``), each stage holds and syncs ``1/pipe`` of the
    params, per-stage param re-reads scale with the microbatch count, and
    a ``collective_p2p`` term prices the boundary activation transfers on
    the innermost tier.

    ``staleness``/``backup_workers`` price the bounded-staleness async-PS
    relaxation (``repro.core.ps.async_step_time``'s terms threaded into
    the roofline): the grad-sync pull amortizes over ``s + 1`` steps
    (traffic factor ``(1 + 1/(s+1))/2``), a ``straggler_wait`` term is
    added (order statistics at ``mean_delay``), and the ``total`` divides
    by :func:`ps.staleness_efficiency` so stale progress pays its
    statistical price.  The synchronous defaults leave every term exactly
    as before."""
    pipe = max(int(pipe), 1)
    m = max(int(n_microbatch) or pipe, pipe)
    dp_data = max(mesh.dp // pipe, 1)
    flops = train_flops_per_step(cfg, shape, remat) / mesh.chips
    t_compute = flops / mesh.chip.peak_flops
    bubble = pipeline_bubble(pipe, m)
    if pipe > 1:
        t_compute *= (m + pipe - 1) / m  # == 1 / (1 - bubble)
    # memory term: params read per microbatch pass + activations traffic
    n = mm.n_params(cfg)
    if pipe > 1:
        # each stage re-reads its 1/pipe param slice once per microbatch
        param_traffic = 2 * n / pipe / mesh.tp * 3 * m
    else:
        n_micro = max(shape.global_batch // mesh.dp, 1) // max(microbatch, 1)
        param_traffic = 2 * n / mesh.tp * 3 * max(n_micro, 1)
    act_traffic = 12 * shape.global_batch * shape.seq_len * cfg.d_model * 2 / mesh.chips
    t_mem = (param_traffic + act_traffic) / mesh.chip.hbm_bw
    # collectives, priced per topology tier: the fp32 grad sync rides the
    # data axis (flat ring at the bottleneck bw, or the hierarchical
    # schedule when the tree is cheaper); TP activation collectives stay on
    # the innermost (fastest) tier, where TP ranks are packed
    cluster = mesh.cluster
    tiers = _dp_tiers(mesh)
    grad_bytes = 4 * n / mesh.tp / pipe
    t_grad, _ = grad_sync_time(grad_bytes, tiers)
    # bounded-staleness relaxation: push every step, pull every s+1 steps
    t_wait = 0.0
    if staleness > 0 or backup_workers > 0:
        t_grad *= (1.0 + 1.0 / (staleness + 1)) / 2.0
        t_wait = ps.straggler_wait(dp_data, backup_workers, mean_delay)
    stat_eff = ps.staleness_efficiency(staleness)
    tp_wire = (4 * cfg.num_layers * shape.global_batch * shape.seq_len
               * cfg.d_model * 2 / mesh.chips)
    t_tp = tp_wire / cluster.tiers[0].bw
    # stage-boundary activation p2p: every microbatch ships its (rows x S
    # x D) bf16 slab forward and its cotangent back across each boundary
    t_p2p = 0.0
    if pipe > 1:
        rows = max(shape.global_batch // dp_data // m, 1)
        t_p2p = (2 * (pipe - 1) / pipe * m * rows * shape.seq_len
                 * cfg.d_model * 2 / cluster.tiers[0].bw)
    t_coll = t_grad + t_tp + t_p2p
    # overlap: the exposed share of the grad sync under the bucketed model
    t_grad_exposed, overlap_frac, n_buckets = t_grad, 0.0, 1
    if sync_overlap and t_grad > 0:
        n_buckets = ps.bucket_count(grad_bytes, bucket_mb)
        t_bwd = (1.0 - ps.FWD_FRACTION) * t_compute
        t_grad_exposed = ps.overlap_exposed_comm(
            t_grad, t_bwd, n_buckets, overlap_efficiency=overlap_efficiency)
        overlap_frac = (t_grad - t_grad_exposed) / t_grad
    t_coll_eff = t_grad_exposed + t_tp + t_p2p
    return {"compute": t_compute, "memory": t_mem, "collective": t_coll,
            "collective_grad": t_grad, "collective_tp": t_tp,
            "collective_p2p": t_p2p,
            "collective_grad_exposed": t_grad_exposed,
            "collective_effective": t_coll_eff,
            "overlap_fraction": overlap_frac,
            "overlap_n_buckets": float(n_buckets),
            "pipeline_bubble": bubble,
            "straggler_wait": t_wait,
            "staleness_efficiency": stat_eff,
            "total": (max(t_compute, t_mem, t_coll_eff) + t_wait) / stat_eff}


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def train_search_space(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec, *,
                       fsdp: bool, opt_kind: str,
                       sync_overlap: bool = False, bucket_mb: float = 0.0,
                       overlap_efficiency: float = 1.0,
                       pipe: Optional[int] = None, n_microbatch: int = 0,
                       staleness: Union[int, Tuple[int, ...], None] = None,
                       backup_workers: int = 0, mean_delay: float = 0.0
                       ) -> Tuple[List[Dim],
                                  Callable[[Dict], Tuple[float, float, bool]],
                                  Callable[[Dict], float]]:
    """The unified auto-parallel grid for one (arch, shape, mesh):
    ``(dims, evaluate, lower_bound)`` ready for
    :func:`repro.core.ilp.search_bnb` — and for
    :func:`repro.core.ilp.search_exhaustive`, the oracle the optimality
    tests compare against.

    Dimensions, in tie-break order: the joint ``pipe_m = (pipe,
    n_microbatch)`` candidates with the no-pipeline cell ``(1, 1)`` first,
    then the per-device microbatch rows, attention impl, and remat — the
    historical enumeration order, so strict-< keeps legacy picks stable.
    ``evaluate`` prices a cell with :func:`estimate_step_time` under the
    Eq.-5 memory bound (0.9 x HBM, via ``mm.train_memory``); non-canonical
    cells (microbatch not dividing the replica batch; an explicit row count
    alongside a pipeline cut, where ``m`` already fixes the rows) price as
    infeasible with infinite memory so they can never win the frugal pick.
    ``lower_bound`` is admissible: 0.98 x the compute-only roofline under
    the best unassigned remat, times the 1F1B stretch once a cut is fixed.

    Pass ``pipe``/``n_microbatch`` to clamp the grid to a user-forced
    pipeline shape (``launch/train.py --pipe/--microbatch``).

    ``staleness`` adds the bounded-staleness async-PS dimension: ``None``
    keeps the synchronous single candidate ``(0,)`` (legacy plans and
    goldens are byte-stable), an int clamps it, and a tuple lets the B&B
    trade pull amortization + straggler savings against the
    :func:`ps.staleness_efficiency` discount.  ``backup_workers`` /
    ``mean_delay`` price the slowest-k drop at every staleness
    candidate."""
    overlap_kw = dict(sync_overlap=sync_overlap, bucket_mb=bucket_mb,
                      overlap_efficiency=overlap_efficiency)
    hbm = mesh.chip.hbm_bytes
    b_rep = max(shape.global_batch // mesh.dp, 1)
    cycles = M.main_cycles(cfg)

    pipe_m: List[Tuple[int, int]] = []
    for p in ((1, 2, 4, 8) if pipe is None else (int(pipe),)):
        if p < 1 or mesh.dp % p or p > cycles:
            continue
        if p == 1:
            pipe_m.append((1, 1))
            continue
        b_data = max(shape.global_batch // (mesh.dp // p), 1)
        for m in ((n_microbatch,) if n_microbatch else (p, 2 * p, 4 * p)):
            if p <= m <= b_data and b_data % m == 0:
                pipe_m.append((p, m))
    if not pipe_m:
        raise ValueError(
            f"no valid (pipe, n_microbatch) candidates for pipe={pipe}, "
            f"n_microbatch={n_microbatch} on dp={mesh.dp} "
            f"({cycles} layer cycles)")

    if staleness is None:
        stale_cands: Tuple[int, ...] = (0,)
    elif isinstance(staleness, int):
        stale_cands = (int(staleness),)
    else:
        stale_cands = tuple(sorted(set(int(s) for s in staleness)))
    if any(s < 0 for s in stale_cands):
        raise ValueError(f"staleness candidates must be >= 0: {stale_cands}")

    dims = [Dim("pipe_m", tuple(pipe_m)),
            Dim("microbatch", (1, 2, 4, 8, 16, 32)),
            Dim("attn_impl", ("dense", "chunked")),
            Dim("remat", ("block", "none")),
            Dim("staleness", stale_cands)]

    def stage_rows(p: int, m: int) -> int:
        return max(shape.global_batch // (mesh.dp // p) // m, 1)

    def evaluate(config: Dict) -> Tuple[float, float, bool]:
        p, m = config["pipe_m"]
        mb, attn_impl, remat = (config["microbatch"], config["attn_impl"],
                                config["remat"])
        s = config["staleness"]
        if s and p > 1:  # async PS assumes one flat data axis (no pipe)
            return float("inf"), float("inf"), False
        if p == 1:
            if mb > b_rep or b_rep % mb:
                return float("inf"), float("inf"), False
            rows = mb
            mem = mm.train_memory(
                cfg, shape, dp=mesh.dp, tp=mesh.tp, fsdp=fsdp,
                microbatch=mb, attn_impl=attn_impl, remat=remat,
                seq_parallel=True, opt_kind=opt_kind)
        else:
            if mb != 1:  # m already fixes the per-pass rows
                return float("inf"), float("inf"), False
            rows = stage_rows(p, m)
            mem = mm.train_memory(
                cfg, shape, dp=mesh.dp // p, tp=mesh.tp, fsdp=fsdp,
                microbatch=rows, attn_impl=attn_impl, remat=remat,
                seq_parallel=True, opt_kind=opt_kind,
                pipe=p, n_microbatch=m)
        t = estimate_step_time(cfg, shape, mesh, remat, rows,
                               pipe=p, n_microbatch=m, staleness=s,
                               backup_workers=backup_workers,
                               mean_delay=mean_delay, **overlap_kw)["total"]
        # dense attention has no flash overhead; tiny bonus at short S
        if attn_impl == "dense" and shape.seq_len <= 4096:
            t *= 0.98
        return t, mem.total, mem.total <= 0.9 * hbm

    t_comp = {r: train_flops_per_step(cfg, shape, r)
              / mesh.chips / mesh.chip.peak_flops for r in ("block", "none")}

    def lower_bound(partial: Dict) -> float:
        factor = 1.0
        if "pipe_m" in partial:
            p, m = partial["pipe_m"]
            if p > 1:
                factor = (m + p - 1) / m
        return 0.98 * factor * t_comp.get(partial.get("remat"),
                                          min(t_comp.values()))

    return dims, evaluate, lower_bound


def plan_train(cfg: ModelConfig, shape: ShapeConfig,
               mesh: MeshSpec = SINGLE_POD, *,
               sync_overlap: bool = False, bucket_mb: float = 0.0,
               overlap_efficiency: float = 1.0,
               pipe: Optional[int] = None, n_microbatch: int = 0,
               staleness: Union[int, Tuple[int, ...], None] = None,
               backup_workers: int = 0, mean_delay: float = 0.0) -> Plan:
    overlap_kw = dict(sync_overlap=sync_overlap, bucket_mb=bucket_mb,
                      overlap_efficiency=overlap_efficiency)
    async_kw = dict(staleness=staleness, backup_workers=backup_workers,
                    mean_delay=mean_delay)
    notes: List[str] = []
    if mesh.chip.calibrated:
        notes.append(f"priced on measured constants ({mesh.chip.name}: "
                     f"{mesh.chip.peak_flops:.3g} FLOP/s achieved)")
    hbm = mesh.chip.hbm_bytes

    n_bytes_bf16 = 2 * mm.n_params(cfg)
    fsdp = n_bytes_bf16 / mesh.tp > 0.25 * hbm
    if fsdp:
        notes.append(f"FSDP on: bf16 params/TP = "
                     f"{n_bytes_bf16 / mesh.tp / 2**30:.1f} GiB > 25% HBM")

    # optimizer: AdamW unless its state cannot fit even fully sharded
    opt_kind = "adamw"
    if 12 * mm.n_params(cfg) / mesh.chips > 0.55 * hbm:
        opt_kind = "momentum"
        notes.append("AdamW state exceeds 55% HBM fully sharded -> "
                     "paper-era momentum SGD (4 B/param)")

    # Eq.-6 unified: branch-and-bound over pipeline cut x microbatch x
    # attention x remat, priced by the roofline under the HBM bound
    dims, evaluate, lb = train_search_space(
        cfg, shape, mesh, fsdp=fsdp, opt_kind=opt_kind,
        pipe=pipe, n_microbatch=n_microbatch, **overlap_kw, **async_kw)
    found = search_bnb(dims, evaluate, lower_bound=lb)
    p, n_micro = found.config["pipe_m"]
    stale = int(found.config["staleness"])
    attn_impl, remat = found.config["attn_impl"], found.config["remat"]
    dp_data = mesh.dp // p
    mb = (found.config["microbatch"] if p == 1
          else max(shape.global_batch // dp_data // n_micro, 1))
    t_best = found.time if found.feasible else float("inf")
    if not found.feasible:
        notes.append("NO feasible microbatch found — does not fit this mesh")
    if p > 1:
        cut = balanced_stage_cut(M.main_cycles(cfg), p)
        notes.append(
            f"1F1B pipeline: {p} stages x {n_micro} microbatches, model "
            f"bubble {pipeline_bubble(p, n_micro):.1%}, stage cut {list(cut)}")
    else:
        cut = None

    mem = mm.train_memory(cfg, shape, dp=dp_data, tp=mesh.tp, fsdp=fsdp,
                          microbatch=mb, attn_impl=attn_impl, remat=remat,
                          seq_parallel=True, opt_kind=opt_kind,
                          pipe=p, n_microbatch=n_micro if p > 1 else 0)
    fits = mem.total <= hbm

    # Lemma 3.2 (tier-aware): can grad sync hide behind compute, and does
    # the topology make the hierarchical schedule the better vehicle?
    sync = ps.grad_sync_plan(
        2 * mm.n_params(cfg) / mesh.tp / p, _dp_tiers(mesh),
        t_c=t_best if math.isfinite(t_best) else 1.0)
    notes.append(f"Lemma3.2: {sync.note}")
    if sync.bottleneck_tier:
        notes.append(f"bottleneck tier: {sync.bottleneck_tier}")

    # Lemma 3.1: overhead ratio from the non-compute roofline terms — with
    # overlap on, only the *exposed* collective share counts as overhead
    terms = estimate_step_time(cfg, shape, mesh, remat, mb,
                               pipe=p, n_microbatch=n_micro, staleness=stale,
                               backup_workers=backup_workers,
                               mean_delay=mean_delay, **overlap_kw)
    r_o = r_o_from_terms(terms)
    if stale > 0 or backup_workers > 0:
        notes.append(
            f"async PS: staleness={stale} (pull amortized "
            f"1/{stale + 1}), backup_workers={backup_workers}, straggler "
            f"wait {terms['straggler_wait']:.3g}s, statistical efficiency "
            f"{terms['staleness_efficiency']:.2f}")
    eff = amdahl.efficiency(mesh.chips, r_o / mesh.chips)  # R_O already aggregate
    if sync_overlap:
        exposed = terms["collective_grad_exposed"]
        serial = terms["collective_grad"]
        bound = ("comm-bound" if exposed + terms["collective_tp"]
                 > max(terms["compute"], terms["memory"]) else "compute-bound")
        notes.append(
            f"overlap: {int(terms['overlap_n_buckets'])} buckets hide "
            f"{terms['overlap_fraction']:.0%} of grad sync "
            f"({serial:.3g}s -> {exposed:.3g}s exposed); {bound} after "
            "overlap")
    return Plan(
        arch=cfg.name, shape=shape.name, mesh=(dp_data, mesh.tp), fsdp=fsdp,
        microbatch=mb, attn_impl=attn_impl, remat=remat, seq_parallel=True,
        opt_kind=opt_kind, sync_schedule=sync.schedule,
        est_step_time=t_best, est_memory_gb=mem.total / 2**30, fits=fits,
        efficiency=eff, grad_bytes=4.0 * mm.n_params(cfg) / mesh.tp / p,
        topology=mesh.cluster.to_dict(),
        bottleneck_tier=sync.bottleneck_tier,
        calibrated=mesh.chip.calibrated,
        sync_overlap=sync_overlap, bucket_mb=bucket_mb,
        pipe=p, n_microbatch=n_micro,
        stage_cut=list(cut) if cut else None,
        staleness=stale, backup_workers=backup_workers, notes=notes,
    )


def plan_decode(cfg: ModelConfig, shape: ShapeConfig,
                mesh: MeshSpec = SINGLE_POD) -> Plan:
    notes: List[str] = []
    hbm = mesh.chip.hbm_bytes
    window = 0
    if shape.seq_len > 100_000 and not cfg.subquadratic:
        window = 8192
        notes.append("long-context SWA-8192 variant (DESIGN.md policy)")
    fsdp = 2 * mm.n_params(cfg) / mesh.tp > 0.5 * hbm
    mem = mm.decode_memory(cfg, shape, dp=mesh.dp, tp=mesh.tp, fsdp=fsdp,
                           window_override=window)
    fits = mem.total <= hbm
    if not fits:
        notes.append(f"decode memory {mem.total/2**30:.1f} GiB > HBM")
    # decode is memory-bound: step time ~ (params + cache) / HBM bw
    t = (mem.params + mem.kv_cache) / mesh.chip.hbm_bw
    return Plan(
        arch=cfg.name, shape=shape.name, mesh=(mesh.dp, mesh.tp), fsdp=fsdp,
        microbatch=0, attn_impl="dense", remat="none", seq_parallel=False,
        opt_kind="-", sync_schedule="-", est_step_time=t,
        est_memory_gb=mem.total / 2**30, fits=fits,
        efficiency=1.0, topology=mesh.cluster.to_dict(),
        calibrated=mesh.chip.calibrated, notes=notes,
    )


def plan(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec = SINGLE_POD, *,
         sync_overlap: bool = False, bucket_mb: float = 0.0,
         overlap_efficiency: float = 1.0,
         pipe: Optional[int] = None, n_microbatch: int = 0,
         staleness: Union[int, Tuple[int, ...], None] = None,
         backup_workers: int = 0, mean_delay: float = 0.0) -> Plan:
    if shape.kind == "train" or shape.kind == "prefill":
        return plan_train(cfg, shape, mesh, sync_overlap=sync_overlap,
                          bucket_mb=bucket_mb,
                          overlap_efficiency=overlap_efficiency,
                          pipe=pipe, n_microbatch=n_microbatch,
                          staleness=staleness,
                          backup_workers=backup_workers,
                          mean_delay=mean_delay)
    return plan_decode(cfg, shape, mesh)  # decode has no gradient sync
