"""docs-check — keep the docs/ site honest.

Two checks, both CI-enforced (.github/workflows/ci.yml `docs-check` job):

1. **Links**: every intra-repo markdown link in README.md, docs/*.md and
   the root *.md files must resolve to an existing file (anchors are
   stripped; external http(s)/mailto links are skipped).
2. **Snippets**: the ``python`` code blocks embedded in
   ``docs/tuning_guide.md`` and ``docs/observability.md`` execute top to
   bottom in one namespace (per doc), like a notebook — each guide's
   walkthrough is run, not just rendered.  Sized for CPU (--quick-scale
   configs inside the docs themselves).

    PYTHONPATH=src python tools/docs_check.py [--links-only]
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# [text](target) — excluding images' ! prefix is unnecessary (images are
# links too and must also resolve)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)

SNIPPET_DOCS = ("docs/tuning_guide.md", "docs/observability.md",
                "docs/serving.md", "docs/static_analysis.md",
                "docs/checkpointing.md")


def iter_doc_files():
    yield from sorted(REPO.glob("*.md"))
    yield from sorted((REPO / "docs").glob("*.md"))


def check_links() -> list:
    """Return a list of "file: broken-target" strings."""
    broken = []
    for md in iter_doc_files():
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                broken.append(f"{md.relative_to(REPO)}: {target}")
    return broken


def run_snippets(doc: str) -> int:
    """Execute the doc's ```python blocks sequentially in one namespace;
    returns the number of blocks run."""
    text = (REPO / doc).read_text()
    blocks = _FENCE_RE.findall(text)
    ns: dict = {"__name__": f"docs_check:{doc}"}
    for i, block in enumerate(blocks):
        t0 = time.time()
        try:
            exec(compile(block, f"{doc}[snippet {i + 1}]", "exec"), ns)
        except Exception:
            print(f"FAIL {doc} snippet {i + 1}:\n{block}", file=sys.stderr)
            raise
        print(f"  ok {doc} snippet {i + 1}/{len(blocks)} "
              f"({time.time() - t0:.1f}s)")
    return len(blocks)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the embedded snippets")
    args = ap.parse_args(argv)

    broken = check_links()
    if broken:
        print("broken intra-repo links:", file=sys.stderr)
        for b in broken:
            print(f"  {b}", file=sys.stderr)
        return 1
    n_files = len(list(iter_doc_files()))
    print(f"links ok across {n_files} markdown files")

    if not args.links_only:
        # pin the backend before anything imports jax (libtpu probe stall)
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        sys.path.insert(0, str(REPO / "src"))
        for doc in SNIPPET_DOCS:
            n = run_snippets(doc)
            print(f"snippets ok: {doc} ({n} blocks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
