"""repro-lint — the repo's domain static-analysis gate.

Runs the four ``repro.analysis`` analyzers (kernel contracts, determinism,
mesh axes, schema drift) over the repo, subtracts the committed baseline
(``tools/lint_baseline.json`` — justified suppressions keyed by
line-stable fingerprints), and exits non-zero on any *unbaselined*
finding.  CI runs this in the ``lint`` job and uploads the ``--json``
artifact.

    PYTHONPATH=src python tools/repro_lint.py              # human output
    PYTHONPATH=src python tools/repro_lint.py --json \\
        --out results/lint_findings.json                   # CI artifact
    PYTHONPATH=src python tools/repro_lint.py --analyzer determinism
    PYTHONPATH=src python tools/repro_lint.py --write-baseline  # accept all

Baseline workflow: fix findings where possible; for the rare justified
exception, add ``{"fingerprint": "CODE:path:context", "reason": "..."}``
to the baseline by hand (or ``--write-baseline`` then edit every
``TODO: justify``).  Stale suppressions (matching nothing) are reported
so fixed findings don't leave dead entries behind.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_BASELINE = REPO / "tools" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="emit the repro.analysis/findings/v1 payload")
    ap.add_argument("--out", default="",
                    help="also write the JSON payload to this file")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="suppression file (repro.analysis/baseline/v1)")
    ap.add_argument("--analyzer", action="append", default=None,
                    choices=["kernel", "determinism", "mesh", "schema"],
                    help="run only these analyzers (repeatable)")
    ap.add_argument("--root", default=str(REPO),
                    help="tree to analyze (default: this repo; the kernel "
                         "analyzer always audits the imported registry)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write a baseline suppressing every current "
                         "finding (reasons start as 'TODO: justify')")
    args = ap.parse_args(argv)

    # pin the backend before repro.kernels pulls in jax (libtpu probe)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import (apply_baseline, load_baseline, make_baseline,
                                make_findings_payload, run_analyzers)
    from repro.obs.trace import monotonic

    t0 = monotonic()
    findings = run_analyzers(Path(args.root), args.analyzer)

    if args.write_baseline:
        reasons = load_baseline(Path(args.baseline))
        doc = make_baseline(findings, reasons)
        Path(args.baseline).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {args.baseline}: {len(doc['suppressions'])} "
              "suppression(s)")
        return 0

    suppressions = load_baseline(Path(args.baseline))
    unbaselined, suppressed, stale = apply_baseline(findings, suppressions)
    payload = make_findings_payload(unbaselined, suppressed, stale,
                                    monotonic() - t0)

    if args.out:
        out_path = Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(payload, indent=2) + "\n")
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        for f in unbaselined:
            print(f)
        for fp in stale:
            print(f"stale suppression (fix landed? delete it): {fp}",
                  file=sys.stderr)
        print(f"repro-lint: {len(unbaselined)} finding(s), "
              f"{len(suppressed)} suppressed, {len(stale)} stale, "
              f"{payload['wall_s']:.1f}s")
    return 1 if unbaselined else 0


if __name__ == "__main__":
    sys.exit(main())
