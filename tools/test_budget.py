"""tier-1 budget guard — keep the fast test subset fast, honestly.

Two checks (both wired into CI's fast-tier job, and the marker scan also
runs inside tier-1 itself via ``tests/test_tier1_guard.py``):

1. **Marker scan** (static, no pytest run): every test function that
   spawns a subprocess (``run_sub`` / ``subprocess.*``) must carry
   ``@pytest.mark.slow`` — a new subprocess test silently landing in the
   fast tier is exactly how tier-1 wall clock rots.  Pre-existing bounded
   fast subprocess tests are grandfathered in :data:`ALLOW_FAST_SUBPROCESS`
   (file-level or per-test); additions to that list should carry a reason.
2. **Wall-clock budget**: given a ``--junit`` report from the tier-1 run
   (``pytest -q --junitxml=...``), the summed test time must stay under
   ``--budget-s``.
3. **Lint budget** (CI's ``lint`` job): given ``--lint-json`` (the
   ``repro.analysis/findings/v1`` artifact from ``tools/repro_lint.py
   --json --out ...``), its recorded ``wall_s`` must stay under
   ``--lint-budget-s`` — the static-analysis gate must stay cheap enough
   to never be worth skipping.

    PYTHONPATH=src python tools/test_budget.py \
        [--junit results/tier1.xml] [--budget-s 900] \
        [--lint-json results/lint_findings.json] [--lint-budget-s 120]

Exit status 0 = within budget and no unmarked subprocess tests.
"""
from __future__ import annotations

import argparse
import ast
import sys
import xml.etree.ElementTree as ET
from pathlib import Path
from typing import Dict, List, Set, Tuple

REPO = Path(__file__).resolve().parent.parent
TESTS_DIR = REPO / "tests"

# Default tier-1 wall-clock budget [s].  The seed suite runs ~5-6 min on
# the CI runner class; the budget leaves headroom without letting the fast
# tier double silently.
DEFAULT_BUDGET_S = 900.0

# Static-analysis gate budget [s]: repro_lint runs in ~1-2s locally; 120s
# leaves room for cold CI caches while still catching an analyzer that
# grew a quadratic scan.
DEFAULT_LINT_BUDGET_S = 120.0

# Fast tests allowed to spawn subprocesses: (file, test-name) with
# "*" = every test in the file.  Keep each entry justified.
ALLOW_FAST_SUBPROCESS: Set[Tuple[str, str]] = {
    # pre-existing bounded re-exec tests: tiny graphs, one subprocess each,
    # they ARE the distributed-correctness fast coverage
    ("test_distributed.py", "*"),
}


def _is_slow_marker(dec: ast.expr) -> bool:
    """True for ``pytest.mark.slow`` / ``mark.slow`` decorators."""
    if isinstance(dec, ast.Call):
        dec = dec.func
    return isinstance(dec, ast.Attribute) and dec.attr == "slow" and (
        isinstance(dec.value, ast.Attribute) and dec.value.attr == "mark"
        or isinstance(dec.value, ast.Name) and dec.value.id == "mark")


def _spawn_names(tree: ast.Module) -> Tuple[Set[str], Set[str]]:
    """(module aliases of ``subprocess``, bare names that spawn) for a test
    module — so ``import subprocess as sp`` and
    ``from subprocess import run`` can't evade the scan."""
    aliases = {"subprocess"}
    names = {"run_sub"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "subprocess":
                    aliases.add(a.asname or a.name)
        elif isinstance(node, ast.ImportFrom) and node.module == "subprocess":
            names.update(a.asname or a.name for a in node.names)
    return aliases, names


def _spawns_subprocess(node: ast.AST, aliases: Set[str],
                       names: Set[str]) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in names:
            return True
        if isinstance(sub, ast.Attribute) and isinstance(sub.value, ast.Name) \
                and sub.value.id in aliases:
            return True
    return False


def _module_is_slow(tree: ast.Module) -> bool:
    """A module-level ``pytestmark = pytest.mark.slow`` covers every test."""
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "pytestmark"
                for t in node.targets):
            marks = (node.value.elts if isinstance(node.value, (ast.List,
                                                                ast.Tuple))
                     else [node.value])
            return any(_is_slow_marker(m) for m in marks)
    return False


def check_markers() -> List[str]:
    """Return a violation line per fast (unmarked) subprocess test."""
    violations: List[str] = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        if _module_is_slow(tree):
            continue
        aliases, names = _spawn_names(tree)
        for node in tree.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not node.name.startswith("test"):
                continue
            if not _spawns_subprocess(node, aliases, names):
                continue
            if any(_is_slow_marker(d) for d in node.decorator_list):
                continue
            if ("*" in {t for f, t in ALLOW_FAST_SUBPROCESS
                        if f == path.name}
                    or (path.name, node.name) in ALLOW_FAST_SUBPROCESS):
                continue
            rel = (path.relative_to(REPO) if path.is_relative_to(REPO)
                   else path.name)
            violations.append(
                f"{rel}::{node.name} spawns a subprocess "
                "but has no @pytest.mark.slow (add the marker, or allowlist "
                "it in tools/test_budget.py with a reason)")
    return violations


def junit_times(junit: Path) -> Dict[str, float]:
    """testcase -> seconds from a junitxml report."""
    root = ET.parse(junit).getroot()
    out: Dict[str, float] = {}
    for case in root.iter("testcase"):
        name = f"{case.get('classname', '')}::{case.get('name', '')}"
        out[name] = float(case.get("time", 0.0))
    return out


def check_budget(junit: Path, budget_s: float) -> List[str]:
    times = junit_times(junit)
    total = sum(times.values())
    print(f"tier-1 test time: {total:.1f}s over {len(times)} tests "
          f"(budget {budget_s:.0f}s)")
    for name, t in sorted(times.items(), key=lambda kv: -kv[1])[:5]:
        print(f"  slowest: {t:7.1f}s  {name}")
    if total > budget_s:
        return [f"tier-1 fast subset took {total:.1f}s > budget "
                f"{budget_s:.0f}s — mark the new heavyweight tests slow or "
                "raise the budget deliberately"]
    return []


def check_lint_budget(lint_json: Path, budget_s: float) -> List[str]:
    """Validate the repro_lint findings artifact and price its wall clock."""
    import json

    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis.findings import validate_findings

    payload = validate_findings(json.loads(lint_json.read_text()))
    wall = float(payload["wall_s"])
    print(f"repro-lint wall clock: {wall:.1f}s "
          f"(budget {budget_s:.0f}s, clean={payload['clean']})")
    if wall > budget_s:
        return [f"repro_lint took {wall:.1f}s > budget {budget_s:.0f}s — "
                "the static-analysis gate must stay cheap"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--junit", default="",
                    help="junitxml report of the tier-1 run; omitting it "
                         "skips the wall-clock check (marker scan only)")
    ap.add_argument("--budget-s", type=float, default=DEFAULT_BUDGET_S)
    ap.add_argument("--lint-json", default="",
                    help="repro_lint --json artifact; omitting it skips "
                         "the lint wall-clock check")
    ap.add_argument("--lint-budget-s", type=float,
                    default=DEFAULT_LINT_BUDGET_S)
    args = ap.parse_args(argv)

    problems = check_markers()
    if args.junit:
        problems += check_budget(Path(args.junit), args.budget_s)
    if args.lint_json:
        problems += check_lint_budget(Path(args.lint_json),
                                      args.lint_budget_s)
    for p in problems:
        print(f"BUDGET GUARD: {p}", file=sys.stderr)
    if not problems:
        print("test budget guard: ok")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
