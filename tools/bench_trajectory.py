"""bench-trajectory — the per-PR performance ledger.

Every CI run appends one record per area to ``BENCH_<area>.json`` (train,
serve) — headline numbers (step time, tokens/s, overlap fraction, serve
p99) plus the git sha — so speedups and regressions land *recorded* instead
of anecdotal.  The compare mode prices the newest record against the
previous one under a per-metric regression budget: within budget passes,
over budget warns (``--warn-only``, the default posture for a metric's
first landing) or fails.

    # append a record distilled from a Report JSON
    PYTHONPATH=src python tools/bench_trajectory.py append \
        --area train --report results/quickstart_train_report.json

    # compare the last two records (exit 1 on an over-budget regression)
    python tools/bench_trajectory.py compare --area train [--warn-only]

Only the *headline* metrics are budget-checked (train: ``step_time_s``
down-is-good, ``tokens_per_s`` up-is-good; serve: ``tokens_per_s``,
``decode_p99_s``); everything else in a record is informational.  CPU CI
wall clocks are noisy, so the default budget is generous (35%) — the
trajectory's job is catching step-function regressions and recording the
trend, not 2% drifts.

Stdlib-only except for the Report schema check (repro.api, via PYTHONPATH).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

REPO = Path(__file__).resolve().parent.parent

TRAJECTORY_SCHEMA_ID = "repro.obs/bench-trajectory/v1"

# area -> headline metrics under budget: {name: direction}, where "down"
# means smaller is better (regression = increase) and "up" the reverse
HEADLINE = {
    "train": {"step_time_s": "down", "tokens_per_s": "up"},
    "serve": {"decode_p99_s": "down", "tokens_per_s": "up"},
}
DEFAULT_BUDGET = 0.35  # fractional regression allowed on a headline metric


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=REPO, capture_output=True, text=True,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def trajectory_path(area: str) -> Path:
    return REPO / f"BENCH_{area}.json"


def load_trajectory(area: str) -> Dict[str, Any]:
    p = trajectory_path(area)
    if not p.exists():
        return {"schema": TRAJECTORY_SCHEMA_ID, "area": area, "records": []}
    d = json.loads(p.read_text())
    if d.get("schema") != TRAJECTORY_SCHEMA_ID:
        raise SystemExit(f"{p}: schema {d.get('schema')!r} != "
                         f"{TRAJECTORY_SCHEMA_ID!r}")
    return d


def save_trajectory(area: str, d: Dict[str, Any]) -> Path:
    p = trajectory_path(area)
    p.write_text(json.dumps(d, indent=2) + "\n")
    return p


# ---------------------------------------------------------------------------
# Record distillation: Report JSON -> one flat trajectory record
# ---------------------------------------------------------------------------


def _train_record(rep: Dict[str, Any]) -> Dict[str, float]:
    m = rep["measured"]
    st = m.get("step_times_mean", {})
    out = {
        "step_time_s": (st.get("compute", 0.0) + st.get("dist_update", 0.0)
                        + st.get("param_update", 0.0)),
        "tokens_per_s": float(m["tokens_per_s"]),
        "r_o": float(m.get("r_o", 0.0)),
    }
    sync = m.get("sync") or {}
    if sync.get("sync_overlap"):
        out["overlap_fraction"] = float(sync["overlap_fraction"])
        out["exposed_comm_s"] = float(sync["exposed_comm_time"])
    return out


def _serve_record(rep: Dict[str, Any]) -> Dict[str, float]:
    m = rep["measured"]
    hists = (m.get("metrics") or {}).get("histograms", {})
    decode = hists.get("serve/decode_s", {})
    prefill = hists.get("serve/prefill_s", {})
    out = {
        "tokens_per_s": float(m["tokens_per_s"]),
        "wall_s": float(m.get("wall_s", 0.0)),
        "decode_p99_s": float(decode.get("p99", 0.0)),
        "prefill_p99_s": float(prefill.get("p99", 0.0)),
        "requests": float(m.get("requests", 0)),
    }
    sv = m.get("serving") or {}
    if sv:  # serving/v1 section: record the SLO-facing distribution too
        out["latency_p99_s"] = float(sv["latency_s"]["p99"])
        out["wasted_decode_steps"] = float(
            sv["throughput"]["wasted_decode_steps"])
        out["kv_peak_occupancy"] = float(sv["kv_cache"]["peak_occupancy"])
    return out


DISTILL = {"train": _train_record, "serve": _serve_record}


def append_record(area: str, report_path: str, *,
                  sha: Optional[str] = None,
                  note: str = "") -> Dict[str, Any]:
    rep = json.loads(Path(report_path).read_text())
    sys.path.insert(0, str(REPO / "src"))
    from repro.api import validate_report

    validate_report(rep)
    kind = rep["kind"]
    metrics = DISTILL[area](rep)
    record: Dict[str, Any] = {
        "sha": sha or git_sha(),
        "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "kind": kind,
        "spec": {k: rep["spec"].get(k) for k in
                 ("arch", "reduced", "steps", "batch", "seq", "dp",
                  "sync_overlap", "staleness", "backup_workers",
                  "requests", "n_new", "serve_mode")},
        "metrics": metrics,
    }
    if note:
        record["note"] = note
    d = load_trajectory(area)
    d["records"].append(record)
    save_trajectory(area, d)
    return record


# ---------------------------------------------------------------------------
# Comparison: newest record vs its predecessor, headline budget
# ---------------------------------------------------------------------------


def compare(area: str, *, budget: float = DEFAULT_BUDGET) -> List[str]:
    """Return over-budget regression messages ([] = within budget)."""
    records = load_trajectory(area)["records"]
    if len(records) < 2:
        print(f"BENCH_{area}: {len(records)} record(s), nothing to compare")
        return []
    prev, cur = records[-2], records[-1]
    if prev.get("spec") != cur.get("spec"):
        print(f"BENCH_{area}: spec changed between records "
              f"({prev.get('sha')} -> {cur.get('sha')}), comparison skipped")
        return []
    regressions: List[str] = []
    for name, direction in HEADLINE[area].items():
        a = float(prev["metrics"].get(name, 0.0))
        b = float(cur["metrics"].get(name, 0.0))
        if a <= 0.0:  # metric's first landing (or degenerate): inform only
            print(f"BENCH_{area}/{name}: no baseline ({a} -> {b})")
            continue
        delta = (b - a) / a
        regressed = delta > budget if direction == "down" \
            else delta < -budget
        arrow = f"{a:.6g} -> {b:.6g} ({delta:+.1%})"
        if regressed:
            regressions.append(
                f"BENCH_{area}/{name}: {arrow} exceeds the "
                f"{budget:.0%} budget ({'lower' if direction == 'down' else 'higher'}"
                " is better)")
        else:
            print(f"BENCH_{area}/{name}: {arrow} ok")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_a = sub.add_parser("append", help="distill a Report into a record")
    ap_a.add_argument("--area", required=True, choices=sorted(HEADLINE))
    ap_a.add_argument("--report", required=True,
                      help="Report JSON to distill (must validate)")
    ap_a.add_argument("--sha", default="", help="override the git sha")
    ap_a.add_argument("--note", default="")
    ap_c = sub.add_parser("compare", help="newest record vs predecessor")
    ap_c.add_argument("--area", required=True, choices=sorted(HEADLINE))
    ap_c.add_argument("--budget", type=float, default=DEFAULT_BUDGET,
                      help=f"fractional regression budget "
                           f"(default {DEFAULT_BUDGET})")
    ap_c.add_argument("--warn-only", action="store_true",
                      help="report over-budget regressions but exit 0 "
                           "(the posture for a metric's first landings)")
    args = ap.parse_args(argv)

    if args.cmd == "append":
        rec = append_record(args.area, args.report,
                            sha=args.sha or None, note=args.note)
        print(f"BENCH_{args.area}: appended {rec['sha']} "
              f"{json.dumps(rec['metrics'])}")
        return 0

    regressions = compare(args.area, budget=args.budget)
    for r in regressions:
        print(("WARN " if args.warn_only else "FAIL ") + r, file=sys.stderr)
    return 0 if (not regressions or args.warn_only) else 1


if __name__ == "__main__":
    sys.exit(main())
